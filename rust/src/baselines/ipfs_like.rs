//! IPFS-like baseline (paper §II, §VI-E, §VII): a content-addressed P2P
//! network. `put` pins the object on the *local* peer only (fast — no
//! central server, no replication); `get` transfers directly from the
//! pinning peer to the requester (P2P, no gateway hop). The flip side
//! the paper highlights: "IPFS relies on a peer-to-peer model, making
//! data unavailable if a storing peer fails" — killing a peer here loses
//! every object pinned on it.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::crypto::sha3_256;
use crate::faas::DataFabric;
use crate::sim::{Device, DeviceKind, Site, Wan};
use crate::util::to_hex;
use crate::{Error, Result};

struct Peer {
    site: Site,
    alive: bool,
    pinned: HashMap<String, Vec<u8>>,
}

pub struct IpfsLike {
    wan: Wan,
    /// The peer acting as "this client" (where puts pin).
    local_peer: usize,
    peers: Mutex<Vec<Peer>>,
    /// CID → peer index (the DHT).
    dht: Mutex<HashMap<String, usize>>,
    /// key → CID (named pins, for the DataFabric key interface).
    names: Mutex<HashMap<String, String>>,
    device: Device,
}

impl IpfsLike {
    pub fn new(wan: Wan, sites: &[Site], local_peer: usize) -> Self {
        assert!(local_peer < sites.len());
        IpfsLike {
            wan,
            local_peer,
            peers: Mutex::new(
                sites
                    .iter()
                    .map(|&site| Peer { site, alive: true, pinned: HashMap::new() })
                    .collect(),
            ),
            dht: Mutex::new(HashMap::new()),
            names: Mutex::new(HashMap::new()),
            device: Device::new(DeviceKind::ChameleonLocal),
        }
    }

    pub fn peer_count(&self) -> usize {
        self.peers.lock().unwrap().len()
    }

    pub fn set_peer_alive(&self, peer: usize, alive: bool) {
        self.peers.lock().unwrap()[peer].alive = alive;
    }

    /// Pin on a specific peer (spreads content for the experiments where
    /// inputs originate at different sites).
    pub fn put_at(&self, peer_idx: usize, key: &str, data: &[u8]) -> Result<f64> {
        let cid = to_hex(&sha3_256(data));
        let mut peers = self.peers.lock().unwrap();
        let peer = &mut peers[peer_idx];
        if !peer.alive {
            return Err(Error::Unavailable(format!("peer {peer_idx} down")));
        }
        peer.pinned.insert(cid.clone(), data.to_vec());
        self.dht.lock().unwrap().insert(cid.clone(), peer_idx);
        self.names.lock().unwrap().insert(key.to_string(), cid);
        // Local pin: device write + DHT provide-record publish. Still
        // far cheaper than a WAN upload — the paper's "lower processing
        // time" edge for IPFS.
        Ok(self.device.write_s(data.len() as u64) + 0.010)
    }

    /// DHT resolution + direct peer-to-peer fetch to `to_site`.
    pub fn get_to(&self, to_site: Site, key: &str) -> Result<(Vec<u8>, f64)> {
        let cid = self
            .names
            .lock()
            .unwrap()
            .get(key)
            .cloned()
            .ok_or_else(|| Error::NotFound(key.to_string()))?;
        let peer_idx = *self
            .dht
            .lock()
            .unwrap()
            .get(&cid)
            .ok_or_else(|| Error::NotFound(format!("cid {cid}")))?;
        let peers = self.peers.lock().unwrap();
        let peer = &peers[peer_idx];
        if !peer.alive {
            // No replication: the pinning peer is the only copy.
            return Err(Error::Unavailable(format!(
                "peer {peer_idx} holding {key} is down"
            )));
        }
        let data = peer.pinned.get(&cid).cloned().ok_or_else(|| Error::NotFound(cid))?;
        // DHT lookup RTT + bitswap session setup + direct transfer —
        // no central hop, but real protocol overhead per object.
        let lookup = self.wan.link(peer.site, to_site).rtt_s + 0.030;
        let xfer = self.wan.transfer_s(peer.site, to_site, data.len() as u64, 1);
        let read = self.device.read_s(data.len() as u64);
        Ok((data, lookup + xfer + read))
    }
}

impl DataFabric for IpfsLike {
    fn put(&self, key: &str, data: &[u8]) -> Result<f64> {
        self.put_at(self.local_peer, key, data)
    }

    fn get(&self, key: &str) -> Result<(Vec<u8>, f64)> {
        let site = self.peers.lock().unwrap()[self.local_peer].site;
        self.get_to(site, key)
    }

    fn exists(&self, key: &str) -> bool {
        let names = self.names.lock().unwrap();
        match names.get(key) {
            Some(cid) => {
                let dht = self.dht.lock().unwrap();
                match dht.get(cid) {
                    Some(&p) => self.peers.lock().unwrap()[p].alive,
                    None => false,
                }
            }
            None => false,
        }
    }

    fn fabric_name(&self) -> &'static str {
        "ipfs-like"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn network() -> IpfsLike {
        IpfsLike::new(
            Wan::paper_testbed(),
            &[Site::ChameleonTacc, Site::ChameleonUc, Site::Madrid],
            0,
        )
    }

    #[test]
    fn content_addressed_roundtrip() {
        let net = network();
        net.put("img", b"pixels").unwrap();
        assert!(net.exists("img"));
        let (data, cost) = net.get("img").unwrap();
        assert_eq!(data, b"pixels");
        assert!(cost > 0.0);
    }

    #[test]
    fn put_is_cheap_local_pin() {
        // The paper's Fig. 10 result: IPFS wins on raw transfer because
        // puts don't cross the WAN.
        let net = network();
        let put_cost = net.put("big", &vec![0u8; 10_000_000]).unwrap();
        let wan_cost =
            Wan::paper_testbed().transfer_s(Site::Madrid, Site::ChameleonTacc, 10_000_000, 1);
        assert!(put_cost < wan_cost / 4.0, "pin {put_cost} vs wan {wan_cost}");
    }

    #[test]
    fn peer_failure_loses_data() {
        // §VII: "IPFS does not replicate files until requested, which
        // risks data unavailability if the storing node fails."
        let net = network();
        net.put_at(1, "img", b"pixels").unwrap();
        assert!(net.exists("img"));
        net.set_peer_alive(1, false);
        assert!(!net.exists("img"));
        assert!(matches!(net.get("img"), Err(Error::Unavailable(_))));
        // Content on other peers is unaffected.
        net.put_at(0, "other", b"x").unwrap();
        assert!(net.exists("other"));
    }

    #[test]
    fn cross_site_fetch_pays_the_wan() {
        let net = network();
        net.put_at(2, "remote", &vec![1u8; 5_000_000]).unwrap(); // Madrid peer
        let (_, near) = net.get_to(Site::Madrid, "remote").unwrap();
        let (_, far) = net.get_to(Site::ChameleonTacc, "remote").unwrap();
        assert!(far > near, "far {far} vs near {near}");
    }
}
