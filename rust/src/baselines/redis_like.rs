//! Redis-like baseline (paper §VI-E, Fig. 10): an in-memory cluster
//! store deployed in a single region ("Redis nodes are deployed in the
//! same region of Chameleon, creating a cluster of virtual machines
//! under the same network"). Persistence is modeled per the paper's
//! fair-comparison setup: periodic disk backup + per-op append-only-file
//! logging. Replication factor 1 primary + 1 replica inside the LAN.
//!
//! Redis's documented limitation (§VII): all nodes must share a stable
//! low-latency network — the model charges the full WAN path for remote
//! clients and has no cross-site placement at all.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::faas::DataFabric;
use crate::sim::{Device, DeviceKind, Site, Wan};
use crate::{Error, Result};

pub struct RedisLike {
    wan: Wan,
    client_site: Site,
    cluster_site: Site,
    mem: Device,
    disk: Device,
    data: Mutex<HashMap<String, Vec<u8>>>,
    alive: std::sync::atomic::AtomicBool,
}

impl RedisLike {
    pub fn new(wan: Wan, client_site: Site, cluster_site: Site) -> Self {
        RedisLike {
            wan,
            client_site,
            cluster_site,
            mem: Device::new(DeviceKind::Memory),
            disk: Device::new(DeviceKind::ChameleonLocal),
            data: Mutex::new(HashMap::new()),
            alive: std::sync::atomic::AtomicBool::new(true),
        }
    }

    /// Simulate cluster outage (Fig. 10 fault-tolerance discussion).
    pub fn set_alive(&self, alive: bool) {
        self.alive.store(alive, std::sync::atomic::Ordering::SeqCst);
    }

    fn check(&self) -> Result<()> {
        if self.alive.load(std::sync::atomic::Ordering::SeqCst) {
            Ok(())
        } else {
            Err(Error::Unavailable("redis cluster down".into()))
        }
    }

    pub fn put_cost(&self, bytes: u64) -> f64 {
        let wan = self.wan.transfer_s(self.client_site, self.cluster_site, bytes, 1);
        // Memory write + LAN replica hop + AOF append (disk, amortized).
        let lan = self.wan.transfer_s(self.cluster_site, self.cluster_site, bytes, 1);
        wan + self.mem.write_s(bytes) + lan + self.disk.write_s(bytes) * 0.2
    }

    pub fn get_cost(&self, bytes: u64) -> f64 {
        self.wan.transfer_s(self.cluster_site, self.client_site, bytes, 1)
            + self.mem.read_s(bytes)
    }
}

impl DataFabric for RedisLike {
    fn put(&self, key: &str, data: &[u8]) -> Result<f64> {
        self.check()?;
        let cost = self.put_cost(data.len() as u64);
        self.data.lock().unwrap().insert(key.to_string(), data.to_vec());
        Ok(cost)
    }

    fn get(&self, key: &str) -> Result<(Vec<u8>, f64)> {
        self.check()?;
        let map = self.data.lock().unwrap();
        let d = map.get(key).ok_or_else(|| Error::NotFound(key.to_string()))?;
        Ok((d.clone(), self.get_cost(d.len() as u64)))
    }

    fn exists(&self, key: &str) -> bool {
        self.check().is_ok() && self.data.lock().unwrap().contains_key(key)
    }

    fn fabric_name(&self) -> &'static str {
        "redis-like"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn redis(client: Site) -> RedisLike {
        RedisLike::new(Wan::paper_testbed(), client, Site::ChameleonUc)
    }

    #[test]
    fn roundtrip() {
        let r = redis(Site::ChameleonUc);
        r.put("k", b"v").unwrap();
        assert_eq!(r.get("k").unwrap().0, b"v");
        assert!(r.exists("k"));
    }

    #[test]
    fn local_clients_are_fast_remote_slow() {
        // §VII: Redis is built for same-network deployments.
        let local = redis(Site::ChameleonUc).put_cost(100_000_000);
        let remote = redis(Site::Madrid).put_cost(100_000_000);
        assert!(remote > local * 3.0, "remote {remote} vs local {local}");
    }

    #[test]
    fn cluster_outage_loses_everything() {
        // Single-site deployment: one outage takes out all data
        // (contrast with DynoStore's chunk dispersal).
        let r = redis(Site::ChameleonUc);
        r.put("k", b"v").unwrap();
        r.set_alive(false);
        assert!(matches!(r.get("k"), Err(Error::Unavailable(_))));
        assert!(!r.exists("k"));
    }
}
