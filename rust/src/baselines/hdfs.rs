//! HDFS-like baseline (paper Fig. 4, Table II, §VII): a cluster
//! filesystem at a single site with the resilience policies the paper
//! evaluates — three-copy replication (R3) and Reed-Solomon RS(d, p)
//! erasure coding (RS(3,2), RS(6,3), RS(10,4) in Fig. 4; RS(6,3) is the
//! Table II default). GlusterFS (RS(4,2)) and DAOS (RS(8,2)) defaults
//! are expressed as [`HdfsPolicy::ReedSolomon`] configs too.
//!
//! Uses the same IDA codec as DynoStore (both are MDS codes with
//! identical operation counts: chunk + parity + d+p block writes), so
//! Fig. 4's "competitive response times due to the similar number of
//! operations" emerges structurally rather than by tuning.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::erasure::{Codec, ErasureConfig};
use crate::faas::DataFabric;
use crate::sim::{cost, Device, DeviceKind, Site, Wan};
use crate::util::Rng;
use crate::{Error, Result};

/// HDFS resilience policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HdfsPolicy {
    /// Triple replication: tolerates 2 failures at 300% of data stored.
    Replicate3,
    /// RS(data, parity): tolerates `parity` failures.
    ReedSolomon { data: usize, parity: usize },
}

impl HdfsPolicy {
    pub fn label(&self) -> String {
        match self {
            HdfsPolicy::Replicate3 => "HDFS-R3".to_string(),
            HdfsPolicy::ReedSolomon { data, parity } => format!("HDFS-RS({data},{parity})"),
        }
    }

    pub fn failures_tolerated(&self) -> usize {
        match self {
            HdfsPolicy::Replicate3 => 2,
            HdfsPolicy::ReedSolomon { parity, .. } => *parity,
        }
    }

    /// Extra bytes stored per data byte (§VII: 300% for R3 vs 50% for
    /// RS(6,3), 20% for configurations like RS(10,2)).
    pub fn storage_overhead(&self) -> f64 {
        match self {
            HdfsPolicy::Replicate3 => 2.0,
            HdfsPolicy::ReedSolomon { data, parity } => *parity as f64 / *data as f64,
        }
    }
}

struct Node {
    alive: bool,
    blocks: HashMap<String, Vec<u8>>,
}

/// The HDFS-like cluster.
pub struct HdfsLike {
    wan: Wan,
    site: Site,
    policy: HdfsPolicy,
    device: Device,
    nodes: Mutex<Vec<Node>>,
    /// key → (node, block-id) placements.
    placements: Mutex<HashMap<String, Vec<(usize, String)>>>,
    rng: Mutex<Rng>,
    client_site: Site,
}

impl HdfsLike {
    pub fn new(wan: Wan, site: Site, client_site: Site, nodes: usize, policy: HdfsPolicy) -> Self {
        HdfsLike {
            wan,
            site,
            policy,
            device: Device::new(DeviceKind::ChameleonLocal),
            nodes: Mutex::new(
                (0..nodes).map(|_| Node { alive: true, blocks: HashMap::new() }).collect(),
            ),
            placements: Mutex::new(HashMap::new()),
            rng: Mutex::new(Rng::new(0x0FD5)),
            client_site,
        }
    }

    pub fn policy(&self) -> HdfsPolicy {
        self.policy
    }

    pub fn set_node_alive(&self, node: usize, alive: bool) {
        self.nodes.lock().unwrap()[node].alive = alive;
    }

    fn pick_nodes(&self, count: usize) -> Result<Vec<usize>> {
        let nodes = self.nodes.lock().unwrap();
        let live: Vec<usize> =
            nodes.iter().enumerate().filter(|(_, n)| n.alive).map(|(i, _)| i).collect();
        if live.len() < count {
            return Err(Error::Unavailable(format!(
                "hdfs: {count} nodes needed, {} live",
                live.len()
            )));
        }
        let mut rng = self.rng.lock().unwrap();
        let picks = rng.sample_indices(live.len(), count);
        Ok(picks.into_iter().map(|i| live[i]).collect())
    }

    /// Store under the policy; returns simulated seconds.
    pub fn put_object(&self, key: &str, data: &[u8]) -> Result<f64> {
        let ingress = self.wan.transfer_s(self.client_site, self.site, data.len() as u64, 1);
        match self.policy {
            HdfsPolicy::Replicate3 => {
                let targets = self.pick_nodes(3)?;
                let mut nodes = self.nodes.lock().unwrap();
                let mut placement = Vec::new();
                for (i, &t) in targets.iter().enumerate() {
                    let bid = format!("{key}/rep{i}");
                    nodes[t].blocks.insert(bid.clone(), data.to_vec());
                    placement.push((t, bid));
                }
                drop(nodes);
                self.placements.lock().unwrap().insert(key.to_string(), placement);
                // HDFS write pipeline: client→n1→n2→n3 overlapped; cost ≈
                // one transfer + 2 pipeline hop latencies + device write.
                let lan_hop = self.wan.link(self.site, self.site).rtt_s;
                Ok(ingress + self.device.write_s(data.len() as u64) + 2.0 * lan_hop)
            }
            HdfsPolicy::ReedSolomon { data: d, parity: p } => {
                let cfg = ErasureConfig::new(d + p, d);
                cfg.validate()?;
                let codec = Codec::new(cfg)?;
                let chunks = codec.encode(data)?;
                // Modeled at the same calibrated coding bandwidth as the
                // DynoStore gateway (see coordinator::ops) so Fig. 4
                // compares policies, not this host's CPU.
                let encode_s = data.len() as f64 / 1.2e9;
                let targets = self.pick_nodes(d + p)?;
                let mut nodes = self.nodes.lock().unwrap();
                let mut placement = Vec::new();
                let mut write_times = Vec::new();
                for (chunk, &t) in chunks.iter().zip(&targets) {
                    let bid = format!("{key}/blk{}", chunk.header.index);
                    nodes[t].blocks.insert(bid.clone(), chunk.packed.clone());
                    placement.push((t, bid));
                    let lan = self.wan.transfer_s(
                        self.site,
                        self.site,
                        chunk.wire_len() as u64,
                        (d + p) as u32,
                    );
                    write_times.push(lan + self.device.write_s(chunk.wire_len() as u64));
                }
                drop(nodes);
                self.placements.lock().unwrap().insert(key.to_string(), placement);
                Ok(ingress + encode_s + cost::par(&write_times))
            }
        }
    }

    /// Fetch under the policy; reconstructs through parity when needed.
    pub fn get_object(&self, key: &str) -> Result<(Vec<u8>, f64)> {
        let placement = self
            .placements
            .lock()
            .unwrap()
            .get(key)
            .cloned()
            .ok_or_else(|| Error::NotFound(key.to_string()))?;
        match self.policy {
            HdfsPolicy::Replicate3 => {
                let nodes = self.nodes.lock().unwrap();
                for (node, bid) in &placement {
                    if nodes[*node].alive {
                        if let Some(data) = nodes[*node].blocks.get(bid) {
                            let egress = self.wan.transfer_s(
                                self.site,
                                self.client_site,
                                data.len() as u64,
                                1,
                            );
                            let t = self.device.read_s(data.len() as u64) + egress;
                            return Ok((data.clone(), t));
                        }
                    }
                }
                Err(Error::Unavailable(format!("all replicas of {key} down")))
            }
            HdfsPolicy::ReedSolomon { data: d, parity: p } => {
                let cfg = ErasureConfig::new(d + p, d);
                let codec = Codec::new(cfg)?;
                let nodes = self.nodes.lock().unwrap();
                let mut collected = Vec::new();
                let mut read_times = Vec::new();
                for (node, bid) in &placement {
                    if collected.len() >= d {
                        break;
                    }
                    if !nodes[*node].alive {
                        continue;
                    }
                    if let Some(bytes) = nodes[*node].blocks.get(bid) {
                        collected.push(crate::erasure::Chunk::unpack(bytes)?);
                        read_times.push(
                            self.device.read_s(bytes.len() as u64)
                                + self.wan.transfer_s(
                                    self.site,
                                    self.site,
                                    bytes.len() as u64,
                                    d as u32,
                                ),
                        );
                    }
                }
                drop(nodes);
                if collected.len() < d {
                    return Err(Error::Unavailable(format!(
                        "{key}: {} of {d} blocks live",
                        collected.len()
                    )));
                }
                let data = codec.decode(&collected)?;
                let decode_s = data.len() as f64 / 1.2e9;
                let egress =
                    self.wan.transfer_s(self.site, self.client_site, data.len() as u64, 1);
                Ok((data, cost::par(&read_times) + decode_s + egress))
            }
        }
    }
}

impl DataFabric for HdfsLike {
    fn put(&self, key: &str, data: &[u8]) -> Result<f64> {
        self.put_object(key, data)
    }

    fn get(&self, key: &str) -> Result<(Vec<u8>, f64)> {
        self.get_object(key)
    }

    fn exists(&self, key: &str) -> bool {
        self.placements.lock().unwrap().contains_key(key)
    }

    fn fabric_name(&self) -> &'static str {
        "hdfs-like"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster(policy: HdfsPolicy) -> HdfsLike {
        HdfsLike::new(Wan::paper_testbed(), Site::ChameleonTacc, Site::ChameleonTacc, 16, policy)
    }

    #[test]
    fn replication_roundtrip_and_failover() {
        let h = cluster(HdfsPolicy::Replicate3);
        let data = crate::util::Rng::new(1).bytes(50_000);
        h.put_object("f", &data).unwrap();
        // Kill 2 of the 3 replica holders — still readable.
        let placement = h.placements.lock().unwrap().get("f").cloned().unwrap();
        h.set_node_alive(placement[0].0, false);
        h.set_node_alive(placement[1].0, false);
        assert_eq!(h.get_object("f").unwrap().0, data);
        // Third failure loses it.
        h.set_node_alive(placement[2].0, false);
        assert!(matches!(h.get_object("f"), Err(Error::Unavailable(_))));
    }

    #[test]
    fn reed_solomon_roundtrip_with_failures() {
        let h = cluster(HdfsPolicy::ReedSolomon { data: 6, parity: 3 });
        let data = crate::util::Rng::new(2).bytes(80_000);
        h.put_object("f", &data).unwrap();
        let placement = h.placements.lock().unwrap().get("f").cloned().unwrap();
        for (node, _) in placement.iter().take(3) {
            h.set_node_alive(*node, false);
        }
        assert_eq!(h.get_object("f").unwrap().0, data);
        h.set_node_alive(placement[3].0, false);
        assert!(h.get_object("f").is_err());
    }

    #[test]
    fn r3_is_faster_than_rs_on_upload() {
        // Fig. 4: "HDFS-R3 is the fastest configuration because
        // replication involves fewer computations than erasure coding."
        let r3 = cluster(HdfsPolicy::Replicate3);
        let rs = cluster(HdfsPolicy::ReedSolomon { data: 10, parity: 4 });
        let data = vec![7u8; 2_000_000];
        let t_r3 = r3.put_object("f", &data).unwrap();
        let t_rs = rs.put_object("f", &data).unwrap();
        assert!(t_r3 < t_rs, "r3 {t_r3} vs rs {t_rs}");
    }

    #[test]
    fn overhead_comparison_matches_paper_claims() {
        // §VII: HDFS needs 300% overhead for 2 failures; RS policies
        // are far cheaper per failure tolerated.
        assert_eq!(HdfsPolicy::Replicate3.storage_overhead(), 2.0);
        let rs63 = HdfsPolicy::ReedSolomon { data: 6, parity: 3 };
        assert!((rs63.storage_overhead() - 0.5).abs() < 1e-9);
        assert_eq!(rs63.failures_tolerated(), 3);
    }

    #[test]
    fn insufficient_nodes_rejected() {
        let h = HdfsLike::new(
            Wan::paper_testbed(),
            Site::ChameleonTacc,
            Site::ChameleonTacc,
            2,
            HdfsPolicy::Replicate3,
        );
        assert!(h.put_object("f", b"x").is_err());
    }
}
