//! Baseline systems the paper compares against, re-implemented over the
//! same simulation substrate so the comparisons isolate *policy*
//! differences (DESIGN.md §3): Amazon-S3-like centralized object store,
//! Redis-like in-memory cluster store, IPFS-like P2P content network,
//! and HDFS-like cluster filesystem with replication + Reed-Solomon
//! policies.

mod hdfs;
mod ipfs_like;
mod redis_like;
mod s3_like;

pub use hdfs::{HdfsLike, HdfsPolicy};
pub use ipfs_like::IpfsLike;
pub use redis_like::RedisLike;
pub use s3_like::S3Like;
