//! Amazon-S3-like baseline (paper Fig. 8, §VII): a centralized,
//! single-region object store. Requests pay the client↔region WAN path
//! plus S3's per-request overhead and device service time. Durability is
//! the provider's problem (modeled as internal 3× replication cost on
//! writes, hidden behind the same endpoint).

use std::sync::Mutex;

use std::collections::HashMap;

use crate::faas::DataFabric;
use crate::sim::{Device, DeviceKind, Site, Wan};
use crate::{Error, Result};

pub struct S3Like {
    wan: Wan,
    client_site: Site,
    region: Site,
    device: Device,
    data: Mutex<HashMap<String, Vec<u8>>>,
}

impl S3Like {
    pub fn new(wan: Wan, client_site: Site, region: Site) -> Self {
        S3Like {
            wan,
            client_site,
            region,
            device: Device::new(DeviceKind::S3Object),
            data: Mutex::new(HashMap::new()),
        }
    }

    /// Upload cost: WAN transfer + request overhead + internal storage.
    /// Multipart uploads overlap network streaming with the backend
    /// write, so only a residual (~40%) of the device time is exposed;
    /// internal replication is the provider's pipelined problem.
    pub fn put_cost(&self, bytes: u64) -> f64 {
        let wan = self.wan.transfer_s(self.client_site, self.region, bytes, 1);
        let residual_write = self.device.write_s(bytes) * 0.4;
        wan + residual_write
    }

    pub fn get_cost(&self, bytes: u64) -> f64 {
        let wan = self.wan.transfer_s(self.region, self.client_site, bytes, 1);
        wan + self.device.read_s(bytes) * 0.3
    }
}

impl DataFabric for S3Like {
    fn put(&self, key: &str, data: &[u8]) -> Result<f64> {
        let cost = self.put_cost(data.len() as u64);
        self.data.lock().unwrap().insert(key.to_string(), data.to_vec());
        Ok(cost)
    }

    fn get(&self, key: &str) -> Result<(Vec<u8>, f64)> {
        let map = self.data.lock().unwrap();
        let d = map.get(key).ok_or_else(|| Error::NotFound(key.to_string()))?;
        Ok((d.clone(), self.get_cost(d.len() as u64)))
    }

    fn exists(&self, key: &str) -> bool {
        self.data.lock().unwrap().contains_key(key)
    }

    fn fabric_name(&self) -> &'static str {
        "s3-like"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s3() -> S3Like {
        S3Like::new(Wan::paper_testbed(), Site::Madrid, Site::AwsVirginia)
    }

    #[test]
    fn fabric_roundtrip() {
        let s = s3();
        let cost = s.put("bucket/key", b"hello").unwrap();
        assert!(cost > 0.0);
        let (data, _) = s.get("bucket/key").unwrap();
        assert_eq!(data, b"hello");
        assert!(s.exists("bucket/key"));
        assert!(!s.exists("bucket/other"));
    }

    #[test]
    fn request_overhead_dominates_small_objects() {
        let s = s3();
        let small = s.put_cost(1_000);
        // Pure WAN time for 1 KB is ~tens of ms; S3 adds its request
        // latency residual (~18 ms after multipart overlap) on top.
        let wan_only = Wan::paper_testbed().transfer_s(Site::Madrid, Site::AwsVirginia, 1_000, 1);
        assert!(small > wan_only + 0.015, "small {small} vs wan {wan_only}");
    }

    #[test]
    fn costs_scale_with_size() {
        let s = s3();
        assert!(s.put_cost(10_000_000_000) > s.put_cost(1_000_000_000) * 5.0);
        assert!(s.get_cost(1_000_000_000) > s.get_cost(1_000_000));
    }
}
