//! Append-only write-ahead log with length+CRC32 framing.
//!
//! One record per Paxos-committed [`crate::paxos::MetaCommand`]:
//!
//! ```text
//! [len: u32 LE] [crc: u32 LE] [seq: u64 LE] [payload: len bytes]
//! ```
//!
//! `len` counts the payload only; `crc` is CRC-32 over `seq || payload`
//! so neither a torn payload nor a torn sequence header can slip
//! through. `seq` is the global commit index of the record — recovery
//! uses it to skip records an existing snapshot already covers (a
//! crash between snapshot write and WAL reset must not double-apply).
//!
//! [`Wal::open`] replays the file sequentially and truncates at the
//! first malformed record (short header, short payload, CRC mismatch,
//! non-UTF-8 payload, or an absurd length): a crash mid-append leaves
//! exactly such a torn tail, and the bytes after it are unacknowledged
//! by construction (append fsyncs before the commit is acknowledged).

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::util::{crc32, crc32_update};
use crate::{Error, Result};

/// File name inside the data dir.
pub const WAL_FILE: &str = "wal.log";

/// Per-record header bytes: len (4) + crc (4) + seq (8).
const HEADER: usize = 16;

/// Sanity cap on a single record's payload — anything larger is treated
/// as corruption, not a record (a `MetaCommand` is a few KiB at most).
const MAX_RECORD: u32 = 1 << 28;

/// One intact record recovered from disk.
#[derive(Debug, Clone, PartialEq)]
pub struct WalRecord {
    pub seq: u64,
    pub payload: String,
}

/// Everything [`Wal::open`] found.
#[derive(Debug, Default)]
pub struct WalRecovery {
    pub records: Vec<WalRecord>,
    /// Trailing garbage (torn append) was dropped and the file
    /// truncated back to the last intact record.
    pub truncated: bool,
}

/// The open log, positioned for appending.
pub struct Wal {
    file: File,
    path: PathBuf,
    records: u64,
    /// Byte offset just past the last fully-persisted record: the
    /// rollback point when an append fails partway.
    end: u64,
    /// Set on ANY append failure. Two reasons to stop cold: (a) a tear
    /// that couldn't be rolled back would sit in front of later
    /// appends, and recovery's truncate-at-first-bad-frame would drop
    /// those later acknowledged records; (b) even after a clean
    /// rollback, the failed command was already *chosen* by Paxos — if
    /// later commits were accepted, their acknowledged metadata
    /// (versions, UUIDs) would be computed with the unlogged command
    /// applied, and a restart (which cannot see it) would re-derive
    /// different metadata for them. After a failed fsync the only
    /// honest state is read-only-until-restart, so every further
    /// append is refused (cf. the fsyncgate postmortems).
    poisoned: bool,
}

impl Wal {
    /// Open (creating if absent) and scan the log, truncating any torn
    /// tail in place. Returns the writer positioned after the last
    /// intact record plus everything readable.
    pub fn open(path: impl Into<PathBuf>) -> Result<(Wal, WalRecovery)> {
        let path = path.into();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file =
            OpenOptions::new().read(true).write(true).create(true).open(&path)?;
        let mut buf = Vec::new();
        file.read_to_end(&mut buf)?;

        let mut records = Vec::new();
        let mut good = 0usize; // offset just past the last intact record
        while good + HEADER <= buf.len() {
            let len = u32::from_le_bytes(buf[good..good + 4].try_into().unwrap());
            let crc = u32::from_le_bytes(buf[good + 4..good + 8].try_into().unwrap());
            let seq_bytes: [u8; 8] = buf[good + 8..good + HEADER].try_into().unwrap();
            if len > MAX_RECORD {
                break;
            }
            let start = good + HEADER;
            let Some(end) = start.checked_add(len as usize) else { break };
            if end > buf.len() {
                break;
            }
            if crc32_update(crc32(&seq_bytes), &buf[start..end]) != crc {
                break;
            }
            let Ok(payload) = std::str::from_utf8(&buf[start..end]) else { break };
            records.push(WalRecord {
                seq: u64::from_le_bytes(seq_bytes),
                payload: payload.to_string(),
            });
            good = end;
        }

        let truncated = good < buf.len();
        if truncated {
            file.set_len(good as u64)?;
            file.sync_all()?;
        }
        file.seek(SeekFrom::Start(good as u64))?;
        let wal = Wal {
            file,
            path,
            records: records.len() as u64,
            end: good as u64,
            poisoned: false,
        };
        Ok((wal, WalRecovery { records, truncated }))
    }

    /// Append one record and fsync it (log-before-ack: the caller only
    /// acknowledges the command after this returns).
    ///
    /// On an I/O failure the file is rolled back to the pre-append
    /// offset (so torn bytes can never sit *in front of* a later
    /// successful append — recovery truncates at the first bad frame,
    /// and an un-rolled-back tear would take every acknowledged record
    /// behind it down too) and the log is poisoned: every further
    /// append is refused until the process restarts (see the
    /// `poisoned` field docs for why rollback alone isn't enough).
    pub fn append(&mut self, seq: u64, payload: &str) -> Result<()> {
        if self.poisoned {
            return Err(Error::Unavailable(
                "wal poisoned by an earlier append failure; refusing to \
                 acknowledge further commits until restart"
                    .into(),
            ));
        }
        let bytes = payload.as_bytes();
        if bytes.len() > MAX_RECORD as usize {
            return Err(Error::Invalid(format!(
                "wal record of {} bytes exceeds the {MAX_RECORD}-byte cap",
                bytes.len()
            )));
        }
        let seq_bytes = seq.to_le_bytes();
        let crc = crc32_update(crc32(&seq_bytes), bytes);
        let mut frame = Vec::with_capacity(HEADER + bytes.len());
        frame.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc.to_le_bytes());
        frame.extend_from_slice(&seq_bytes);
        frame.extend_from_slice(bytes);
        let wrote = self
            .file
            .write_all(&frame)
            .and_then(|()| self.file.sync_data());
        match wrote {
            Ok(()) => {
                self.end += frame.len() as u64;
                self.records += 1;
                Ok(())
            }
            Err(e) => {
                // Best-effort rollback so a clean restart reopens a
                // clean file; poison regardless (see field docs).
                let _ = self
                    .file
                    .set_len(self.end)
                    .and_then(|()| self.file.seek(SeekFrom::Start(self.end)).map(|_| ()));
                self.poisoned = true;
                Err(e.into())
            }
        }
    }

    /// True after an append failure: the log refuses further appends
    /// until the process restarts and reopens it.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    /// Records currently in the log (since open/last reset).
    pub fn len(&self) -> u64 {
        self.records
    }

    pub fn is_empty(&self) -> bool {
        self.records == 0
    }

    /// Empty the log — called right after a snapshot makes its contents
    /// redundant. Callers must persist the snapshot *first*; the seq
    /// numbers protect the crash window in between.
    pub fn reset(&mut self) -> Result<()> {
        self.file.set_len(0)?;
        self.file.seek(SeekFrom::Start(0))?;
        self.file.sync_all()?;
        self.records = 0;
        self.end = 0;
        // `poisoned` stays sticky: truncation clears the tear, but a
        // chosen-yet-unlogged command may exist in this process — only
        // a restart (which discards it) makes the log trustworthy.
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dynostore-wal-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir.join(WAL_FILE)
    }

    fn cleanup(path: &Path) {
        if let Some(dir) = path.parent() {
            std::fs::remove_dir_all(dir).ok();
        }
    }

    #[test]
    fn append_reopen_roundtrip() {
        let path = tmp("roundtrip");
        {
            let (mut wal, rec) = Wal::open(&path).unwrap();
            assert!(rec.records.is_empty());
            assert!(!rec.truncated);
            for i in 0..10u64 {
                wal.append(i, &format!("{{\"op\":\"cmd{i}\"}}")).unwrap();
            }
            assert_eq!(wal.len(), 10);
        }
        let (wal, rec) = Wal::open(&path).unwrap();
        assert_eq!(wal.len(), 10);
        assert!(!rec.truncated);
        assert_eq!(rec.records.len(), 10);
        for (i, r) in rec.records.iter().enumerate() {
            assert_eq!(r.seq, i as u64);
            assert_eq!(r.payload, format!("{{\"op\":\"cmd{i}\"}}"));
        }
        cleanup(&path);
    }

    #[test]
    fn torn_tail_is_truncated_not_fatal() {
        let path = tmp("torn");
        {
            let (mut wal, _) = Wal::open(&path).unwrap();
            for i in 0..5u64 {
                wal.append(i, "{\"op\":\"x\"}").unwrap();
            }
        }
        // Chop the file mid-way through the last record's payload.
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 3).unwrap();
        drop(f);
        let (wal, rec) = Wal::open(&path).unwrap();
        assert!(rec.truncated);
        assert_eq!(rec.records.len(), 4, "intact prefix survives");
        assert_eq!(wal.len(), 4);
        // The file was physically truncated: a re-open is clean.
        let (_, rec2) = Wal::open(&path).unwrap();
        assert!(!rec2.truncated);
        assert_eq!(rec2.records.len(), 4);
        cleanup(&path);
    }

    #[test]
    fn corrupt_crc_truncates_from_that_record() {
        let path = tmp("crc");
        {
            let (mut wal, _) = Wal::open(&path).unwrap();
            for i in 0..5u64 {
                wal.append(i, "{\"op\":\"payload\"}").unwrap();
            }
        }
        // Flip one byte in the MIDDLE record's payload: that record and
        // everything after it must be dropped (replay cannot resync).
        let mut bytes = std::fs::read(&path).unwrap();
        let record = 16 + "{\"op\":\"payload\"}".len();
        let off = 2 * record + 16 + 3; // third record, payload byte 3
        bytes[off] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let (wal, rec) = Wal::open(&path).unwrap();
        assert!(rec.truncated);
        assert_eq!(rec.records.len(), 2);
        assert_eq!(wal.len(), 2);
        cleanup(&path);
    }

    #[test]
    fn append_after_truncated_open_continues_cleanly() {
        let path = tmp("continue");
        {
            let (mut wal, _) = Wal::open(&path).unwrap();
            for i in 0..3u64 {
                wal.append(i, "{\"a\":1}").unwrap();
            }
        }
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 1).unwrap();
        drop(f);
        {
            let (mut wal, rec) = Wal::open(&path).unwrap();
            assert_eq!(rec.records.len(), 2);
            wal.append(2, "{\"b\":2}").unwrap();
        }
        let (_, rec) = Wal::open(&path).unwrap();
        assert!(!rec.truncated);
        assert_eq!(rec.records.len(), 3);
        assert_eq!(rec.records[2].payload, "{\"b\":2}");
        cleanup(&path);
    }

    #[test]
    fn reset_empties_the_log() {
        let path = tmp("reset");
        let (mut wal, _) = Wal::open(&path).unwrap();
        wal.append(0, "{\"x\":1}").unwrap();
        wal.reset().unwrap();
        assert!(wal.is_empty());
        wal.append(1, "{\"y\":2}").unwrap();
        drop(wal);
        let (_, rec) = Wal::open(&path).unwrap();
        assert_eq!(rec.records.len(), 1);
        assert_eq!(rec.records[0].seq, 1);
        cleanup(&path);
    }

    #[test]
    fn absurd_length_header_is_corruption() {
        let path = tmp("absurd");
        {
            let (mut wal, _) = Wal::open(&path).unwrap();
            wal.append(0, "{\"ok\":true}").unwrap();
        }
        // Append a frame claiming a 1 GiB payload.
        let mut garbage = Vec::new();
        garbage.extend_from_slice(&(1u32 << 30).to_le_bytes());
        garbage.extend_from_slice(&[0u8; 12]);
        garbage.extend_from_slice(b"short");
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&garbage).unwrap();
        drop(f);
        let (wal, rec) = Wal::open(&path).unwrap();
        assert!(rec.truncated);
        assert_eq!(rec.records.len(), 1);
        assert_eq!(wal.len(), 1);
        cleanup(&path);
    }
}
