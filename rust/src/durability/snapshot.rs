//! Compacted metadata snapshots, written atomically.
//!
//! A snapshot is one JSON document holding the full
//! [`crate::metadata::MetadataStore`] state
//! ([`MetadataStore::snapshot_value`](crate::metadata::MetadataStore::snapshot_value))
//! plus the global commit count it covers. Writes go to a same-dir temp
//! file, fsync, then `rename` over the previous snapshot — so a crash
//! mid-write leaves the old snapshot intact and readable; there is
//! never a moment with zero valid snapshots on disk once one exists.

use std::fs::File;
use std::io::Write;
use std::path::Path;

use crate::json::{obj, parse, to_string, Value};
use crate::{Error, Result};

/// File name inside the data dir.
pub const SNAPSHOT_FILE: &str = "meta.snapshot";

/// Header fields of a loaded snapshot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SnapshotInfo {
    /// Total commits (global sequence) the snapshot covers: WAL records
    /// with `seq < commits` are already folded in.
    pub commits: u64,
    /// Unix seconds when the snapshot was written.
    pub taken_at: u64,
}

/// Persist `store` (a [`MetadataStore::snapshot_value`] tree) covering
/// the first `commits` commands. Atomic: temp + fsync + rename (+ a
/// best-effort directory fsync so the rename itself is durable).
pub fn save(dir: &Path, commits: u64, taken_at: u64, store: Value) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let doc = obj(vec![
        ("version", 1u64.into()),
        ("commits", commits.into()),
        ("taken_at", taken_at.into()),
        ("store", store),
    ]);
    let tmp = dir.join(format!("{SNAPSHOT_FILE}.tmp"));
    {
        let mut f = File::create(&tmp)?;
        f.write_all(to_string(&doc).as_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, dir.join(SNAPSHOT_FILE))?;
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Load the snapshot, if one exists. `Ok(None)` when the deployment has
/// never snapshotted; an unreadable/garbled file is an error (the
/// atomic write discipline means that only happens on real disk
/// damage — recovery should stop and say so rather than silently start
/// empty and orphan every chunk).
pub fn load(dir: &Path) -> Result<Option<(SnapshotInfo, Value)>> {
    let path = dir.join(SNAPSHOT_FILE);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let v = parse(&text)
        .map_err(|e| Error::Json(format!("snapshot {} unreadable: {e}", path.display())))?;
    let info =
        SnapshotInfo { commits: v.req_u64("commits")?, taken_at: v.opt_u64("taken_at", 0) };
    Ok(Some((info, v.get("store").clone())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metadata::MetadataStore;
    use std::path::PathBuf;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dynostore-snap-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = tmpdir("roundtrip");
        assert_eq!(load(&dir).unwrap(), None);
        let s = MetadataStore::new(7);
        s.create_namespace("UserA").unwrap();
        save(&dir, 3, 1234, s.snapshot_value()).unwrap();
        let (info, store_v) = load(&dir).unwrap().unwrap();
        assert_eq!(info, SnapshotInfo { commits: 3, taken_at: 1234 });
        let restored = MetadataStore::restore(&store_v).unwrap();
        assert!(restored.collection_exists("/UserA"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn overwrite_replaces_previous_snapshot() {
        let dir = tmpdir("overwrite");
        let s = MetadataStore::new(7);
        s.create_namespace("UserA").unwrap();
        save(&dir, 1, 10, s.snapshot_value()).unwrap();
        s.create_namespace("UserB").unwrap();
        save(&dir, 2, 20, s.snapshot_value()).unwrap();
        let (info, store_v) = load(&dir).unwrap().unwrap();
        assert_eq!(info.commits, 2);
        assert!(MetadataStore::restore(&store_v).unwrap().collection_exists("/UserB"));
        // No temp file left behind.
        assert!(!dir.join(format!("{SNAPSHOT_FILE}.tmp")).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn garbled_snapshot_is_a_hard_error() {
        let dir = tmpdir("garbled");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(SNAPSHOT_FILE), b"not json at all").unwrap();
        assert!(load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
