//! Keyed, incrementally-compacting local store for metadata snapshots.
//!
//! The legacy snapshot path serializes the *entire*
//! [`crate::metadata::MetadataStore`] to one JSON document every
//! `snapshot_every` commits — O(catalog) work on the commit path, which
//! walls at the ROADMAP's millions-of-objects target. This store keeps
//! the snapshot *keyed* (one entry per collection / object / chain /
//! upload, see `MetadataStore::kv_dump`) and makes snapshotting
//! incremental: each snapshot appends only the keys dirtied since the
//! last one, as a CRC-framed *segment*, and a background thread folds
//! accumulated segments into the base table.
//!
//! On-disk layout inside one shard directory:
//!
//! ```text
//! kv.base        JSON {version, seq, taken_at, entries: [[k, v], ...]}
//! kv.segments    CRC-framed segment log (reuses the WAL frame format);
//!                each record: seq watermark + JSON [[k, v|null], ...]
//! kv.segments.1  rotated segment log being folded into kv.base by the
//!                background compactor (absent in steady state)
//! ```
//!
//! Recovery folds `kv.base`, then `kv.segments.1` (if a compaction was
//! interrupted), then `kv.segments`, newest value per key winning; a
//! `null` value is a tombstone. Every segment record carries the commit
//! sequence it covers, so the folded watermark tells WAL replay where
//! to resume — exactly the crash-window discipline of the legacy
//! full-JSON snapshot, per key instead of per catalog.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::thread::JoinHandle;

use crate::json::{obj, parse, to_string, Value};
use crate::{Error, Result};

use super::sweep_tmp;
use super::wal::Wal;

/// Base table file name inside a shard dir.
pub const KV_BASE_FILE: &str = "kv.base";
/// Active segment log file name.
pub const KV_SEGMENTS_FILE: &str = "kv.segments";
/// Rotated segment log awaiting background compaction.
pub const KV_ROTATED_FILE: &str = "kv.segments.1";

/// Fold segments into the base once this many have accumulated.
const COMPACT_AFTER_SEGMENTS: u64 = 8;

/// What [`KvStore::open`] found on disk.
#[derive(Debug, Default)]
pub struct KvRecovery {
    /// Folded entries (base + rotated + active segments), key-sorted,
    /// tombstones already dropped.
    pub entries: Vec<(String, Value)>,
    /// Commit watermark the folded state covers: WAL records with
    /// `seq < watermark` are already folded in.
    pub watermark: u64,
    /// Any keyed state existed on disk (base or segments).
    pub loaded: bool,
    /// A torn segment tail was truncated during open.
    pub truncated: bool,
}

/// The open keyed store, positioned to append delta segments.
pub struct KvStore {
    dir: PathBuf,
    segments: Wal,
    compactor: Option<JoinHandle<()>>,
}

impl KvStore {
    /// Open (creating if absent) the keyed store in `dir`: sweep stale
    /// `*.tmp` leftovers, fold base + rotated + active segments, and
    /// position the segment log for appending.
    pub fn open(dir: impl Into<PathBuf>) -> Result<(KvStore, KvRecovery)> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        sweep_tmp(&dir)?;

        let mut folded: BTreeMap<String, Value> = BTreeMap::new();
        let mut watermark = 0u64;
        let mut loaded = false;
        let mut truncated = false;

        if let Some((seq, entries)) = load_base(&dir)? {
            watermark = seq;
            loaded = true;
            for (k, v) in entries {
                folded.insert(k, v);
            }
        }
        // A rotated log left behind means the compactor died mid-fold:
        // its records still overlay the (old) base correctly, and the
        // next compaction pass retires it.
        let rotated = dir.join(KV_ROTATED_FILE);
        if rotated.exists() {
            let (_, rec) = Wal::open(&rotated)?;
            truncated |= rec.truncated;
            loaded |= !rec.records.is_empty();
            for r in &rec.records {
                apply_segment(&mut folded, &r.payload)?;
                watermark = watermark.max(r.seq);
            }
        }
        let seg_path = dir.join(KV_SEGMENTS_FILE);
        loaded |= seg_path.exists();
        let (segments, rec) = Wal::open(seg_path)?;
        truncated |= rec.truncated;
        for r in &rec.records {
            apply_segment(&mut folded, &r.payload)?;
            watermark = watermark.max(r.seq);
        }

        let store = KvStore { dir, segments, compactor: None };
        let recovery = KvRecovery {
            entries: folded.into_iter().collect(),
            watermark,
            loaded,
            truncated,
        };
        Ok((store, recovery))
    }

    /// Append one delta segment covering commits up to `seq` and fsync
    /// it. `None` values are tombstones. An *empty* delta is still a
    /// valid (and necessary) segment: it advances the watermark so WAL
    /// replay after the accompanying `wal.reset()` starts at the right
    /// commit.
    pub fn append_delta(&mut self, seq: u64, delta: &[(String, Option<Value>)]) -> Result<()> {
        let entries: Vec<Value> = delta
            .iter()
            .map(|(k, v)| {
                Value::Arr(vec![k.as_str().into(), v.clone().unwrap_or(Value::Null)])
            })
            .collect();
        self.segments.append(seq, &to_string(&Value::Arr(entries)))
    }

    /// Fold accumulated segments into the base on a background thread
    /// once enough have piled up. Rotation is the only foreground work:
    /// the active segment log is renamed aside and a fresh one opened,
    /// so commits never wait on the fold itself.
    pub fn maybe_compact(&mut self) -> Result<()> {
        if let Some(h) = &self.compactor {
            if !h.is_finished() {
                return Ok(()); // previous fold still running
            }
            let _ = self.compactor.take().unwrap().join();
        }
        if self.segments.len() < COMPACT_AFTER_SEGMENTS {
            return Ok(());
        }
        let rotated = self.dir.join(KV_ROTATED_FILE);
        if !rotated.exists() {
            std::fs::rename(self.segments.path(), &rotated)?;
            let (fresh, _) = Wal::open(self.dir.join(KV_SEGMENTS_FILE))?;
            self.segments = fresh;
        }
        let dir = self.dir.clone();
        self.compactor = Some(std::thread::spawn(move || {
            if let Err(e) = compact_once(&dir) {
                crate::log_warn!("kv compaction in {} failed: {e}", dir.display());
            }
        }));
        Ok(())
    }

    /// Block until any in-flight background fold finishes (tests, and
    /// orderly shutdown via `Drop`).
    pub fn sync_compactor(&mut self) {
        if let Some(h) = self.compactor.take() {
            let _ = h.join();
        }
    }

    /// Segments appended since the last rotation.
    pub fn segment_count(&self) -> u64 {
        self.segments.len()
    }

    /// True after a failed segment append: like the WAL, the store
    /// refuses further appends until the process restarts.
    pub fn is_poisoned(&self) -> bool {
        self.segments.is_poisoned()
    }
}

impl Drop for KvStore {
    fn drop(&mut self) {
        self.sync_compactor();
    }
}

/// Write a full base table atomically (temp + fsync + rename + dir
/// fsync — the same discipline as the legacy snapshot). Used by the
/// compactor and by single-shard → sharded migration, which seeds each
/// shard's base directly.
pub fn write_base(
    dir: &Path,
    seq: u64,
    taken_at: u64,
    entries: &[(String, Value)],
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let rows: Vec<Value> = entries
        .iter()
        .map(|(k, v)| Value::Arr(vec![k.as_str().into(), v.clone()]))
        .collect();
    let doc = obj(vec![
        ("version", 1u64.into()),
        ("seq", seq.into()),
        ("taken_at", taken_at.into()),
        ("entries", Value::Arr(rows)),
    ]);
    let tmp = dir.join(format!("{KV_BASE_FILE}.tmp"));
    {
        let mut f = File::create(&tmp)?;
        f.write_all(to_string(&doc).as_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, dir.join(KV_BASE_FILE))?;
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Load the base table: `Ok(None)` when none exists yet; a garbled file
/// is a hard error (atomic writes mean that only happens on real disk
/// damage).
fn load_base(dir: &Path) -> Result<Option<(u64, Vec<(String, Value)>)>> {
    let path = dir.join(KV_BASE_FILE);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let v = parse(&text)
        .map_err(|e| Error::Json(format!("kv base {} unreadable: {e}", path.display())))?;
    let seq = v.req_u64("seq")?;
    let mut entries = Vec::new();
    for row in v.get("entries").as_arr().unwrap_or(&[]) {
        let pair = row.as_arr().ok_or_else(|| Error::Json("kv base row".into()))?;
        if pair.len() != 2 {
            return Err(Error::Json("kv base row arity".into()));
        }
        let key = pair[0].as_str().ok_or_else(|| Error::Json("kv base key".into()))?;
        entries.push((key.to_string(), pair[1].clone()));
    }
    Ok(Some((seq, entries)))
}

/// Overlay one segment payload onto the folded map (tombstones remove).
fn apply_segment(folded: &mut BTreeMap<String, Value>, payload: &str) -> Result<()> {
    let v = parse(payload).map_err(|e| Error::Json(format!("kv segment unreadable: {e}")))?;
    for row in v.as_arr().ok_or_else(|| Error::Json("kv segment shape".into()))? {
        let pair = row.as_arr().ok_or_else(|| Error::Json("kv segment row".into()))?;
        if pair.len() != 2 {
            return Err(Error::Json("kv segment row arity".into()));
        }
        let key = pair[0].as_str().ok_or_else(|| Error::Json("kv segment key".into()))?;
        match &pair[1] {
            Value::Null => {
                folded.remove(key);
            }
            v => {
                folded.insert(key.to_string(), v.clone());
            }
        }
    }
    Ok(())
}

/// One background fold: base + rotated segments → new base, then retire
/// the rotated log. Crash-safe at every step — recovery folds whatever
/// combination of files survives, in the same order.
fn compact_once(dir: &Path) -> Result<()> {
    let rotated = dir.join(KV_ROTATED_FILE);
    if !rotated.exists() {
        return Ok(());
    }
    let mut folded: BTreeMap<String, Value> = BTreeMap::new();
    let mut seq = 0u64;
    if let Some((base_seq, entries)) = load_base(dir)? {
        seq = base_seq;
        for (k, v) in entries {
            folded.insert(k, v);
        }
    }
    let (_, rec) = Wal::open(&rotated)?;
    for r in &rec.records {
        apply_segment(&mut folded, &r.payload)?;
        seq = seq.max(r.seq);
    }
    let entries: Vec<(String, Value)> = folded.into_iter().collect();
    write_base(dir, seq, crate::util::unix_secs(), &entries)?;
    std::fs::remove_file(&rotated)?;
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dynostore-kv-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn sv(s: &str) -> Value {
        Value::Str(s.into())
    }

    #[test]
    fn delta_segments_fold_on_reopen() {
        let dir = tmpdir("fold");
        {
            let (mut kv, rec) = KvStore::open(&dir).unwrap();
            assert!(!rec.loaded);
            assert_eq!(rec.watermark, 0);
            kv.append_delta(2, &[("a".into(), Some(sv("1"))), ("b".into(), Some(sv("2")))])
                .unwrap();
            kv.append_delta(5, &[("a".into(), Some(sv("3"))), ("b".into(), None)])
                .unwrap();
        }
        let (_, rec) = KvStore::open(&dir).unwrap();
        assert!(rec.loaded);
        assert_eq!(rec.watermark, 5);
        // Newest value wins; the tombstone removed "b".
        assert_eq!(rec.entries, vec![("a".to_string(), sv("3"))]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_delta_still_advances_the_watermark() {
        let dir = tmpdir("watermark");
        {
            let (mut kv, _) = KvStore::open(&dir).unwrap();
            kv.append_delta(7, &[]).unwrap();
        }
        let (_, rec) = KvStore::open(&dir).unwrap();
        assert_eq!(rec.watermark, 7);
        assert!(rec.entries.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_segment_tail_is_truncated() {
        let dir = tmpdir("torn");
        {
            let (mut kv, _) = KvStore::open(&dir).unwrap();
            kv.append_delta(1, &[("a".into(), Some(sv("1")))]).unwrap();
            kv.append_delta(2, &[("a".into(), Some(sv("2")))]).unwrap();
        }
        let path = dir.join(KV_SEGMENTS_FILE);
        let len = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 3).unwrap();
        drop(f);
        let (_, rec) = KvStore::open(&dir).unwrap();
        assert!(rec.truncated);
        assert_eq!(rec.watermark, 1, "torn second segment dropped");
        assert_eq!(rec.entries, vec![("a".to_string(), sv("1"))]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compaction_folds_into_base_and_retires_rotated_log() {
        let dir = tmpdir("compact");
        {
            let (mut kv, _) = KvStore::open(&dir).unwrap();
            for i in 0..COMPACT_AFTER_SEGMENTS {
                kv.append_delta(i + 1, &[(format!("k{i}"), Some(sv("v")))]).unwrap();
            }
            kv.maybe_compact().unwrap();
            kv.sync_compactor();
            assert_eq!(kv.segment_count(), 0, "active log rotated away");
            assert!(!dir.join(KV_ROTATED_FILE).exists(), "rotated log retired");
            // New deltas land in the fresh log and overlay the base.
            kv.append_delta(9, &[("k0".into(), None)]).unwrap();
        }
        let (seq, base) = load_base(&dir).unwrap().unwrap();
        assert_eq!(seq, COMPACT_AFTER_SEGMENTS);
        assert_eq!(base.len(), COMPACT_AFTER_SEGMENTS as usize);
        let (_, rec) = KvStore::open(&dir).unwrap();
        assert_eq!(rec.watermark, 9);
        assert_eq!(
            rec.entries.len(),
            COMPACT_AFTER_SEGMENTS as usize - 1,
            "post-compaction tombstone applies over the folded base"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn interrupted_compaction_recovers_from_rotated_log() {
        let dir = tmpdir("interrupted");
        {
            let (mut kv, _) = KvStore::open(&dir).unwrap();
            kv.append_delta(1, &[("a".into(), Some(sv("old")))]).unwrap();
            kv.append_delta(2, &[("a".into(), Some(sv("new")))]).unwrap();
        }
        // Simulate a crash right after rotation, before the fold ran.
        std::fs::rename(dir.join(KV_SEGMENTS_FILE), dir.join(KV_ROTATED_FILE)).unwrap();
        let (kv, rec) = KvStore::open(&dir).unwrap();
        assert_eq!(rec.watermark, 2);
        assert_eq!(rec.entries, vec![("a".to_string(), sv("new"))]);
        drop(kv);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_tmp_files_are_swept_at_open() {
        let dir = tmpdir("sweep");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(format!("{KV_BASE_FILE}.tmp")), b"torn").unwrap();
        std::fs::write(dir.join("meta.snapshot.tmp"), b"torn").unwrap();
        std::fs::write(dir.join("keepme.json"), b"{}").unwrap();
        let (_, _) = KvStore::open(&dir).unwrap();
        assert!(!dir.join(format!("{KV_BASE_FILE}.tmp")).exists());
        assert!(!dir.join("meta.snapshot.tmp").exists());
        assert!(dir.join("keepme.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn garbled_base_is_a_hard_error() {
        let dir = tmpdir("garbled");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(KV_BASE_FILE), b"not json").unwrap();
        assert!(KvStore::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
