//! The crash-consistency plane for the metadata services.
//!
//! The paper sells DynoStore on resilience, and PRs 1-3 made the *data*
//! plane durable (chunks survive on [`crate::container::FsBackend`]) —
//! but the metadata plane (Paxos log, object catalog, namespaces) lived
//! purely in memory: one coordinator restart orphaned every persisted
//! chunk. This module closes that gap with the classic WAL + snapshot
//! pair:
//!
//! * [`wal::Wal`] — an append-only write-ahead log of Paxos-committed
//!   [`crate::paxos::MetaCommand`] JSON payloads, length+CRC32-framed
//!   and fsync'd per commit. [`crate::paxos::ReplicatedMeta`] appends
//!   *after* the command is chosen and *before* it is applied or
//!   acknowledged (log-before-ack), so no acknowledged mutation can be
//!   lost to a crash.
//! * [`snapshot`] — periodic compacted snapshots of the full
//!   [`crate::metadata::MetadataStore`] state (written atomically:
//!   temp file → fsync → rename), after which the WAL is reset.
//!
//! Recovery (`ReplicatedMeta::durable`) is snapshot load → WAL tail
//! replay → torn-tail truncation at the first bad CRC. Each WAL record
//! carries the global commit sequence number so a crash *between*
//! snapshot write and WAL reset never double-applies the records the
//! snapshot already covers (commands are not idempotent — a replayed
//! `PutObject` would mint a new version).
//!
//! The sharded metadata plane (ISSUE 9) adds a third piece:
//!
//! * [`kvstore::KvStore`] — a keyed, incrementally-compacting snapshot
//!   store. Instead of serializing the whole catalog per snapshot, each
//!   snapshot appends only the keys dirtied since the last one, and a
//!   background thread folds segments into the base table.
//!
//! Data-dir layouts:
//!
//! ```text
//! <data_dir>/                      meta_shards = 1 (legacy, unchanged)
//!   wal.log        length+CRC-framed command log since the last snapshot
//!   meta.snapshot  JSON: {version, commits, taken_at, store: {...}}
//!
//! <data_dir>/                      meta_shards = N > 1
//!   meta.layout    JSON: {version, shards: N} — shard count pin
//!   shard-<i>/     one durability lineage per Paxos group
//!     wal.log          that shard's command log
//!     kv.base          keyed base table
//!     kv.segments      incremental delta segments since the base
//! ```

pub mod kvstore;
pub mod snapshot;
pub mod wal;

pub use kvstore::{KvRecovery, KvStore, KV_BASE_FILE, KV_SEGMENTS_FILE};
pub use snapshot::{SnapshotInfo, SNAPSHOT_FILE};
pub use wal::{Wal, WalRecord, WalRecovery, WAL_FILE};

use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// Shard-count pin written at the data-dir root for sharded layouts.
pub const LAYOUT_FILE: &str = "meta.layout";

/// The durability directory of metadata shard `i` under `data_dir`.
pub fn shard_dir(data_dir: &Path, shard: usize) -> PathBuf {
    data_dir.join(format!("shard-{shard}"))
}

/// Remove stale `*.tmp` files left by a crash between temp-write and
/// rename. Called per directory at open — the legacy layout and every
/// shard directory alike — so an interrupted snapshot or base write
/// can't accumulate dead bytes forever. Returns how many were swept.
pub fn sweep_tmp(dir: &Path) -> Result<usize> {
    let mut swept = 0;
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
        Err(e) => return Err(e.into()),
    };
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        if name.to_string_lossy().ends_with(".tmp") && entry.file_type()?.is_file() {
            std::fs::remove_file(entry.path())?;
            swept += 1;
        }
    }
    Ok(swept)
}

/// Read the shard-count pin, if one exists.
pub fn read_layout(data_dir: &Path) -> Result<Option<usize>> {
    let path = data_dir.join(LAYOUT_FILE);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let v = crate::json::parse(&text)
        .map_err(|e| Error::Json(format!("layout {} unreadable: {e}", path.display())))?;
    Ok(Some(v.req_u64("shards")? as usize))
}

/// Pin the shard count (atomic write). Once written, opening the same
/// data dir with a different `meta_shards` is a hard error — resharding
/// in place is not supported.
pub fn write_layout(data_dir: &Path, shards: usize) -> Result<()> {
    std::fs::create_dir_all(data_dir)?;
    let doc = crate::json::obj(vec![
        ("version", 1u64.into()),
        ("shards", (shards as u64).into()),
    ]);
    let tmp = data_dir.join(format!("{LAYOUT_FILE}.tmp"));
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(crate::json::to_string(&doc).as_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, data_dir.join(LAYOUT_FILE))?;
    if let Ok(d) = std::fs::File::open(data_dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Snapshot cadence when the deployment doesn't configure one: compact
/// the WAL every 64 committed commands.
pub const DEFAULT_SNAPSHOT_EVERY: u64 = 64;

/// Where and how often the metadata plane persists.
#[derive(Debug, Clone)]
pub struct DurabilityOpts {
    /// Directory holding `wal.log` and `meta.snapshot` (created if
    /// missing).
    pub dir: PathBuf,
    /// Take a compacted snapshot (and reset the WAL) every N commits.
    pub snapshot_every: u64,
}

impl DurabilityOpts {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        DurabilityOpts { dir: dir.into(), snapshot_every: DEFAULT_SNAPSHOT_EVERY }
    }

    pub fn snapshot_every(mut self, n: u64) -> Self {
        self.snapshot_every = n.max(1);
        self
    }
}

/// What recovery found on disk — surfaced through the coordinator and
/// the gateway's `/health`.
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    /// A snapshot file was loaded.
    pub snapshot_loaded: bool,
    /// Commits covered by the loaded snapshot (0 without one).
    pub snapshot_commits: u64,
    /// WAL records found intact on disk.
    pub wal_records: u64,
    /// WAL records actually replayed (records the snapshot already
    /// covered are skipped).
    pub wal_replayed: u64,
    /// A torn/corrupt WAL tail was truncated during open.
    pub wal_truncated: bool,
}

impl RecoveryReport {
    /// True when any prior state was recovered (the `/health`
    /// `recovered` flag).
    pub fn recovered(&self) -> bool {
        self.snapshot_loaded || self.wal_records > 0
    }

    /// Fold another shard's report into this one (the aggregate the
    /// legacy single-report surfaces keep exposing).
    pub fn absorb(&mut self, other: &RecoveryReport) {
        self.snapshot_loaded |= other.snapshot_loaded;
        self.snapshot_commits += other.snapshot_commits;
        self.wal_records += other.wal_records;
        self.wal_replayed += other.wal_replayed;
        self.wal_truncated |= other.wal_truncated;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dynostore-dur-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn sweep_tmp_removes_only_stale_temp_files() {
        let dir = tmpdir("sweep");
        assert_eq!(sweep_tmp(&dir).unwrap(), 0, "missing dir sweeps nothing");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("meta.snapshot.tmp"), b"torn half-write").unwrap();
        std::fs::write(dir.join("kv.base.tmp"), b"torn half-write").unwrap();
        std::fs::write(dir.join("meta.snapshot"), b"{}").unwrap();
        std::fs::write(dir.join("wal.log"), b"").unwrap();
        assert_eq!(sweep_tmp(&dir).unwrap(), 2);
        assert!(dir.join("meta.snapshot").exists());
        assert!(dir.join("wal.log").exists());
        assert!(!dir.join("meta.snapshot.tmp").exists());
        assert!(!dir.join("kv.base.tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn layout_pin_roundtrip() {
        let dir = tmpdir("layout");
        assert_eq!(read_layout(&dir).unwrap(), None);
        write_layout(&dir, 4).unwrap();
        assert_eq!(read_layout(&dir).unwrap(), Some(4));
        assert!(!dir.join(format!("{LAYOUT_FILE}.tmp")).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_dirs_are_stable_names() {
        let root = PathBuf::from("/data");
        assert_eq!(shard_dir(&root, 0), PathBuf::from("/data/shard-0"));
        assert_eq!(shard_dir(&root, 3), PathBuf::from("/data/shard-3"));
    }

    #[test]
    fn recovery_report_aggregates_across_shards() {
        let mut agg = RecoveryReport::default();
        assert!(!agg.recovered());
        agg.absorb(&RecoveryReport {
            snapshot_loaded: true,
            snapshot_commits: 5,
            wal_records: 2,
            wal_replayed: 2,
            wal_truncated: false,
        });
        agg.absorb(&RecoveryReport { wal_truncated: true, ..Default::default() });
        assert!(agg.recovered());
        assert_eq!(agg.snapshot_commits, 5);
        assert_eq!(agg.wal_records, 2);
        assert!(agg.wal_truncated);
    }
}
