//! The crash-consistency plane for the metadata services.
//!
//! The paper sells DynoStore on resilience, and PRs 1-3 made the *data*
//! plane durable (chunks survive on [`crate::container::FsBackend`]) —
//! but the metadata plane (Paxos log, object catalog, namespaces) lived
//! purely in memory: one coordinator restart orphaned every persisted
//! chunk. This module closes that gap with the classic WAL + snapshot
//! pair:
//!
//! * [`wal::Wal`] — an append-only write-ahead log of Paxos-committed
//!   [`crate::paxos::MetaCommand`] JSON payloads, length+CRC32-framed
//!   and fsync'd per commit. [`crate::paxos::ReplicatedMeta`] appends
//!   *after* the command is chosen and *before* it is applied or
//!   acknowledged (log-before-ack), so no acknowledged mutation can be
//!   lost to a crash.
//! * [`snapshot`] — periodic compacted snapshots of the full
//!   [`crate::metadata::MetadataStore`] state (written atomically:
//!   temp file → fsync → rename), after which the WAL is reset.
//!
//! Recovery (`ReplicatedMeta::durable`) is snapshot load → WAL tail
//! replay → torn-tail truncation at the first bad CRC. Each WAL record
//! carries the global commit sequence number so a crash *between*
//! snapshot write and WAL reset never double-applies the records the
//! snapshot already covers (commands are not idempotent — a replayed
//! `PutObject` would mint a new version).
//!
//! Data-dir layout:
//!
//! ```text
//! <data_dir>/
//!   wal.log        length+CRC-framed command log since the last snapshot
//!   meta.snapshot  JSON: {version, commits, taken_at, store: {...}}
//! ```

pub mod snapshot;
pub mod wal;

pub use snapshot::{SnapshotInfo, SNAPSHOT_FILE};
pub use wal::{Wal, WalRecord, WalRecovery, WAL_FILE};

use std::path::PathBuf;

/// Snapshot cadence when the deployment doesn't configure one: compact
/// the WAL every 64 committed commands.
pub const DEFAULT_SNAPSHOT_EVERY: u64 = 64;

/// Where and how often the metadata plane persists.
#[derive(Debug, Clone)]
pub struct DurabilityOpts {
    /// Directory holding `wal.log` and `meta.snapshot` (created if
    /// missing).
    pub dir: PathBuf,
    /// Take a compacted snapshot (and reset the WAL) every N commits.
    pub snapshot_every: u64,
}

impl DurabilityOpts {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        DurabilityOpts { dir: dir.into(), snapshot_every: DEFAULT_SNAPSHOT_EVERY }
    }

    pub fn snapshot_every(mut self, n: u64) -> Self {
        self.snapshot_every = n.max(1);
        self
    }
}

/// What recovery found on disk — surfaced through the coordinator and
/// the gateway's `/health`.
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    /// A snapshot file was loaded.
    pub snapshot_loaded: bool,
    /// Commits covered by the loaded snapshot (0 without one).
    pub snapshot_commits: u64,
    /// WAL records found intact on disk.
    pub wal_records: u64,
    /// WAL records actually replayed (records the snapshot already
    /// covered are skipped).
    pub wal_replayed: u64,
    /// A torn/corrupt WAL tail was truncated during open.
    pub wal_truncated: bool,
}

impl RecoveryReport {
    /// True when any prior state was recovered (the `/health`
    /// `recovered` flag).
    pub fn recovered(&self) -> bool {
        self.snapshot_loaded || self.wal_records > 0
    }
}
