//! Fixed-size worker pool (the "scale-in via multi-threading" of paper
//! §III-C) used by the HTTP server, the FaaS executor, and the
//! column-sharded erasure backend
//! ([`crate::erasure::ParallelBackend`]).
//!
//! Workers survive panicking jobs (each job runs under `catch_unwind`),
//! and both gather APIs report panicked jobs as [`Error::Pool`] instead
//! of poisoning the caller with a misleading unwrap.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::{Error, Result};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A classic shared-queue thread pool.
///
/// The submission side sits behind a `Mutex` so the pool is `Sync`
/// regardless of whether this toolchain's `mpsc::Sender` is (it only
/// became `Sync` in newer std); submission cost is a lock + channel
/// push, negligible next to any job worth pooling.
pub struct ThreadPool {
    tx: Option<Mutex<Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
    /// Jobs enqueued but not yet picked up by a worker — the queue-depth
    /// gauge admission control and `/health` read.
    pending: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx: Arc<Mutex<Receiver<Job>>> = Arc::new(Mutex::new(rx));
        let pending = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            // A panicking job must not kill the worker:
                            // swallow the unwind, keep serving. Gather
                            // APIs detect the missing result and surface
                            // Error::Pool to the submitter.
                            Ok(job) => {
                                pending.fetch_sub(1, Ordering::Relaxed);
                                let _ = catch_unwind(AssertUnwindSafe(move || job()));
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(Mutex::new(tx)), workers, pending }
    }

    /// Enqueue a job; never blocks.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.pending.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("pool is live")
            .lock()
            .unwrap()
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Jobs waiting in the queue (submitted, not yet dequeued by a
    /// worker). A sustained non-zero value means the pool is saturated.
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::Relaxed)
    }

    /// Submit one job and get a [`JobHandle`] for its result — the
    /// pipelining primitive of the streaming data plane: dispatch
    /// stripe `p`'s chunk uploads while the caller reads stripe `p+1`
    /// off the socket, then `join()` before dispatching the next. A
    /// panicked job surfaces as [`Error::Pool`] at `join`, not a hang:
    /// the result sender is dropped by the unwind and the receiver sees
    /// a closed channel.
    pub fn submit<T: Send + 'static>(
        &self,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> JobHandle<T> {
        let (tx, rx) = channel::<T>();
        self.execute(move || {
            let _ = tx.send(f());
        });
        JobHandle { rx }
    }

    /// Map `f` over `0..n` with the pool's parallelism; returns results
    /// in index order. A panicking job no longer poisons the gather with
    /// an unrelated unwrap — it yields `Error::Pool` naming how many
    /// jobs died, and the pool remains usable.
    pub fn scatter_gather<T: Send + 'static>(
        &self,
        n: usize,
        f: impl Fn(usize) -> T + Send + Sync + 'static,
    ) -> Result<Vec<T>> {
        let f = Arc::new(f);
        let (tx, rx) = channel::<(usize, T)>();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let out = f(i);
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        // The channel closes once every job's sender clone is gone —
        // i.e. after every job finished or unwound.
        for (i, v) in rx {
            results[i] = Some(v);
        }
        let missing = results.iter().filter(|r| r.is_none()).count();
        if missing > 0 {
            return Err(Error::Pool(format!("{missing} of {n} jobs panicked")));
        }
        Ok(results.into_iter().map(|v| v.expect("checked above")).collect())
    }

    /// Run borrowing jobs on the pool, blocking until all complete.
    /// This is the generalization that lets the erasure data plane shard
    /// a borrowed stripe across workers without `'static` gymnastics.
    ///
    /// Returns `Error::Pool` if any job panicked.
    pub fn run_scoped<'a>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) -> Result<()> {
        let n = jobs.len();
        let (tx, rx) = channel::<()>();
        for job in jobs {
            let tx = tx.clone();
            // SAFETY: the transmute only erases the borrow lifetime 'a.
            // We block below until the completion channel closes, which
            // happens only after every job's `tx` clone is dropped —
            // i.e. after every job has returned or finished unwinding.
            // No job (or anything it borrows) outlives this call.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'a>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            self.execute(move || {
                job();
                let _ = tx.send(());
            });
        }
        drop(tx);
        let completed = rx.iter().count();
        if completed != n {
            return Err(Error::Pool(format!(
                "{} of {n} scoped jobs panicked",
                n - completed
            )));
        }
        Ok(())
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Handle to a single pooled job submitted via [`ThreadPool::submit`].
pub struct JobHandle<T> {
    rx: Receiver<T>,
}

impl<T> JobHandle<T> {
    /// Block until the job completes and take its result.
    pub fn join(self) -> Result<T> {
        self.rx.recv().map_err(|_| Error::Pool("submitted job panicked".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scatter_gather_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.scatter_gather(50, |i| i * i).unwrap();
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn pending_gauge_tracks_queue_depth() {
        let pool = ThreadPool::new(1);
        let gate = Arc::new(Mutex::new(()));
        let guard = gate.lock().unwrap();
        // First job occupies the single worker (blocked on the gate)…
        let g = Arc::clone(&gate);
        pool.execute(move || {
            drop(g.lock().unwrap());
        });
        // …wait until the worker has dequeued it.
        let t0 = std::time::Instant::now();
        while pool.pending() != 0 {
            assert!(t0.elapsed() < std::time::Duration::from_secs(5), "worker never dequeued");
            std::thread::yield_now();
        }
        // Three more jobs can only queue.
        for _ in 0..3 {
            pool.execute(|| {});
        }
        assert_eq!(pool.pending(), 3, "queued jobs visible in the gauge");
        drop(guard); // release the worker
        drop(pool); // join: everything ran
    }

    #[test]
    fn zero_size_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.scatter_gather(3, |i| i).unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn panicking_job_surfaces_pool_error_not_poison() {
        let pool = ThreadPool::new(2);
        let res = pool.scatter_gather(5, |i| {
            if i == 2 {
                panic!("job 2 exploded");
            }
            i * 10
        });
        match res {
            Err(Error::Pool(msg)) => assert!(msg.contains("1 of 5"), "{msg}"),
            other => panic!("expected Error::Pool, got {other:?}"),
        }
        // Workers survived the unwind: the pool still does useful work.
        assert_eq!(pool.scatter_gather(4, |i| i + 1).unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn run_scoped_borrows_and_joins() {
        let pool = ThreadPool::new(4);
        let mut buf = vec![0u8; 64];
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            let mut rest = buf.as_mut_slice();
            for chunk_id in 0..4u8 {
                // mem::take detaches the slice so head keeps the full
                // borrow lifetime while rest is reassigned.
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(16);
                rest = tail;
                jobs.push(Box::new(move || {
                    for b in head {
                        *b = chunk_id + 1;
                    }
                }));
            }
            pool.run_scoped(jobs).unwrap();
            assert!(rest.is_empty());
        }
        for (i, &b) in buf.iter().enumerate() {
            assert_eq!(b as usize, i / 16 + 1);
        }
    }

    #[test]
    fn submit_returns_result_and_reports_panics() {
        let pool = ThreadPool::new(2);
        let h = pool.submit(|| 6 * 7);
        assert_eq!(h.join().unwrap(), 42);
        // Overlap: two in-flight jobs complete independently.
        let a = pool.submit(|| "a".to_string());
        let b = pool.submit(|| "b".to_string());
        assert_eq!(b.join().unwrap(), "b");
        assert_eq!(a.join().unwrap(), "a");
        // A panicking job yields Error::Pool at join, not a hang.
        let boom = pool.submit(|| -> usize { panic!("submitted boom") });
        assert!(matches!(boom.join(), Err(Error::Pool(_))));
        // The pool survives.
        assert_eq!(pool.submit(|| 1).join().unwrap(), 1);
    }

    #[test]
    fn run_scoped_reports_panics() {
        let pool = ThreadPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            vec![Box::new(|| {}), Box::new(|| panic!("scoped boom"))];
        assert!(matches!(pool.run_scoped(jobs), Err(Error::Pool(_))));
        // And the pool is still alive.
        assert_eq!(pool.scatter_gather(2, |i| i).unwrap(), vec![0, 1]);
    }
}
