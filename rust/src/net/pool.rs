//! Fixed-size worker pool (the "scale-in via multi-threading" of paper
//! §III-C) used by the HTTP server and the FaaS executor.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A classic shared-queue thread pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx: Arc<Mutex<Receiver<Job>>> = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Enqueue a job; never blocks.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool is live")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Map `f` over `0..n` with the pool's parallelism; returns results
    /// in index order (panics in jobs are surfaced as poisoned results).
    pub fn scatter_gather<T: Send + 'static>(
        &self,
        n: usize,
        f: impl Fn(usize) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let f = Arc::new(f);
        let (tx, rx) = channel::<(usize, T)>();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let out = f(i);
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            results[i] = Some(v);
        }
        results.into_iter().map(|v| v.expect("job completed")).collect()
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scatter_gather_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.scatter_gather(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn zero_size_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.scatter_gather(3, |i| i), vec![0, 1, 2]);
    }
}
