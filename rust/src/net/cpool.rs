//! Global keep-alive connection pool for [`crate::net::HttpClient`].
//!
//! One process-wide pool, keyed by host string (`"host:port"` as the
//! client addresses it), holding bounded per-host stacks of idle
//! keep-alive connections. The coordinator's chunk fan-out builds many
//! short-lived `HttpClient`s for the same agent endpoints; a global
//! pool (rather than per-client state) is what lets those reuse each
//! other's connections.
//!
//! Staleness is handled twice, because a pooled connection can die at
//! any moment (server restart, keep-alive idle eviction on the far
//! side):
//!
//! 1. **Checkout probe**: a non-blocking 1-byte peek. A healthy idle
//!    keep-alive connection has nothing to read — `WouldBlock`. An EOF
//!    or stray byte (a late error response, protocol garbage) means the
//!    connection is dead or desynchronized; it is dropped and the next
//!    candidate tried.
//! 2. **Retry-once** in the client: if a *reused* connection then still
//!    fails before yielding a single response byte, the request is
//!    retried on a fresh connection (RFC 7230 §6.3.1).
//!
//! Idle connections also age out: ones parked longer than the idle TTL
//! are dropped at checkout time. The TTL (30 s) deliberately undercuts
//! the server's default keep-alive idle window (60 s) so the client
//! rarely picks up a connection the server is about to reap.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::net::http::ConnReader;

/// Default cap on idle pooled connections kept per host.
pub const DEFAULT_POOL_PER_HOST: usize = 8;

const DEFAULT_IDLE_TTL: Duration = Duration::from_secs(30);

/// Client-side pool counters, exported through the gateway's `/health`.
#[derive(Debug, Default)]
pub struct PoolStats {
    /// Requests served on a reused pooled connection.
    pub reuses: AtomicU64,
    /// Fresh TCP connects (pool misses + unpooled requests).
    pub connects: AtomicU64,
    /// Requests retried on a fresh connection after a reused one proved
    /// stale (died before yielding a response byte).
    pub stale_retries: AtomicU64,
    /// Pooled connections dropped by TTL expiry or the checkout probe.
    pub evicted: AtomicU64,
}

impl PoolStats {
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("reuses", self.reuses.load(Ordering::Relaxed)),
            ("connects", self.connects.load(Ordering::Relaxed)),
            ("stale_retries", self.stale_retries.load(Ordering::Relaxed)),
            ("evicted", self.evicted.load(Ordering::Relaxed)),
        ]
    }
}

struct Pooled {
    conn: ConnReader,
    since: Instant,
}

/// Bounded per-host pool of idle keep-alive connections.
pub struct ClientPool {
    conns: Mutex<HashMap<String, VecDeque<Pooled>>>,
    per_host: AtomicUsize,
    idle_ttl_ms: AtomicU64,
    pub stats: PoolStats,
}

impl ClientPool {
    fn new() -> ClientPool {
        ClientPool {
            conns: Mutex::new(HashMap::new()),
            per_host: AtomicUsize::new(DEFAULT_POOL_PER_HOST),
            idle_ttl_ms: AtomicU64::new(DEFAULT_IDLE_TTL.as_millis() as u64),
            stats: PoolStats::default(),
        }
    }

    /// Set the per-host idle-connection cap; `0` disables pooling
    /// entirely (every request connects fresh with `connection:
    /// close`). Applies process-wide.
    pub fn configure(&self, per_host: usize) {
        self.per_host.store(per_host, Ordering::Relaxed);
        if per_host == 0 {
            self.conns.lock().unwrap().clear();
        }
    }

    /// Whether pooling is enabled at all.
    pub fn enabled(&self) -> bool {
        self.per_host.load(Ordering::Relaxed) > 0
    }

    /// An idle connection for `host`, health-probed, or `None` (pool
    /// empty / everything stale).
    pub fn checkout(&self, host: &str) -> Option<ConnReader> {
        let ttl = Duration::from_millis(self.idle_ttl_ms.load(Ordering::Relaxed));
        let mut map = self.conns.lock().unwrap();
        let queue = map.get_mut(host)?;
        while let Some(p) = queue.pop_back() {
            if p.since.elapsed() > ttl {
                self.stats.evicted.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            if probe_healthy(&p.conn) {
                if queue.is_empty() {
                    map.remove(host);
                }
                return Some(p.conn);
            }
            self.stats.evicted.fetch_add(1, Ordering::Relaxed);
        }
        map.remove(host);
        None
    }

    /// Park a reusable connection for `host`; dropped when the host's
    /// stack is at capacity (the TCP close tells the server).
    pub fn checkin(&self, host: &str, conn: ConnReader) {
        let cap = self.per_host.load(Ordering::Relaxed);
        if cap == 0 {
            return;
        }
        let mut map = self.conns.lock().unwrap();
        let queue = map.entry(host.to_string()).or_default();
        if queue.len() >= cap {
            self.stats.evicted.fetch_add(1, Ordering::Relaxed);
            return;
        }
        queue.push_back(Pooled { conn, since: Instant::now() });
    }

    /// Drop every pooled connection to `host` — the peer is known dead
    /// (circuit breaker opened, agent decommissioned), so parked
    /// connections to it are guaranteed garbage.
    pub fn invalidate(&self, host: &str) {
        self.conns.lock().unwrap().remove(host);
    }

    /// Currently parked idle connections across all hosts.
    pub fn idle_count(&self) -> usize {
        self.conns.lock().unwrap().values().map(|q| q.len()).sum()
    }
}

/// Non-blocking 1-byte peek: a healthy idle keep-alive connection has
/// nothing to send us, so `WouldBlock` is the healthy answer. `Ok(0)`
/// is EOF (server closed), `Ok(1)` is protocol garbage (an unsolicited
/// byte) — both mean the connection must not carry another request.
fn probe_healthy(conn: &ConnReader) -> bool {
    let stream = conn.stream();
    if stream.set_nonblocking(true).is_err() {
        return false;
    }
    let mut byte = [0u8; 1];
    let healthy = matches!(
        stream.peek(&mut byte),
        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock
    );
    healthy && stream.set_nonblocking(false).is_ok()
}

/// The process-wide pool.
pub fn global() -> &'static ClientPool {
    static POOL: OnceLock<ClientPool> = OnceLock::new();
    POOL.get_or_init(ClientPool::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    fn pair() -> (ConnReader, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        (ConnReader::new(client), server_side)
    }

    #[test]
    fn checkout_returns_healthy_checkin() {
        let pool = ClientPool::new();
        let (conn, _server) = pair();
        pool.checkin("h:1", conn);
        assert_eq!(pool.idle_count(), 1);
        assert!(pool.checkout("h:1").is_some());
        assert_eq!(pool.idle_count(), 0);
        assert!(pool.checkout("h:1").is_none(), "pool is empty after checkout");
    }

    #[test]
    fn probe_rejects_closed_and_garbage_connections() {
        let pool = ClientPool::new();
        // Server closed while parked: probe sees EOF.
        let (conn, server) = pair();
        pool.checkin("h:1", conn);
        drop(server);
        // Give the FIN a moment to land.
        std::thread::sleep(Duration::from_millis(50));
        assert!(pool.checkout("h:1").is_none(), "closed connection must not check out");
        assert!(pool.stats.evicted.load(Ordering::Relaxed) >= 1);

        // Unsolicited bytes while parked: desynchronized, rejected.
        let (conn, mut server) = pair();
        pool.checkin("h:2", conn);
        server.write_all(b"X").unwrap();
        std::thread::sleep(Duration::from_millis(50));
        assert!(pool.checkout("h:2").is_none(), "garbage connection must not check out");
    }

    #[test]
    fn per_host_cap_bounds_parked_connections() {
        let pool = ClientPool::new();
        pool.configure(2);
        let mut keep = Vec::new();
        for _ in 0..4 {
            let (conn, server) = pair();
            keep.push(server);
            pool.checkin("h:1", conn);
        }
        assert_eq!(pool.idle_count(), 2, "per-host cap enforced");
        // The two overflow connections were closed client-side: the
        // server halves read EOF.
        let mut eofs = 0;
        for s in &mut keep {
            s.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
            let mut b = [0u8; 1];
            if matches!(s.read(&mut b), Ok(0)) {
                eofs += 1;
            }
        }
        assert_eq!(eofs, 2, "overflow connections are actually closed");
    }

    #[test]
    fn ttl_evicts_aged_connections() {
        let pool = ClientPool::new();
        pool.idle_ttl_ms.store(10, Ordering::Relaxed);
        let (conn, _server) = pair();
        pool.checkin("h:1", conn);
        std::thread::sleep(Duration::from_millis(50));
        assert!(pool.checkout("h:1").is_none(), "aged connection evicted");
        assert!(pool.stats.evicted.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn invalidate_clears_host() {
        let pool = ClientPool::new();
        let (conn, _s1) = pair();
        let (conn2, _s2) = pair();
        pool.checkin("h:1", conn);
        pool.checkin("h:2", conn2);
        pool.invalidate("h:1");
        assert!(pool.checkout("h:1").is_none());
        assert!(pool.checkout("h:2").is_some(), "other hosts untouched");
    }

    #[test]
    fn configure_zero_disables_and_clears() {
        let pool = ClientPool::new();
        let (conn, _server) = pair();
        pool.checkin("h:1", conn);
        pool.configure(0);
        assert!(!pool.enabled());
        assert_eq!(pool.idle_count(), 0, "disabling drops parked connections");
        let (conn, _server) = pair();
        pool.checkin("h:1", conn);
        assert_eq!(pool.idle_count(), 0, "checkin is a no-op while disabled");
    }
}
