//! Readiness-based epoll reactor: the default server engine on Linux.
//!
//! One event-loop thread owns every socket. Connections are accepted
//! non-blocking, parked, and their request heads buffered incrementally
//! off readiness events; only when a complete head (`\r\n\r\n`) has
//! arrived is the connection handed — head bytes included — to the
//! worker pool, which runs the exact same blocking `serve_one` path the
//! threaded engine uses (with the socket switched back to blocking mode
//! and the slowloris timeouts armed). After a keep-alive response the
//! worker hands the connection *back* to the reactor through a channel
//! + waker pipe, and it parks again waiting for the next request.
//!
//! The economics this buys: an idle keep-alive connection costs one
//! file descriptor and a small parked buffer — not a thread. Thread
//! count stays O(workers) no matter how many clients stay connected.
//!
//! Admission control happens at the two points where load enters:
//!
//! - **accept**: beyond `max_connections` open connections, the new
//!   socket is answered `503 + Retry-After` and closed.
//! - **dispatch**: beyond `max_inflight` requests already in the worker
//!   pool, a complete request is answered `429 + Retry-After` and the
//!   connection closed (request-body bytes may already be in flight
//!   behind the head, so shedding on a kept-alive connection would
//!   desynchronize framing).
//!
//! epoll is reached through raw FFI (`epoll_create1`/`epoll_ctl`/
//! `epoll_wait`) to keep the zero-dependency build — no `libc` crate.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::net::http::{
    failure_response, serve_one, shed_connection, AnyHandler, ConnReader, HttpResponse, NetStats,
    ParseFailure, Served, ServerLimits, ServerOptions,
};
use crate::net::ThreadPool;
use crate::{Error, Result};

const EPOLL_CLOEXEC: i32 = 0o2000000;
const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLLIN: u32 = 0x001;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;
const EPOLLRDHUP: u32 = 0x2000;

/// The kernel's `struct epoll_event`. Packed on x86-64 (the kernel ABI
/// packs it there); natural layout elsewhere.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
struct EpollEvent {
    /// Written by the kernel on `epoll_wait`; this reactor re-polls the
    /// socket with `read()` instead of inspecting readiness flags, so
    /// the field is only ever written on our side.
    #[allow(dead_code)]
    events: u32,
    data: u64,
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    fn close(fd: i32) -> i32;
}

/// Thin RAII wrapper over an epoll instance.
struct Epoll {
    fd: i32,
}

impl Epoll {
    fn create() -> Result<Epoll> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(Error::Net(format!("epoll_create1: {}", io::Error::last_os_error())));
        }
        Ok(Epoll { fd })
    }

    fn add(&self, fd: RawFd, token: u64) -> io::Result<()> {
        let mut ev =
            EpollEvent { events: EPOLLIN | EPOLLRDHUP | EPOLLERR | EPOLLHUP, data: token };
        let rc = unsafe { epoll_ctl(self.fd, EPOLL_CTL_ADD, fd, &mut ev) };
        if rc < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(())
        }
    }

    fn del(&self, fd: RawFd) {
        let mut ev = EpollEvent { events: 0, data: 0 };
        let _ = unsafe { epoll_ctl(self.fd, EPOLL_CTL_DEL, fd, &mut ev) };
    }

    /// Wait for events; EINTR retries, any other failure degrades to an
    /// empty tick (with a small sleep so a persistent error cannot spin
    /// the loop hot).
    fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> usize {
        loop {
            let rc = unsafe {
                epoll_wait(self.fd, events.as_mut_ptr(), events.len() as i32, timeout_ms)
            };
            if rc >= 0 {
                return rc as usize;
            }
            if io::Error::last_os_error().kind() == io::ErrorKind::Interrupted {
                continue;
            }
            std::thread::sleep(Duration::from_millis(5));
            return 0;
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            close(self.fd);
        }
    }
}

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// Largest request head (request line + headers) the reactor buffers
/// before answering `431` — a head is metadata, not a body.
const MAX_HEAD: usize = 64 * 1024;

/// Event-loop tick while connections are parked: bounds how late the
/// idle/timeout sweep can run after a deadline passes.
const TICK_MS: i32 = 25;
/// Relaxed tick while no connection is open.
const IDLE_TICK_MS: i32 = 250;

/// A connection parked in the reactor between (or before) requests.
struct Parked {
    stream: TcpStream,
    /// Bytes read so far toward the next request head (may already
    /// contain body bytes past the head; they ride along as the
    /// dispatch prefix).
    buf: Vec<u8>,
    /// Last progress: accept/return time, refreshed on every readable
    /// chunk — so timeouts measure stall, matching the per-read socket
    /// timeouts of the threaded engine.
    since: Instant,
    /// Whether this connection already served at least one request.
    reused: bool,
}

/// A keep-alive connection a worker is handing back, with any
/// read-ahead (pipelined) bytes it pulled past the request it served.
struct Returned {
    stream: TcpStream,
    leftover: Vec<u8>,
}

/// What a readiness event on a parked connection amounts to.
enum Action {
    Wait,
    Dispatch,
    Close,
    TooBig,
}

/// Decrements the in-flight gauge when the worker job ends, however it
/// ends — a panicking handler must not leak admission budget.
struct InflightGuard(Arc<AtomicU64>);

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Decrements `conns_open` on drop unless disarmed — disarmed exactly
/// when the connection was handed back to the reactor, which then owns
/// the count.
struct OpenGuard {
    stats: Arc<NetStats>,
    armed: bool,
}

impl Drop for OpenGuard {
    fn drop(&mut self) {
        if self.armed {
            self.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// Spawn the reactor thread. Returns the join handle plus a waker the
/// server handle uses to unblock `epoll_wait` for shutdown.
pub(crate) fn spawn(
    listener: TcpListener,
    workers: usize,
    handler: AnyHandler,
    limits: ServerLimits,
    opts: &ServerOptions,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
) -> Result<(JoinHandle<()>, Box<dyn Fn() + Send + Sync>)> {
    listener.set_nonblocking(true)?;
    let epoll = Epoll::create()?;
    let (waker_tx, waker_rx) = UnixStream::pair()?;
    waker_tx.set_nonblocking(true)?;
    waker_rx.set_nonblocking(true)?;
    epoll.add(listener.as_raw_fd(), TOKEN_LISTENER)?;
    epoll.add(waker_rx.as_raw_fd(), TOKEN_WAKER)?;
    let waker_tx = Arc::new(waker_tx);
    let (return_tx, return_rx) = channel();
    let mut reactor = Reactor {
        epoll,
        listener,
        waker_rx,
        waker_tx: Arc::clone(&waker_tx),
        conns: HashMap::new(),
        next_token: FIRST_CONN_TOKEN,
        pool: Some(ThreadPool::new(workers)),
        handler,
        limits,
        max_connections: opts.max_connections,
        max_inflight: opts.max_inflight,
        keepalive_idle: opts.keepalive_idle,
        inflight: Arc::new(AtomicU64::new(0)),
        stats,
        stop,
        return_tx,
        return_rx,
    };
    let thread = std::thread::Builder::new()
        .name("http-reactor".into())
        .spawn(move || reactor.run())
        .map_err(|e| Error::Net(format!("spawn reactor thread: {e}")))?;
    let wake: Box<dyn Fn() + Send + Sync> = Box::new(move || {
        let _ = (&*waker_tx).write_all(&[1]);
    });
    Ok((thread, wake))
}

struct Reactor {
    epoll: Epoll,
    listener: TcpListener,
    waker_rx: UnixStream,
    waker_tx: Arc<UnixStream>,
    conns: HashMap<u64, Parked>,
    next_token: u64,
    pool: Option<ThreadPool>,
    handler: AnyHandler,
    limits: ServerLimits,
    max_connections: usize,
    max_inflight: usize,
    keepalive_idle: Duration,
    inflight: Arc<AtomicU64>,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
    return_tx: Sender<Returned>,
    return_rx: Receiver<Returned>,
}

impl Reactor {
    fn run(&mut self) {
        let mut events = [EpollEvent { events: 0, data: 0 }; 64];
        loop {
            let timeout = if self.conns.is_empty() { IDLE_TICK_MS } else { TICK_MS };
            let n = self.epoll.wait(&mut events, timeout);
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let t0 = Instant::now();
            for ev in events.iter().take(n) {
                let token = ev.data;
                match token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKER => self.drain_waker(),
                    token => self.conn_ready(token),
                }
            }
            self.collect_returned();
            self.sweep();
            // Lag gauge: how long this iteration spent processing — the
            // time a freshly-ready socket would have waited on the loop.
            self.stats.reactor_lag_us.store(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        }
        self.shutdown();
    }

    /// Accept every pending connection (level-triggered listener).
    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    self.stats.conns_accepted.fetch_add(1, Ordering::Relaxed);
                    if self.stats.conns_open.load(Ordering::Relaxed)
                        >= self.max_connections as u64
                    {
                        self.stats.admission_shed.fetch_add(1, Ordering::Relaxed);
                        shed_connection(stream, 503, "server at connection capacity");
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    self.stats.conns_open.fetch_add(1, Ordering::Relaxed);
                    self.park(stream, Vec::new(), false);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    /// Register a connection with the event loop. On registration
    /// failure the connection is dropped (and the open gauge released).
    fn park(&mut self, stream: TcpStream, buf: Vec<u8>, reused: bool) {
        let token = self.next_token;
        self.next_token += 1;
        if self.epoll.add(stream.as_raw_fd(), token).is_err() {
            self.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        self.conns.insert(token, Parked { stream, buf, since: Instant::now(), reused });
        // A pipelined client may have sent the next request's head
        // along with the previous body: dispatch immediately, don't
        // wait for more bytes that may never come.
        if head_complete(&self.conns[&token].buf) {
            self.dispatch(token);
        }
    }

    /// A parked connection became readable: pull bytes until the head
    /// completes or the socket runs dry.
    fn conn_ready(&mut self, token: u64) {
        let Some(parked) = self.conns.get_mut(&token) else {
            // A stale event for a token already dispatched or closed.
            return;
        };
        let mut chunk = [0u8; 8192];
        let action = loop {
            match parked.stream.read(&mut chunk) {
                Ok(0) => break Action::Close,
                Ok(n) => {
                    parked.buf.extend_from_slice(&chunk[..n]);
                    parked.since = Instant::now();
                    if head_complete(&parked.buf) {
                        break Action::Dispatch;
                    }
                    if parked.buf.len() > MAX_HEAD {
                        break Action::TooBig;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break Action::Wait,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break Action::Close,
            }
        };
        match action {
            Action::Wait => {}
            Action::Dispatch => self.dispatch(token),
            Action::Close => self.close(token),
            Action::TooBig => {
                if let Some(parked) = self.unpark(token) {
                    self.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
                    let mut stream = parked.stream;
                    let _ = stream.set_nonblocking(false);
                    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
                    let mut resp = HttpResponse::text(
                        431,
                        &format!("request head exceeds {MAX_HEAD} bytes"),
                    );
                    let _ = resp.write_to(&mut stream, false);
                }
            }
        }
    }

    /// Deregister and take a parked connection.
    fn unpark(&mut self, token: u64) -> Option<Parked> {
        let parked = self.conns.remove(&token)?;
        self.epoll.del(parked.stream.as_raw_fd());
        Some(parked)
    }

    /// Silently close a parked connection (EOF, broken socket, idle
    /// keep-alive expiry).
    fn close(&mut self, token: u64) {
        if self.unpark(token).is_some() {
            self.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// A complete request head is buffered: admission-check, then hand
    /// the connection (blocking mode, slowloris timeouts armed) plus
    /// the buffered bytes to the worker pool.
    fn dispatch(&mut self, token: u64) {
        let Some(parked) = self.unpark(token) else { return };
        if self.inflight.load(Ordering::Relaxed) >= self.max_inflight as u64 {
            self.stats.admission_shed.fetch_add(1, Ordering::Relaxed);
            self.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
            // Shed always closes: body bytes may trail the head, so a
            // kept-alive shed would leave the stream unframed.
            shed_connection(parked.stream, 429, "server at in-flight request capacity");
            return;
        }
        let mut stream = parked.stream;
        if stream.set_nonblocking(false).is_err() {
            self.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        let _ = stream.set_read_timeout(Some(self.limits.conn_timeout));
        let _ = stream.set_write_timeout(Some(self.limits.conn_timeout));
        self.inflight.fetch_add(1, Ordering::Relaxed);
        let job = Job {
            stream,
            prefix: parked.buf,
            reused: parked.reused,
            handler: self.handler.clone(),
            limits: self.limits,
            stats: Arc::clone(&self.stats),
            inflight: Arc::clone(&self.inflight),
            return_tx: self.return_tx.clone(),
            wake: Arc::clone(&self.waker_tx),
        };
        match &self.pool {
            Some(pool) => pool.execute(move || job.run()),
            // Unreachable outside shutdown, but never leak the gauges.
            None => {
                self.inflight.fetch_sub(1, Ordering::Relaxed);
                self.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
            }
        }
    }

    /// Re-park keep-alive connections the workers handed back.
    fn collect_returned(&mut self) {
        while let Ok(ret) = self.return_rx.try_recv() {
            if self.stop.load(Ordering::SeqCst) || ret.stream.set_nonblocking(true).is_err() {
                self.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
                continue;
            }
            self.park(ret.stream, ret.leftover, true);
        }
    }

    /// Periodic reaping: idle keep-alive connections close silently
    /// after `keepalive_idle`; connections mid-head (or fresh ones that
    /// never sent a byte) get the threaded engine's `408` after
    /// `conn_timeout` of stall.
    fn sweep(&mut self) {
        let now = Instant::now();
        let mut idle = Vec::new();
        let mut slow = Vec::new();
        for (&token, parked) in &self.conns {
            let stalled = now.duration_since(parked.since);
            if parked.reused && parked.buf.is_empty() {
                if stalled >= self.keepalive_idle {
                    idle.push(token);
                }
            } else if stalled >= self.limits.conn_timeout {
                slow.push(token);
            }
        }
        for token in idle {
            self.close(token);
        }
        for token in slow {
            if let Some(parked) = self.unpark(token) {
                self.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
                let mut stream = parked.stream;
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
                let mut resp = failure_response(&ParseFailure::SlowClient, &self.limits);
                let _ = resp.write_to(&mut stream, false);
            }
        }
    }

    fn drain_waker(&mut self) {
        let mut sink = [0u8; 256];
        loop {
            match (&self.waker_rx).read(&mut sink) {
                Ok(0) => break,
                Ok(_) => continue,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    /// Orderly teardown: finish in-flight requests, then account for
    /// every connection still owned here.
    fn shutdown(&mut self) {
        // Joining the pool first lets in-flight responses complete;
        // their keep-alive returns then land in the channel below.
        drop(self.pool.take());
        while let Ok(_ret) = self.return_rx.try_recv() {
            self.stats.conns_open.fetch_sub(1, Ordering::Relaxed);
        }
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for token in tokens {
            self.close(token);
        }
    }
}

/// `\r\n\r\n` (or bare-LF `\n\n`) present — a complete request head.
fn head_complete(buf: &[u8]) -> bool {
    buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.windows(2).any(|w| w == b"\n\n")
}

/// One dispatched request: runs on a worker thread, serves via the
/// shared blocking path, and either hands the connection back to the
/// reactor (keep-alive) or lets it drop (close).
struct Job {
    stream: TcpStream,
    prefix: Vec<u8>,
    reused: bool,
    handler: AnyHandler,
    limits: ServerLimits,
    stats: Arc<NetStats>,
    inflight: Arc<AtomicU64>,
    return_tx: Sender<Returned>,
    wake: Arc<UnixStream>,
}

impl Job {
    fn run(self) {
        let _inflight = InflightGuard(Arc::clone(&self.inflight));
        let mut open = OpenGuard { stats: Arc::clone(&self.stats), armed: true };
        if self.reused {
            self.stats.keepalive_reuses.fetch_add(1, Ordering::Relaxed);
        }
        let mut stream = self.stream;
        let Ok(read_half) = stream.try_clone() else {
            return;
        };
        let mut reader = ConnReader::with_prefix(read_half, self.prefix);
        match serve_one(&mut stream, &mut reader, &self.handler, &self.limits, true) {
            Served::KeepAlive => {
                let leftover = reader.into_leftover();
                if self.return_tx.send(Returned { stream, leftover }).is_ok() {
                    // The reactor owns the open count from here on.
                    open.armed = false;
                    let _ = (&*self.wake).write_all(&[1]);
                }
            }
            Served::Close => {}
        }
    }
}
