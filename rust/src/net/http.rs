//! HTTP/1.1 subset: server (request routing via a handler fn) + client,
//! with keep-alive connections on both sides.
//!
//! Two service modes share one request loop:
//!
//! - **Buffered** ([`HttpServer::serve`]): the classic path — the body is
//!   read fully (bounded by the body cap) before the handler runs.
//! - **Streaming** ([`HttpServer::serve_stream_with_limits`]): the
//!   handler receives the parsed head plus a [`BodyReader`] and pulls
//!   body bytes incrementally — both `content-length`-framed and
//!   `Transfer-Encoding: chunked` bodies — so a gateway can
//!   erasure-encode per stripe while the client is still uploading.
//!
//! Responses are symmetric: [`HttpResponse`] carries either a buffered
//! body or a [`BodyStream`] whose blocks are written as they are
//! produced (`content-length` framing when the total is known, chunked
//! transfer-encoding otherwise — exactly one of the two, never both).
//!
//! Two server **engines** sit under the same handler API
//! ([`ServerEngine`]):
//!
//! - **Reactor** (default on Linux): a readiness-based epoll event loop
//!   owns every socket, buffers request heads off non-blocking reads,
//!   and hands complete requests to the worker pool. Idle keep-alive
//!   connections cost a file descriptor, not a thread, so thread count
//!   stays O(workers) under any connection count. Admission control
//!   sheds with `503` (connection cap) and `429` (in-flight cap), both
//!   with `Retry-After`.
//! - **Threaded** (fallback, and the default off Linux): the original
//!   thread-per-request loop, kept behind a knob for differential
//!   testing. It serves one request per connection (`connection:
//!   close`) so an idle client can never pin a pooled worker.
//!
//! [`HttpClient`] keeps a bounded per-host pool of keep-alive
//! connections (see [`crate::net::cpool`]) so repeated requests to the
//! same host — the coordinator→agent chunk fan-out — stop paying a TCP
//! handshake per call.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::net::{cpool, ThreadPool};
use crate::{Error, Result};

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    /// `Authorization: Bearer <token>` extraction. The scheme is
    /// case-insensitive per RFC 7235 §2.1 (`bearer`, `BEARER`, … all
    /// match).
    pub fn bearer_token(&self) -> Option<&str> {
        let header = self.header("authorization")?;
        let (scheme, rest) = header.split_once(|c: char| c.is_ascii_whitespace())?;
        if scheme.eq_ignore_ascii_case("bearer") {
            let token = rest.trim();
            if token.is_empty() {
                None
            } else {
                Some(token)
            }
        } else {
            None
        }
    }
}

/// A streamed response body: successive blocks pulled from `next` and
/// written to the socket as they arrive, so the server never holds the
/// full payload. `len: Some(n)` frames with `content-length: n` (the
/// writer enforces the total); `len: None` frames with
/// `transfer-encoding: chunked`.
pub struct BodyStream {
    pub len: Option<u64>,
    /// Yields the next body block, `Ok(None)` at end of stream. An `Err`
    /// aborts the connection mid-body so the client observes a short
    /// (or unterminated) body rather than silently truncated data.
    pub next: Box<dyn FnMut() -> Result<Option<Vec<u8>>> + Send>,
}

/// An HTTP response under construction.
pub struct HttpResponse {
    pub status: u16,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
    /// When set, `body` is ignored and blocks are streamed instead.
    pub stream: Option<BodyStream>,
}

impl std::fmt::Debug for HttpResponse {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HttpResponse")
            .field("status", &self.status)
            .field("headers", &self.headers)
            .field("body_len", &self.body.len())
            .field("streamed", &self.stream.is_some())
            .finish()
    }
}

impl HttpResponse {
    pub fn new(status: u16) -> Self {
        HttpResponse { status, headers: BTreeMap::new(), body: Vec::new(), stream: None }
    }

    pub fn json(status: u16, body: &crate::json::Value) -> Self {
        let mut r = HttpResponse::new(status);
        r.headers.insert("content-type".into(), "application/json".into());
        r.body = crate::json::to_string(body).into_bytes();
        r
    }

    pub fn bytes(status: u16, body: Vec<u8>) -> Self {
        let mut r = HttpResponse::new(status);
        r.headers.insert("content-type".into(), "application/octet-stream".into());
        r.body = body;
        r
    }

    pub fn text(status: u16, body: &str) -> Self {
        let mut r = HttpResponse::new(status);
        r.headers.insert("content-type".into(), "text/plain".into());
        r.body = body.as_bytes().to_vec();
        r
    }

    /// A streamed-body response: blocks from `next` go on the wire as
    /// they are produced. `len: Some(n)` promises exactly `n` body
    /// bytes (content-length framing); `len: None` uses chunked
    /// transfer-encoding.
    pub fn stream(
        status: u16,
        len: Option<u64>,
        next: Box<dyn FnMut() -> Result<Option<Vec<u8>>> + Send>,
    ) -> Self {
        let mut r = HttpResponse::new(status);
        r.headers.insert("content-type".into(), "application/octet-stream".into());
        r.stream = Some(BodyStream { len, next });
        r
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            204 => "No Content",
            206 => "Partial Content",
            304 => "Not Modified",
            400 => "Bad Request",
            401 => "Unauthorized",
            403 => "Forbidden",
            404 => "Not Found",
            408 => "Request Timeout",
            409 => "Conflict",
            413 => "Payload Too Large",
            416 => "Range Not Satisfiable",
            429 => "Too Many Requests",
            431 => "Request Header Fields Too Large",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            507 => "Insufficient Storage",
            _ => "Status",
        }
    }

    /// Serialize onto the socket. Framing is exactly one of
    /// `content-length` XOR `transfer-encoding: chunked`, decided here —
    /// handler-supplied copies of either header are dropped from the
    /// iteration and re-emitted once, so the two can never both appear.
    /// The `connection` header is likewise owned by the server loop:
    /// `keep_alive` reflects the negotiated outcome, not handler intent
    /// (a handler can still force closure by setting `connection:
    /// close`, which the loop honors before calling this).
    pub(crate) fn write_to(
        &mut self,
        stream: &mut TcpStream,
        keep_alive: bool,
    ) -> std::io::Result<()> {
        let conn = if keep_alive { "keep-alive" } else { "close" };
        let mut head = format!("HTTP/1.1 {} {}\r\n", self.status, self.reason());
        for (k, v) in &self.headers {
            if k == "content-length" || k == "transfer-encoding" || k == "connection" {
                continue; // framing + connection policy emitted once below
            }
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        let body_stream = self.stream.take();
        match body_stream {
            None => {
                // A handler-set `content-length` wins over the body
                // length: HEAD responses advertise the full object size
                // while carrying no body (RFC 9110 §9.3.2). Everything
                // else frames on the body.
                let declared = self
                    .headers
                    .get("content-length")
                    .cloned()
                    .unwrap_or_else(|| self.body.len().to_string());
                head.push_str(&format!(
                    "content-length: {declared}\r\nconnection: {conn}\r\n\r\n"
                ));
                stream.write_all(head.as_bytes())?;
                stream.write_all(&self.body)?;
            }
            Some(mut bs) => {
                match bs.len {
                    Some(total) => head.push_str(&format!(
                        "content-length: {total}\r\nconnection: {conn}\r\n\r\n"
                    )),
                    None => head.push_str(&format!(
                        "transfer-encoding: chunked\r\nconnection: {conn}\r\n\r\n"
                    )),
                }
                stream.write_all(head.as_bytes())?;
                let mut written = 0u64;
                loop {
                    let block = (bs.next)().map_err(stream_abort)?;
                    match block {
                        None => break,
                        Some(b) if b.is_empty() => continue,
                        Some(b) => match bs.len {
                            Some(total) => {
                                written += b.len() as u64;
                                if written > total {
                                    return Err(stream_abort(Error::Net(format!(
                                        "body stream produced more than the declared {total} bytes"
                                    ))));
                                }
                                stream.write_all(&b)?;
                            }
                            None => {
                                stream.write_all(format!("{:x}\r\n", b.len()).as_bytes())?;
                                stream.write_all(&b)?;
                                stream.write_all(b"\r\n")?;
                            }
                        },
                    }
                }
                match bs.len {
                    Some(total) if written != total => {
                        // Short stream: abort the connection so the
                        // client's content-length read fails loudly.
                        return Err(stream_abort(Error::Net(format!(
                            "body stream ended at {written} of {total} bytes"
                        ))));
                    }
                    Some(_) => {}
                    None => stream.write_all(b"0\r\n\r\n")?,
                }
            }
        }
        stream.flush()
    }
}

/// Mid-stream failures become an I/O error so the connection is torn
/// down — the only honest signal once the status line is on the wire.
fn stream_abort(e: Error) -> std::io::Error {
    std::io::Error::other(format!("body stream failed: {e}"))
}

type Handler = dyn Fn(HttpRequest) -> HttpResponse + Send + Sync + 'static;

/// A streaming request handler: gets the parsed head (empty `body`
/// field) plus an incremental [`BodyReader`] positioned at the first
/// body byte.
pub type StreamHandler =
    dyn Fn(HttpRequest, &mut BodyReader<'_>) -> HttpResponse + Send + Sync + 'static;

/// Largest request body [`HttpServer::serve`] accepts: 64 MiB. A
/// client-supplied `content-length` drives a buffer allocation, so an
/// unchecked header would let one bogus request OOM the process; bigger
/// deployments pick their own cap via [`HttpServer::serve_with_limit`].
pub const DEFAULT_MAX_BODY: usize = 64 << 20;

/// Default per-connection socket read/write timeout: the slowloris
/// guard. A client that trickles (or stops sending) its request holds a
/// handler thread at most this long before the server answers `408
/// Request Timeout` and reclaims the thread; a client that stops
/// reading its response is cut off by the matching write timeout.
pub const DEFAULT_CONN_TIMEOUT: Duration = Duration::from_secs(10);

/// Most unread request-body bytes the server will consume after a
/// response before simply closing the connection. Draining lets the
/// response reach a well-behaved client (closing with unread inbound
/// data can RST the socket and discard the response in the client's
/// receive buffer), but a hostile `content-length` must not pin a
/// server thread — past this budget the connection is cut. An
/// incompletely drained connection is never kept alive.
pub const DRAIN_BUDGET: u64 = 64 * 1024;

/// Default cap on concurrently open server connections (reactor: parked
/// + in-flight; threaded: queued + in-flight). Beyond it, accepts are
/// answered `503 + Retry-After` and closed.
pub const DEFAULT_MAX_CONNECTIONS: usize = 4096;

/// Default cap on requests concurrently dispatched to the worker pool
/// (reactor engine). Beyond it, complete requests are shed `429 +
/// Retry-After` instead of queueing without bound.
pub const DEFAULT_MAX_INFLIGHT: usize = 1024;

/// Default time an idle keep-alive connection may sit parked in the
/// reactor between requests before it is silently closed.
pub const DEFAULT_KEEPALIVE_IDLE: Duration = Duration::from_secs(60);

/// Per-connection resource limits for [`HttpServer::serve_with_limits`].
#[derive(Debug, Clone, Copy)]
pub struct ServerLimits {
    /// Largest accepted request body (413 beyond).
    pub max_body: usize,
    /// Socket read/write timeout (408 on header-read expiry).
    pub conn_timeout: Duration,
}

impl Default for ServerLimits {
    fn default() -> Self {
        ServerLimits { max_body: DEFAULT_MAX_BODY, conn_timeout: DEFAULT_CONN_TIMEOUT }
    }
}

/// Which connection-handling core serves the sockets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServerEngine {
    /// Readiness-based epoll event loop (Linux): keep-alive on, idle
    /// connections cost a file descriptor, thread count O(workers).
    #[default]
    Reactor,
    /// The original thread-per-request loop: one request per connection
    /// (`connection: close`), kept for differential testing and as the
    /// portable fallback.
    Threaded,
}

impl ServerEngine {
    /// The engine that will actually run on this platform: the reactor
    /// needs epoll, so off Linux it falls back to the threaded loop.
    pub fn resolved(self) -> ServerEngine {
        if cfg!(target_os = "linux") {
            self
        } else {
            ServerEngine::Threaded
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ServerEngine::Reactor => "reactor",
            ServerEngine::Threaded => "threaded",
        }
    }

    pub fn parse(s: &str) -> Option<ServerEngine> {
        match s.trim().to_ascii_lowercase().as_str() {
            "reactor" | "epoll" => Some(ServerEngine::Reactor),
            "threaded" | "threads" | "thread" => Some(ServerEngine::Threaded),
            _ => None,
        }
    }
}

/// Connection-plane counters exported through `/metrics` and `/health`.
/// Gauges (`conns_open`, `reactor_lag_us`) hold the current value;
/// everything else is a monotonic counter.
#[derive(Debug, Default)]
pub struct NetStats {
    /// Currently open server connections (accepted, not yet closed).
    pub conns_open: AtomicU64,
    /// Connections accepted since start (including shed ones).
    pub conns_accepted: AtomicU64,
    /// Requests served on a reused keep-alive connection.
    pub keepalive_reuses: AtomicU64,
    /// Connections/requests refused by admission control (503/429).
    pub admission_shed: AtomicU64,
    /// Last reactor loop iteration's processing time, microseconds — a
    /// lag gauge: how long ready sockets waited on the event loop.
    pub reactor_lag_us: AtomicU64,
}

impl NetStats {
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("conns_open", self.conns_open.load(Ordering::Relaxed)),
            ("conns_accepted", self.conns_accepted.load(Ordering::Relaxed)),
            ("keepalive_reuses", self.keepalive_reuses.load(Ordering::Relaxed)),
            ("admission_shed", self.admission_shed.load(Ordering::Relaxed)),
            ("reactor_lag_us", self.reactor_lag_us.load(Ordering::Relaxed)),
        ]
    }
}

/// Engine + admission-control knobs for [`HttpServer::serve_with_options`].
#[derive(Clone)]
pub struct ServerOptions {
    pub engine: ServerEngine,
    /// Open-connection cap; accepts beyond it get `503 + Retry-After`.
    pub max_connections: usize,
    /// In-flight request cap (reactor); complete requests beyond it get
    /// `429 + Retry-After` and the connection is closed (request-body
    /// bytes may already trail the head, so a kept-alive shed would
    /// desynchronize framing).
    pub max_inflight: usize,
    /// Idle keep-alive parking time before a silent close (reactor).
    pub keepalive_idle: Duration,
    /// Share a stats block with the server (the gateway threads one
    /// into `/metrics` + `/health`); `None` lets the server allocate
    /// its own, readable via [`HttpServer::stats`].
    pub stats: Option<Arc<NetStats>>,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            engine: ServerEngine::default(),
            max_connections: DEFAULT_MAX_CONNECTIONS,
            max_inflight: DEFAULT_MAX_INFLIGHT,
            keepalive_idle: DEFAULT_KEEPALIVE_IDLE,
            stats: None,
        }
    }
}

pub(crate) enum AnyHandler {
    Buffered(Arc<Handler>),
    Stream(Arc<StreamHandler>),
}

impl Clone for AnyHandler {
    fn clone(&self) -> Self {
        match self {
            AnyHandler::Buffered(h) => AnyHandler::Buffered(Arc::clone(h)),
            AnyHandler::Stream(h) => AnyHandler::Stream(Arc::clone(h)),
        }
    }
}

/// HTTP server handle: one engine thread (reactor event loop or
/// threaded accept loop) plus its worker pool.
pub struct HttpServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    stats: Arc<NetStats>,
    engine: ServerEngine,
    /// Unblocks the engine thread so `shutdown` can join it: the
    /// reactor's event-fd poke, or a wake connect for the threaded
    /// loop's blocking `accept`.
    waker: Option<Box<dyn Fn() + Send + Sync>>,
}

impl HttpServer {
    /// Bind `addr` ("127.0.0.1:0" for an ephemeral port) and serve with
    /// `workers` handler threads and the [`DEFAULT_MAX_BODY`] cap.
    pub fn serve(
        addr: &str,
        workers: usize,
        handler: Arc<Handler>,
    ) -> Result<HttpServer> {
        Self::serve_with_limits(addr, workers, handler, ServerLimits::default())
    }

    /// [`HttpServer::serve`] with an explicit request-body cap: any
    /// request declaring a larger `content-length` is answered `413
    /// Payload Too Large` without allocating for (or reading) its body.
    pub fn serve_with_limit(
        addr: &str,
        workers: usize,
        handler: Arc<Handler>,
        max_body: usize,
    ) -> Result<HttpServer> {
        Self::serve_with_limits(
            addr,
            workers,
            handler,
            ServerLimits { max_body, ..Default::default() },
        )
    }

    /// [`HttpServer::serve`] with explicit per-connection limits (body
    /// cap + slowloris socket timeout).
    pub fn serve_with_limits(
        addr: &str,
        workers: usize,
        handler: Arc<Handler>,
        limits: ServerLimits,
    ) -> Result<HttpServer> {
        Self::serve_inner(
            addr,
            workers,
            AnyHandler::Buffered(handler),
            limits,
            ServerOptions::default(),
        )
    }

    /// [`HttpServer::serve_with_limits`] plus engine/admission knobs.
    pub fn serve_with_options(
        addr: &str,
        workers: usize,
        handler: Arc<Handler>,
        limits: ServerLimits,
        opts: ServerOptions,
    ) -> Result<HttpServer> {
        Self::serve_inner(addr, workers, AnyHandler::Buffered(handler), limits, opts)
    }

    /// Streaming-mode server: the handler pulls request-body bytes
    /// incrementally through a [`BodyReader`] instead of receiving a
    /// pre-buffered body. The body cap still applies — a declared
    /// `content-length` over `limits.max_body` is refused 413 before
    /// the handler runs, and chunked bodies are capped cumulatively as
    /// they are read — but peak memory is bounded by how much the
    /// handler chooses to hold, not by object size.
    pub fn serve_stream_with_limits(
        addr: &str,
        workers: usize,
        handler: Arc<StreamHandler>,
        limits: ServerLimits,
    ) -> Result<HttpServer> {
        Self::serve_inner(
            addr,
            workers,
            AnyHandler::Stream(handler),
            limits,
            ServerOptions::default(),
        )
    }

    /// [`HttpServer::serve_stream_with_limits`] plus engine/admission
    /// knobs.
    pub fn serve_stream_with_options(
        addr: &str,
        workers: usize,
        handler: Arc<StreamHandler>,
        limits: ServerLimits,
        opts: ServerOptions,
    ) -> Result<HttpServer> {
        Self::serve_inner(addr, workers, AnyHandler::Stream(handler), limits, opts)
    }

    fn serve_inner(
        addr: &str,
        workers: usize,
        handler: AnyHandler,
        limits: ServerLimits,
        opts: ServerOptions,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stats =
            opts.stats.clone().unwrap_or_else(|| Arc::new(NetStats::default()));
        let engine = opts.engine.resolved();
        let (thread, waker) = match engine {
            #[cfg(target_os = "linux")]
            ServerEngine::Reactor => crate::net::reactor::spawn(
                listener,
                workers,
                handler,
                limits,
                &opts,
                Arc::clone(&stats),
                Arc::clone(&stop),
            )?,
            _ => {
                let thread = serve_threaded(
                    listener,
                    workers,
                    handler,
                    limits,
                    opts.max_connections,
                    Arc::clone(&stats),
                    Arc::clone(&stop),
                )?;
                let wake_addr = wake_addr_for(local);
                let waker: Box<dyn Fn() + Send + Sync> = Box::new(move || {
                    let _ = TcpStream::connect_timeout(
                        &wake_addr,
                        Duration::from_millis(250),
                    );
                });
                (thread, waker)
            }
        };
        Ok(HttpServer {
            addr: local,
            stop,
            thread: Some(thread),
            stats,
            engine,
            waker: Some(waker),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// The engine actually serving (after platform fallback).
    pub fn engine(&self) -> ServerEngine {
        self.engine
    }

    /// The server's connection-plane counters.
    pub fn stats(&self) -> Arc<NetStats> {
        Arc::clone(&self.stats)
    }

    pub fn shutdown(&mut self) {
        if self.thread.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        if let Some(wake) = &self.waker {
            wake();
        }
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Where a wake connect can reach the listener: the bound address, with
/// an unspecified IP (0.0.0.0 / ::) replaced by the loopback of the
/// same family.
fn wake_addr_for(local: std::net::SocketAddr) -> std::net::SocketAddr {
    let mut addr = local;
    if addr.ip().is_unspecified() {
        match addr {
            std::net::SocketAddr::V4(_) => {
                addr.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST))
            }
            std::net::SocketAddr::V6(_) => {
                addr.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST))
            }
        }
    }
    addr
}

/// The fallback threaded engine: a blocking accept loop dispatching one
/// worker job per connection. No busy-poll — the thread sleeps in
/// `accept(2)` until a connection (or the shutdown wake connect)
/// arrives.
fn serve_threaded(
    listener: TcpListener,
    workers: usize,
    handler: AnyHandler,
    limits: ServerLimits,
    max_connections: usize,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
) -> Result<std::thread::JoinHandle<()>> {
    let thread = std::thread::Builder::new()
        .name("http-accept".into())
        .spawn(move || {
            let pool = ThreadPool::new(workers);
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        stats.conns_accepted.fetch_add(1, Ordering::Relaxed);
                        if stats.conns_open.load(Ordering::Relaxed) >= max_connections as u64 {
                            stats.admission_shed.fetch_add(1, Ordering::Relaxed);
                            shed_connection(stream, 503, "server at connection capacity");
                            continue;
                        }
                        stats.conns_open.fetch_add(1, Ordering::Relaxed);
                        let handler = handler.clone();
                        let stats = Arc::clone(&stats);
                        pool.execute(move || handle_conn(stream, handler, limits, stats));
                    }
                    Err(_) => {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        // Transient accept errors (EMFILE under fd
                        // pressure): back off instead of spinning hot.
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
            }
        })
        .map_err(|e| Error::Net(format!("spawn accept thread: {e}")))?;
    Ok(thread)
}

/// Best-effort admission-shed response (`503`/`429` + `Retry-After`),
/// then close. Used before a connection enters normal service, so the
/// socket's send buffer is empty and the small write cannot block long.
pub(crate) fn shed_connection(mut stream: TcpStream, status: u16, msg: &str) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let mut resp = HttpResponse::text(status, msg);
    resp.headers.insert("retry-after".into(), "1".into());
    let _ = resp.write_to(&mut stream, false);
}

/// Why a request could not be parsed into an [`HttpRequest`].
pub(crate) enum ParseFailure {
    /// Declared `content-length` exceeds the server's cap — answered
    /// 413 without allocating for the body.
    TooLarge { declared: u64, cap: usize },
    /// The socket read timed out before a complete request arrived —
    /// the slowloris case, answered 408 so the thread is reclaimed.
    SlowClient,
    /// Clean EOF before the first request byte: the peer closed an idle
    /// connection. Not an error — closed silently (no one is listening
    /// for a response).
    Eof,
    Malformed(Error),
}

/// Classify an I/O failure: a socket-timeout expiry is a slow client
/// (408), anything else is a malformed/broken request (400).
fn read_failure(e: std::io::Error) -> ParseFailure {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => ParseFailure::SlowClient,
        _ => ParseFailure::Malformed(Error::Io(e)),
    }
}

impl From<Error> for ParseFailure {
    fn from(e: Error) -> Self {
        ParseFailure::Malformed(e)
    }
}

impl From<std::io::Error> for ParseFailure {
    fn from(e: std::io::Error) -> Self {
        read_failure(e)
    }
}

/// The error a body read returns when the cumulative body size passes
/// the server's cap; HTTP-facing callers answer it with 413.
fn over_cap_error(cap: u64) -> Error {
    Error::Invalid(format!("request body exceeds the {cap}-byte limit"))
}

/// Whether `e` is the body-over-cap error from a [`BodyReader`] — the
/// HTTP-facing caller answers 413 instead of 400. The error may arrive
/// wrapped as `Net` when the reader was driven through `std::io::Read`
/// (the streaming ingest path), so both variants are recognized.
pub fn is_over_cap(e: &Error) -> bool {
    matches!(e, Error::Invalid(m) | Error::Net(m) if m.contains("body exceeds the"))
}

/// Buffered reader over one TCP connection that can be handed *back*
/// after a request completes, carrying any read-ahead bytes with it —
/// the primitive keep-alive is built on.
///
/// `prefix` holds bytes that arrived before this reader owned the
/// socket (the reactor's non-blocking head buffer, or the leftover of a
/// previous request on the same connection); reads serve the prefix
/// first, then the socket through an internal `BufReader`. `consumed`
/// counts every byte served, which is how callers distinguish "peer
/// closed an idle connection" (zero bytes) from a mid-request failure.
pub(crate) struct ConnReader {
    prefix: Vec<u8>,
    pos: usize,
    inner: BufReader<TcpStream>,
    consumed: u64,
}

impl ConnReader {
    pub(crate) fn new(stream: TcpStream) -> ConnReader {
        ConnReader::with_prefix(stream, Vec::new())
    }

    pub(crate) fn with_prefix(stream: TcpStream, prefix: Vec<u8>) -> ConnReader {
        ConnReader { prefix, pos: 0, inner: BufReader::new(stream), consumed: 0 }
    }

    /// The underlying socket (shared fd — timeouts set here apply to
    /// reads through the reader too).
    pub(crate) fn stream(&self) -> &TcpStream {
        self.inner.get_ref()
    }

    /// Total bytes served through this reader.
    pub(crate) fn consumed(&self) -> u64 {
        self.consumed
    }

    /// Tear down the reader, returning every byte it read off the
    /// socket but never served — the next request's head when the
    /// client pipelined. Feed these back as the next reader's prefix.
    pub(crate) fn into_leftover(self) -> Vec<u8> {
        let mut left = self.prefix[self.pos..].to_vec();
        left.extend_from_slice(self.inner.buffer());
        left
    }
}

impl Read for ConnReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let available = self.fill_buf()?;
        let n = available.len().min(buf.len());
        buf[..n].copy_from_slice(&available[..n]);
        self.consume(n);
        Ok(n)
    }
}

impl BufRead for ConnReader {
    fn fill_buf(&mut self) -> std::io::Result<&[u8]> {
        if self.pos < self.prefix.len() {
            return Ok(&self.prefix[self.pos..]);
        }
        self.inner.fill_buf()
    }

    fn consume(&mut self, amt: usize) {
        self.consumed += amt as u64;
        let from_prefix = amt.min(self.prefix.len().saturating_sub(self.pos));
        self.pos += from_prefix;
        if amt > from_prefix {
            self.inner.consume(amt - from_prefix);
        }
    }
}

enum BodyState {
    Done,
    Sized { remaining: u64 },
    /// Mid-chunked-stream; `in_chunk` bytes left of the current chunk.
    Chunked { in_chunk: u64 },
}

/// Incremental request-body reader borrowed from the connection's
/// [`ConnReader`] for the duration of one request. Handles both
/// framings: `content-length` (exact byte count) and
/// `Transfer-Encoding: chunked` (RFC 9112 §7.1, trailers skipped).
/// Borrowing (rather than owning) the connection is what lets the
/// server reclaim it afterwards for the next keep-alive request.
pub struct BodyReader<'a> {
    reader: &'a mut ConnReader,
    state: BodyState,
    declared: Option<u64>,
    /// Cumulative cap for chunked bodies (sized bodies are checked
    /// against the cap before the reader is built).
    cap: u64,
    total: u64,
}

impl<'a> BodyReader<'a> {
    fn sized(reader: &'a mut ConnReader, len: u64) -> BodyReader<'a> {
        let state = if len == 0 { BodyState::Done } else { BodyState::Sized { remaining: len } };
        BodyReader { reader, state, declared: Some(len), cap: u64::MAX, total: 0 }
    }

    fn chunked(reader: &'a mut ConnReader, cap: u64) -> BodyReader<'a> {
        BodyReader {
            reader,
            state: BodyState::Chunked { in_chunk: 0 },
            declared: None,
            cap,
            total: 0,
        }
    }

    /// The request's `content-length`, when framed that way (`None` for
    /// chunked bodies, whose total is unknown until fully read).
    pub fn declared_len(&self) -> Option<u64> {
        self.declared
    }

    /// Total body bytes consumed so far.
    pub fn bytes_read(&self) -> u64 {
        self.total
    }

    /// Read up to `buf.len()` body bytes; `Ok(0)` means end of body.
    /// A socket EOF before the framing completes is an error, not EOF —
    /// a truncated upload must never look like a clean end of body.
    pub fn read_some(&mut self, buf: &mut [u8]) -> Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        match &mut self.state {
            BodyState::Done => Ok(0),
            BodyState::Sized { remaining } => {
                let want = buf.len().min((*remaining).min(usize::MAX as u64) as usize);
                let got = self.reader.read(&mut buf[..want])?;
                if got == 0 {
                    return Err(Error::Net(format!(
                        "unexpected eof with {remaining} body bytes outstanding"
                    )));
                }
                *remaining -= got as u64;
                if *remaining == 0 {
                    self.state = BodyState::Done;
                }
                self.total += got as u64;
                Ok(got)
            }
            BodyState::Chunked { in_chunk } => {
                if *in_chunk == 0 {
                    let mut line = String::new();
                    self.reader.read_line(&mut line)?;
                    if line.is_empty() {
                        return Err(Error::Net("unexpected eof before chunk size".into()));
                    }
                    if line.len() > 1024 {
                        return Err(Error::Net("chunk-size line too long".into()));
                    }
                    let size = parse_chunk_size(&line)?;
                    if size == 0 {
                        // Trailer section: skip lines until the blank
                        // terminator (bounded — trailers are metadata,
                        // not a second body).
                        for _ in 0..32 {
                            let mut t = String::new();
                            self.reader.read_line(&mut t)?;
                            if t.is_empty() || t == "\r\n" || t == "\n" {
                                self.state = BodyState::Done;
                                return Ok(0);
                            }
                        }
                        return Err(Error::Net("too many chunked trailer lines".into()));
                    }
                    if self.total.saturating_add(size) > self.cap {
                        return Err(over_cap_error(self.cap));
                    }
                    *in_chunk = size;
                }
                let want = buf.len().min((*in_chunk).min(usize::MAX as u64) as usize);
                let got = self.reader.read(&mut buf[..want])?;
                if got == 0 {
                    return Err(Error::Net("unexpected eof inside chunk".into()));
                }
                *in_chunk -= got as u64;
                self.total += got as u64;
                if *in_chunk == 0 {
                    // The CRLF that closes every chunk's data section.
                    let mut crlf = [0u8; 2];
                    self.reader.read_exact(&mut crlf)?;
                    if &crlf != b"\r\n" {
                        return Err(Error::Net("missing CRLF after chunk data".into()));
                    }
                }
                Ok(got)
            }
        }
    }

    /// Read exactly `buf.len()` body bytes, erroring on a short body.
    pub fn read_full(&mut self, buf: &mut [u8]) -> Result<()> {
        let mut off = 0;
        while off < buf.len() {
            match self.read_some(&mut buf[off..])? {
                0 => {
                    return Err(Error::Net(format!(
                        "body ended at {off} of {} expected bytes",
                        buf.len()
                    )))
                }
                n => off += n,
            }
        }
        Ok(())
    }

    /// Buffer the remaining body, refusing (without the allocation,
    /// when the length is declared) to exceed `cap`.
    pub fn read_to_end_cap(&mut self, cap: usize) -> Result<Vec<u8>> {
        if let BodyState::Sized { remaining } = self.state {
            if remaining > cap as u64 {
                return Err(over_cap_error(cap as u64));
            }
            let mut body = vec![0u8; remaining as usize];
            self.read_full(&mut body)?;
            return Ok(body);
        }
        let mut out = Vec::new();
        let mut buf = [0u8; 64 * 1024];
        loop {
            match self.read_some(&mut buf)? {
                0 => return Ok(out),
                n => {
                    if out.len() + n > cap {
                        return Err(over_cap_error(cap as u64));
                    }
                    out.extend_from_slice(&buf[..n]);
                }
            }
        }
    }

    /// Consume the unread remainder, up to `budget` bytes. Returns
    /// `true` when the body was fully drained (safe to close politely —
    /// or to keep the connection for the next request); `false` means
    /// the budget ran out or the read failed — the caller just closes
    /// the connection.
    fn drain(&mut self, budget: u64) -> bool {
        // The drain is bounded by its own budget; the chunked
        // cumulative cap must not re-fire while discarding.
        self.cap = u64::MAX;
        let mut sink = [0u8; 8192];
        let mut used = 0u64;
        loop {
            if matches!(self.state, BodyState::Done) {
                return true;
            }
            if used >= budget {
                return false;
            }
            let want = sink.len().min((budget - used) as usize);
            match self.read_some(&mut sink[..want]) {
                Ok(0) => return true,
                Ok(n) => used += n as u64,
                Err(_) => return false,
            }
        }
    }
}

/// `std::io::Read` adapter so streaming consumers (the coordinator's
/// stripe pipeline) can drive the body through a plain reader trait.
/// Framing/cap errors are wrapped as `io::Error` with the message
/// preserved, so [`is_over_cap`] still recognizes the cap error after a
/// round trip through `io` (it arrives back as `Error::Net`).
impl std::io::Read for BodyReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.read_some(buf).map_err(|e| std::io::Error::other(e.to_string()))
    }
}

/// `1a3f` or `1a3f;ext=v` → 0x1a3f (chunk extensions are ignored).
fn parse_chunk_size(line: &str) -> Result<u64> {
    let token = line.trim().split(';').next().unwrap_or("").trim();
    if token.is_empty() || token.len() > 16 {
        return Err(Error::Net(format!("bad chunk size line '{}'", line.trim())));
    }
    u64::from_str_radix(token, 16).map_err(|_| Error::Net(format!("bad chunk size '{token}'")))
}

pub(crate) fn failure_response(failure: &ParseFailure, limits: &ServerLimits) -> HttpResponse {
    match failure {
        ParseFailure::TooLarge { declared, cap } => HttpResponse::text(
            413,
            &format!("declared body of {declared} bytes exceeds the {cap}-byte limit"),
        ),
        ParseFailure::SlowClient => HttpResponse::text(
            408,
            &format!("request not received within {:?} — connection closed", limits.conn_timeout),
        ),
        ParseFailure::Eof => HttpResponse::text(400, "connection closed before a request"),
        ParseFailure::Malformed(e) => HttpResponse::text(400, &format!("bad request: {e}")),
    }
}

/// The threaded engine's per-connection job: serve exactly one request.
/// Keep-alive stays off here by design — with a fixed worker pool, a
/// parked-but-idle keep-alive client would pin a worker for the whole
/// idle window; parking without threads is the reactor's job.
fn handle_conn(
    mut stream: TcpStream,
    handler: AnyHandler,
    limits: ServerLimits,
    stats: Arc<NetStats>,
) {
    // The write half gets the same timeout: a client that stops reading
    // its response must not pin a handler thread either.
    let _ = stream.set_write_timeout(Some(limits.conn_timeout));
    let _ = stream.set_read_timeout(Some(limits.conn_timeout));
    if let Ok(read_half) = stream.try_clone() {
        let mut reader = ConnReader::new(read_half);
        let _ = serve_one(&mut stream, &mut reader, &handler, &limits, false);
    }
    stats.conns_open.fetch_sub(1, Ordering::Relaxed);
}

/// One request serviced on an established connection: parse the head
/// off `reader`, run the handler, write the response, drain the unread
/// body remainder. The return value is the keep-alive verdict — a
/// connection is only kept when the client asked for it, the engine
/// allows it, the handler didn't force `connection: close`, the
/// response write succeeded, AND the request body was fully consumed
/// (anything else leaves the stream unframed for the next request).
pub(crate) enum Served {
    Close,
    KeepAlive,
}

pub(crate) fn serve_one(
    stream: &mut TcpStream,
    reader: &mut ConnReader,
    handler: &AnyHandler,
    limits: &ServerLimits,
    allow_keep_alive: bool,
) -> Served {
    match parse_head_from(reader, limits) {
        Ok((req, framing)) => {
            let client_keep = req
                .headers
                .get("connection")
                .map(|v| !v.to_ascii_lowercase().split(',').any(|t| t.trim() == "close"))
                .unwrap_or(true);
            let want_keep = allow_keep_alive && client_keep;
            let mut body = match framing {
                Framing::Chunked => BodyReader::chunked(reader, limits.max_body as u64),
                Framing::Sized(len) => BodyReader::sized(reader, len),
            };
            let mut response = match handler {
                AnyHandler::Buffered(h) => match body.read_to_end_cap(limits.max_body) {
                    Ok(bytes) => {
                        let mut req = req;
                        req.body = bytes;
                        h(req)
                    }
                    Err(e) if is_over_cap(&e) => HttpResponse::text(
                        413,
                        &format!("request body exceeds the {}-byte limit", limits.max_body),
                    ),
                    Err(Error::Io(e)) => failure_response(&read_failure(e), limits),
                    Err(e) => failure_response(&ParseFailure::Malformed(e), limits),
                },
                AnyHandler::Stream(h) => h(req, &mut body),
            };
            // A handler that sets `connection: close` forces closure
            // (e.g. a response whose correctness depends on EOF).
            let handler_close = response
                .headers
                .get("connection")
                .map(|v| v.to_ascii_lowercase().contains("close"))
                .unwrap_or(false);
            let keep = want_keep && !handler_close;
            let write_ok = response.write_to(stream, keep).is_ok();
            // Bounded courtesy drain of whatever the client already
            // sent: closing with unread inbound data can RST the
            // connection and discard the response sitting in the
            // client's receive buffer. Past the budget, just close.
            let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
            let drained = body.drain(DRAIN_BUDGET);
            if write_ok && keep && drained {
                let _ = stream.set_read_timeout(Some(limits.conn_timeout));
                Served::KeepAlive
            } else {
                Served::Close
            }
        }
        // The peer closed an idle connection cleanly — nothing to
        // answer, nobody listening.
        Err(ParseFailure::Eof) => Served::Close,
        Err(failure) => {
            let mut response = failure_response(&failure, limits);
            let _ = response.write_to(stream, false);
            if let ParseFailure::TooLarge { declared, .. } = failure {
                // Same courtesy drain, same bound: a hostile
                // content-length past the budget is cut off instead of
                // pinning this thread while the client pushes bytes.
                if declared <= DRAIN_BUDGET {
                    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
                    let mut sink = [0u8; 8192];
                    let mut remaining = declared;
                    while remaining > 0 {
                        match reader.read(&mut sink) {
                            Ok(0) | Err(_) => break,
                            Ok(n) => remaining = remaining.saturating_sub(n as u64),
                        }
                    }
                }
            }
            Served::Close
        }
    }
}

/// How the request body is framed on the wire.
pub(crate) enum Framing {
    Sized(u64),
    Chunked,
}

/// Parse the request line + headers off `reader` and hand back the head
/// plus the body framing. A declared `content-length` beyond the cap is
/// refused here — before any allocation, in both service modes.
///
/// HTTP/1.0 requests without an explicit `connection: keep-alive` are
/// normalized to carry `connection: close`, so every downstream
/// keep-alive decision can read the header alone.
fn parse_head_from(
    reader: &mut ConnReader,
    limits: &ServerLimits,
) -> std::result::Result<(HttpRequest, Framing), ParseFailure> {
    let max_body = limits.max_body;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    if line.is_empty() {
        return Err(ParseFailure::Eof);
    }
    let mut parts = line.trim_end().split_whitespace();
    let method = parts.next().ok_or_else(|| Error::Net("missing method".into()))?.to_string();
    let path = parts.next().ok_or_else(|| Error::Net("missing path".into()))?.to_string();
    let http10 = parts.next().map(|v| v.eq_ignore_ascii_case("HTTP/1.0")).unwrap_or(false);

    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    if http10 {
        // RFC 7230 appendix A.1.2: 1.0 defaults to close unless the
        // client opted in.
        let keep = headers
            .get("connection")
            .map(|v| v.eq_ignore_ascii_case("keep-alive"))
            .unwrap_or(false);
        if !keep {
            headers.insert("connection".into(), "close".into());
        }
    }
    let request = HttpRequest { method, path, headers, body: Vec::new() };
    // RFC 9112 §6.3: when both are present, transfer-encoding wins and
    // content-length is ignored.
    let chunked = request
        .headers
        .get("transfer-encoding")
        .map(|v| v.to_ascii_lowercase().contains("chunked"))
        .unwrap_or(false);
    if chunked {
        return Ok((request, Framing::Chunked));
    }
    // Never trust the client's content-length with an allocation: cap
    // it BEFORE `vec![0u8; len]` — one bogus header must not OOM the
    // gateway. Parse as u64 so a length beyond usize (32-bit hosts)
    // can't wrap; a malformed value is a malformed request.
    let len: u64 = match request.headers.get("content-length") {
        None => 0,
        Some(v) => {
            v.trim().parse().map_err(|_| Error::Net(format!("bad content-length '{v}'")))?
        }
    };
    if len > max_body as u64 {
        return Err(ParseFailure::TooLarge { declared: len, cap: max_body });
    }
    Ok((request, Framing::Sized(len)))
}

/// Blocking HTTP client for the CLI, tests, and remote container
/// channels, with keep-alive connection reuse through the global
/// per-host pool ([`crate::net::cpool`]).
pub struct HttpClient {
    base: String,
    /// Connect/read/write timeout; `None` blocks indefinitely (CLI use).
    timeout: Option<Duration>,
    /// Whether this client participates in the keep-alive pool.
    pooled: bool,
}

impl HttpClient {
    /// `base` like `127.0.0.1:8080`.
    pub fn new(base: &str) -> Self {
        HttpClient { base: base.to_string(), timeout: None, pooled: true }
    }

    /// A client whose connects, reads, and writes all fail after
    /// `timeout` — so a dead endpoint surfaces as an error instead of a
    /// hung dispatch thread.
    pub fn with_timeout(base: &str, timeout: Duration) -> Self {
        HttpClient { base: base.to_string(), timeout: Some(timeout), pooled: true }
    }

    /// Opt this client out of keep-alive pooling: every request opens a
    /// fresh connection and sends `connection: close` — the pre-pool
    /// behavior, kept for differential tests and benches.
    pub fn without_pool(mut self) -> Self {
        self.pooled = false;
        self
    }

    /// Drop every pooled connection to this client's host — called when
    /// the peer is known dead (circuit breaker tripped, agent kill) so
    /// later requests don't burn their stale-retry on a corpse.
    pub fn invalidate_pooled(&self) {
        cpool::global().invalidate(&self.base);
    }

    fn connect(&self, timeout: Option<Duration>) -> Result<TcpStream> {
        match timeout {
            None => Ok(TcpStream::connect(&self.base)?),
            Some(t) => {
                use std::net::ToSocketAddrs;
                let addr = self
                    .base
                    .to_socket_addrs()?
                    .next()
                    .ok_or_else(|| Error::Net(format!("cannot resolve '{}'", self.base)))?;
                let stream = TcpStream::connect_timeout(&addr, t)?;
                stream.set_read_timeout(Some(t))?;
                stream.set_write_timeout(Some(t))?;
                Ok(stream)
            }
        }
    }

    /// A connection ready for one exchange: a pooled keep-alive one
    /// when allowed and available (flagged `true`), else a fresh
    /// connect. Timeouts are (re)applied either way — a pooled
    /// connection may have been checked in under different ones.
    fn obtain(
        &self,
        timeout: Option<Duration>,
        allow_pool: bool,
    ) -> Result<(ConnReader, bool)> {
        if allow_pool {
            if let Some(conn) = cpool::global().checkout(&self.base) {
                let _ = conn.stream().set_read_timeout(timeout);
                let _ = conn.stream().set_write_timeout(timeout);
                return Ok((conn, true));
            }
        }
        let stream = self.connect(timeout)?;
        cpool::global().stats.connects.fetch_add(1, Ordering::Relaxed);
        Ok((ConnReader::new(stream), false))
    }

    /// Write one request and read its response off `conn`. The second
    /// return flag says the connection is reusable afterwards (response
    /// fully framed and the server didn't announce `close`).
    fn exchange(
        &self,
        conn: &mut ConnReader,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
        keep_alive: bool,
    ) -> Result<(HttpResponse, bool)> {
        // RFC 7230 §5.4 + §6.1: Host on every request, and an explicit
        // Connection header stating this client's reuse intent.
        let mut head = format!(
            "{method} {path} HTTP/1.1\r\nhost: {}\r\nconnection: {}\r\n",
            self.base,
            if keep_alive { "keep-alive" } else { "close" }
        );
        for (k, v) in headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str(&format!("content-length: {}\r\n\r\n", body.len()));
        {
            let mut w = conn.stream();
            w.write_all(head.as_bytes())?;
            w.write_all(body)?;
            w.flush()?;
        }
        read_response(conn, method)
    }

    pub fn request(
        &self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> Result<HttpResponse> {
        self.request_with_timeout(method, path, headers, body, self.timeout)
    }

    /// [`HttpClient::request`] with a per-request timeout override: the
    /// deadline-propagation path clamps each hop's wait to the request's
    /// remaining budget instead of the client's configured default.
    ///
    /// When a **reused** pooled connection dies before yielding a single
    /// response byte, the request is retried exactly once on a fresh
    /// connection (RFC 7230 §6.3.1 — the server closed an idle
    /// keep-alive connection in a race with this request; zero response
    /// bytes proves the server never started processing the retry-able
    /// way a mid-response failure would not).
    pub fn request_with_timeout(
        &self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
        timeout: Option<Duration>,
    ) -> Result<HttpResponse> {
        let use_pool = self.pooled && cpool::global().enabled();
        for attempt in 0..2u8 {
            let (mut conn, reused) = self.obtain(timeout, use_pool && attempt == 0)?;
            let before = conn.consumed();
            match self.exchange(&mut conn, method, path, headers, body, use_pool) {
                Ok((resp, reusable)) => {
                    if reused {
                        cpool::global().stats.reuses.fetch_add(1, Ordering::Relaxed);
                    }
                    if use_pool && reusable {
                        cpool::global().checkin(&self.base, conn);
                    }
                    return Ok(resp);
                }
                Err(e) => {
                    if !(reused && conn.consumed() == before) {
                        return Err(e);
                    }
                    cpool::global().stats.stale_retries.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        Err(Error::Net(format!(
            "{method} {path}: pooled connection was stale and the fresh retry failed"
        )))
    }

    /// Send a request whose body is streamed from `body` with chunked
    /// transfer-encoding — the wire-level dual of the server's
    /// [`BodyReader`]; the total size need not be known up front.
    ///
    /// Always a fresh connection: a streamed body cannot be replayed,
    /// so there is no stale-retry to arm. The connection still joins
    /// the pool afterwards when the response leaves it reusable.
    pub fn request_stream(
        &self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &mut dyn Read,
    ) -> Result<HttpResponse> {
        let use_pool = self.pooled && cpool::global().enabled();
        let stream = self.connect(self.timeout)?;
        cpool::global().stats.connects.fetch_add(1, Ordering::Relaxed);
        let mut conn = ConnReader::new(stream);
        let mut head = format!(
            "{method} {path} HTTP/1.1\r\nhost: {}\r\nconnection: {}\r\n",
            self.base,
            if use_pool { "keep-alive" } else { "close" }
        );
        for (k, v) in headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str("transfer-encoding: chunked\r\n\r\n");
        {
            let mut w = conn.stream();
            w.write_all(head.as_bytes())?;
            let mut buf = vec![0u8; 64 * 1024];
            loop {
                let n = body.read(&mut buf)?;
                if n == 0 {
                    break;
                }
                w.write_all(format!("{n:x}\r\n").as_bytes())?;
                w.write_all(&buf[..n])?;
                w.write_all(b"\r\n")?;
            }
            w.write_all(b"0\r\n\r\n")?;
            w.flush()?;
        }
        let (resp, reusable) = read_response(&mut conn, method)?;
        if use_pool && reusable {
            cpool::global().checkin(&self.base, conn);
        }
        Ok(resp)
    }

    /// [`HttpClient::request_stream`] for PUT uploads.
    pub fn put_stream(
        &self,
        path: &str,
        headers: &[(&str, &str)],
        body: &mut dyn Read,
    ) -> Result<HttpResponse> {
        self.request_stream("PUT", path, headers, body)
    }

    pub fn get(&self, path: &str, headers: &[(&str, &str)]) -> Result<HttpResponse> {
        self.request("GET", path, headers, &[])
    }

    pub fn put(&self, path: &str, headers: &[(&str, &str)], body: &[u8]) -> Result<HttpResponse> {
        self.request("PUT", path, headers, body)
    }

    pub fn post(&self, path: &str, headers: &[(&str, &str)], body: &[u8]) -> Result<HttpResponse> {
        self.request("POST", path, headers, body)
    }

    pub fn delete(&self, path: &str, headers: &[(&str, &str)]) -> Result<HttpResponse> {
        self.request("DELETE", path, headers, &[])
    }
}

/// Read a full response off `conn`: status line, headers, then the body
/// under whichever framing the server chose. Returns the response plus
/// whether the connection is reusable for another request: the body was
/// self-delimiting (content-length / chunked / bodiless — NOT
/// read-to-EOF) and the server didn't send `connection: close`.
fn read_response(conn: &mut ConnReader, method: &str) -> Result<(HttpResponse, bool)> {
    let mut status_line = String::new();
    conn.read_line(&mut status_line)?;
    if status_line.is_empty() {
        return Err(Error::Net("connection closed before the response status line".into()));
    }
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Net(format!("bad status line '{status_line}'")))?;
    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        conn.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    // HEAD responses and 204/304 have no body by definition — their
    // content-length (HEAD advertises the object size) must not be
    // read off the wire.
    let bodiless = method.eq_ignore_ascii_case("HEAD") || status == 204 || status == 304;
    let chunked = headers
        .get("transfer-encoding")
        .map(|v| v.to_ascii_lowercase().contains("chunked"))
        .unwrap_or(false);
    let mut self_delimited = true;
    let body = if bodiless {
        Vec::new()
    } else if chunked {
        BodyReader::chunked(conn, u64::MAX).read_to_end_cap(usize::MAX)?
    } else if let Some(len) =
        headers.get("content-length").and_then(|v| v.trim().parse::<usize>().ok())
    {
        let mut body = vec![0u8; len];
        if len > 0 {
            conn.read_exact(&mut body)?;
        }
        body
    } else {
        // RFC 7230 §3.3.3 case 7: no framing headers at all — the body
        // runs until the server closes the connection (error paths of
        // minimal servers). Such a connection is spent.
        self_delimited = false;
        let mut body = Vec::new();
        conn.read_to_end(&mut body)?;
        body
    };
    let close = headers
        .get("connection")
        .map(|v| v.to_ascii_lowercase().contains("close"))
        .unwrap_or(false);
    Ok((HttpResponse { status, headers, body, stream: None }, self_delimited && !close))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> HttpServer {
        HttpServer::serve(
            "127.0.0.1:0",
            2,
            Arc::new(|req: HttpRequest| {
                if req.path == "/hello" {
                    HttpResponse::text(200, "world")
                } else if req.method == "PUT" {
                    HttpResponse::bytes(201, req.body)
                } else {
                    HttpResponse::text(404, "nope")
                }
            }),
        )
        .unwrap()
    }

    #[test]
    fn get_roundtrip() {
        let server = echo_server();
        let client = HttpClient::new(&server.addr().to_string());
        let resp = client.get("/hello", &[]).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"world");
    }

    #[test]
    fn put_echoes_binary_body() {
        let server = echo_server();
        let client = HttpClient::new(&server.addr().to_string());
        let payload: Vec<u8> = (0..=255u8).cycle().take(70_000).collect();
        let resp = client.put("/obj", &[("x-test", "1")], &payload).unwrap();
        assert_eq!(resp.status, 201);
        assert_eq!(resp.body, payload, "binary body intact");
    }

    #[test]
    fn not_found_and_headers() {
        let server = echo_server();
        let client = HttpClient::new(&server.addr().to_string());
        let resp = client.get("/missing", &[]).unwrap();
        assert_eq!(resp.status, 404);
        assert_eq!(resp.headers.get("content-type").unwrap(), "text/plain");
    }

    #[test]
    fn concurrent_requests() {
        let server = echo_server();
        let addr = server.addr().to_string();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let client = HttpClient::new(&addr);
                    let body = vec![i as u8; 1000];
                    let resp = client.put("/o", &[], &body).unwrap();
                    assert_eq!(resp.body, body);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn timeout_client_still_roundtrips() {
        let server = echo_server();
        let client = HttpClient::with_timeout(
            &server.addr().to_string(),
            std::time::Duration::from_secs(5),
        );
        let resp = client.get("/hello", &[]).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"world");
    }

    #[test]
    fn timeout_client_fails_fast_on_dead_endpoint() {
        let client =
            HttpClient::with_timeout("127.0.0.1:1", std::time::Duration::from_millis(500));
        let t0 = std::time::Instant::now();
        assert!(client.get("/x", &[]).is_err());
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
    }

    #[test]
    fn bearer_token_parsing() {
        let with_auth = |value: &str| HttpRequest {
            method: "GET".into(),
            path: "/".into(),
            headers: [("authorization".to_string(), value.to_string())]
                .into_iter()
                .collect(),
            body: vec![],
        };
        assert_eq!(with_auth("Bearer abc.def").bearer_token(), Some("abc.def"));
        // RFC 7235: the scheme is case-insensitive.
        assert_eq!(with_auth("bearer abc.def").bearer_token(), Some("abc.def"));
        assert_eq!(with_auth("BEARER abc.def").bearer_token(), Some("abc.def"));
        assert_eq!(with_auth("BeArEr  spaced ").bearer_token(), Some("spaced"));
        // Other schemes and empty credentials are not bearer tokens.
        assert_eq!(with_auth("Basic dXNlcg==").bearer_token(), None);
        assert_eq!(with_auth("Bearer ").bearer_token(), None);
        assert_eq!(with_auth("Bearer").bearer_token(), None);
    }

    #[test]
    fn head_advertises_length_without_body() {
        // A handler-set content-length overrides body framing, and the
        // client must not try to read a HEAD body off the wire.
        let server = HttpServer::serve(
            "127.0.0.1:0",
            2,
            Arc::new(|req: HttpRequest| {
                if req.method == "HEAD" {
                    let mut r = HttpResponse::new(200);
                    r.headers.insert("content-length".into(), "12345".into());
                    r.headers.insert("etag".into(), "\"abc\"".into());
                    r
                } else {
                    HttpResponse::text(200, "body")
                }
            }),
        )
        .unwrap();
        let client = HttpClient::new(&server.addr().to_string());
        let head = client.request("HEAD", "/o", &[], &[]).unwrap();
        assert_eq!(head.status, 200);
        assert_eq!(head.headers.get("content-length").unwrap(), "12345");
        assert!(head.body.is_empty(), "HEAD carries no body");
        // The connection still works for normal GETs.
        let got = client.get("/o", &[]).unwrap();
        assert_eq!(got.body, b"body");
    }

    #[test]
    fn oversized_declared_body_gets_413() {
        let server = HttpServer::serve_with_limit(
            "127.0.0.1:0",
            2,
            Arc::new(|req: HttpRequest| HttpResponse::bytes(201, req.body)),
            1_000,
        )
        .unwrap();
        let client = HttpClient::new(&server.addr().to_string());
        // Under the cap: normal echo.
        let resp = client.put("/o", &[], &[7u8; 900]).unwrap();
        assert_eq!(resp.status, 201);
        // Over the cap: 413 with the right reason phrase, body unread.
        let resp = client.put("/o", &[], &[7u8; 5_000]).unwrap();
        assert_eq!(resp.status, 413);
    }

    #[test]
    fn oversized_body_past_drain_budget_closes_connection() {
        // A hostile content-length far past the drain budget must get
        // its 413 and a prompt close — no thread pinned consuming the
        // body. The client sends only headers, so the response is
        // readable before the server cuts the connection.
        let server = HttpServer::serve_with_limit(
            "127.0.0.1:0",
            1,
            Arc::new(|req: HttpRequest| HttpResponse::bytes(201, req.body)),
            1_000,
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream
            .write_all(b"PUT /o HTTP/1.1\r\nhost: t\r\ncontent-length: 104857600\r\n\r\n")
            .unwrap();
        let mut reply = String::new();
        let mut reader = BufReader::new(&mut stream);
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("413"), "{reply}");
        // Server must not sit in a 100 MiB drain loop: the connection
        // reaches EOF (close) quickly.
        let mut rest = Vec::new();
        let _ = reader.read_to_end(&mut rest);
        assert!(t0.elapsed() < std::time::Duration::from_secs(5), "drain was not bounded");
        // The worker thread is free again.
        let client = HttpClient::new(&server.addr().to_string());
        assert_eq!(client.put("/o", &[], &[1u8; 10]).unwrap().status, 201);
    }

    #[test]
    fn slow_client_gets_408_and_server_survives() {
        let server = HttpServer::serve_with_limits(
            "127.0.0.1:0",
            2,
            Arc::new(|_req: HttpRequest| HttpResponse::text(200, "ok")),
            ServerLimits {
                max_body: DEFAULT_MAX_BODY,
                conn_timeout: std::time::Duration::from_millis(100),
            },
        )
        .unwrap();
        // A slowloris connection: open, trickle half a request line, stall.
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.write_all(b"GET /stalled HTT").unwrap();
        let mut reply = String::new();
        let mut reader = BufReader::new(&mut stream);
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("408"), "{reply}");
        assert!(reply.contains("Request Timeout"), "{reply}");
        // A stalled *header* section (complete request line) times out too.
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.write_all(b"GET /h HTTP/1.1\r\nhost: t\r\nx-part").unwrap();
        let mut reply = String::new();
        let mut reader = BufReader::new(&mut stream);
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("408"), "{reply}");
        // The handler thread was reclaimed: normal requests still work.
        let client = HttpClient::new(&server.addr().to_string());
        assert_eq!(client.get("/fine", &[]).unwrap().status, 200);
    }

    #[test]
    fn per_request_timeout_override() {
        let server = echo_server();
        // Client default: no timeout. Per-request: tight but sufficient.
        let client = HttpClient::new(&server.addr().to_string());
        let resp = client
            .request_with_timeout(
                "GET",
                "/hello",
                &[],
                &[],
                Some(std::time::Duration::from_secs(5)),
            )
            .unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"world");
    }

    #[test]
    fn absurd_content_length_header_rejected_without_allocation() {
        // A bogus header claiming an 8 EiB body must be answered with
        // 413, not a vec![0u8; 2^63] allocation.
        let server = HttpServer::serve(
            "127.0.0.1:0",
            1,
            Arc::new(|_req: HttpRequest| HttpResponse::new(200)),
        )
        .unwrap();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream
            .write_all(
                b"PUT /objects/x HTTP/1.1\r\nhost: t\r\ncontent-length: 9223372036854775807\r\n\r\n",
            )
            .unwrap();
        let mut reply = String::new();
        let mut reader = BufReader::new(&mut stream);
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("413"), "{reply}");
        assert!(reply.contains("Payload Too Large"), "{reply}");
        // Garbage content-length is a 400, not a silent zero.
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream
            .write_all(b"PUT /x HTTP/1.1\r\nhost: t\r\ncontent-length: banana\r\n\r\n")
            .unwrap();
        let mut reply = String::new();
        let mut reader = BufReader::new(&mut stream);
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("400"), "{reply}");
    }

    #[test]
    fn chunked_request_body_reaches_buffered_handler() {
        // A chunked upload (no content-length anywhere) is reassembled
        // for buffered handlers exactly as a sized body would be.
        let server = echo_server();
        let client = HttpClient::new(&server.addr().to_string());
        let payload: Vec<u8> = (0..=255u8).cycle().take(200_000).collect();
        let mut reader = std::io::Cursor::new(payload.clone());
        let resp = client.put_stream("/obj", &[], &mut reader).unwrap();
        assert_eq!(resp.status, 201);
        assert_eq!(resp.body, payload, "chunked body reassembled intact");
    }

    #[test]
    fn chunked_request_over_cap_gets_413() {
        let server = HttpServer::serve_with_limit(
            "127.0.0.1:0",
            2,
            Arc::new(|req: HttpRequest| HttpResponse::bytes(201, req.body)),
            1_000,
        )
        .unwrap();
        let client = HttpClient::new(&server.addr().to_string());
        let mut reader = std::io::Cursor::new(vec![9u8; 5_000]);
        let resp = client.put_stream("/o", &[], &mut reader).unwrap();
        assert_eq!(resp.status, 413, "cumulative chunked cap enforced");
    }

    #[test]
    fn streaming_handler_reads_body_incrementally() {
        // The streaming server hands the handler a BodyReader; the
        // handler consumes the body in small reads and echoes a digest.
        let server = HttpServer::serve_stream_with_limits(
            "127.0.0.1:0",
            2,
            Arc::new(|req: HttpRequest, body: &mut BodyReader| {
                assert!(req.body.is_empty(), "streaming mode leaves head.body empty");
                let mut total = 0u64;
                let mut sum = 0u64;
                let mut buf = [0u8; 777]; // deliberately odd block size
                loop {
                    match body.read_some(&mut buf) {
                        Ok(0) => break,
                        Ok(n) => {
                            total += n as u64;
                            sum += buf[..n].iter().map(|&b| b as u64).sum::<u64>();
                        }
                        Err(e) => return HttpResponse::text(400, &format!("{e}")),
                    }
                }
                HttpResponse::text(200, &format!("{total}:{sum}"))
            }),
            ServerLimits::default(),
        )
        .unwrap();
        let client = HttpClient::new(&server.addr().to_string());
        let payload = vec![3u8; 100_000];
        // Sized framing.
        let resp = client.put("/o", &[], &payload).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, format!("{}:{}", 100_000, 300_000).as_bytes());
        // Chunked framing through the same handler.
        let mut reader = std::io::Cursor::new(payload);
        let resp = client.put_stream("/o", &[], &mut reader).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, format!("{}:{}", 100_000, 300_000).as_bytes());
    }

    #[test]
    fn streamed_response_known_length_frames_with_content_length() {
        let payload: Vec<u8> = (0..=255u8).cycle().take(70_000).collect();
        let expect = payload.clone();
        let server = HttpServer::serve(
            "127.0.0.1:0",
            2,
            Arc::new(move |_req: HttpRequest| {
                let blocks: Vec<Vec<u8>> = payload.chunks(1000).map(|c| c.to_vec()).collect();
                let mut iter = blocks.into_iter();
                HttpResponse::stream(200, Some(70_000), Box::new(move || Ok(iter.next())))
            }),
        )
        .unwrap();
        let client = HttpClient::new(&server.addr().to_string());
        let resp = client.get("/o", &[]).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.headers.get("content-length").unwrap(), "70000");
        assert!(
            !resp.headers.contains_key("transfer-encoding"),
            "content-length XOR transfer-encoding"
        );
        assert_eq!(resp.body, expect);
    }

    #[test]
    fn streamed_response_unknown_length_uses_chunked_te() {
        let server = HttpServer::serve(
            "127.0.0.1:0",
            2,
            Arc::new(move |_req: HttpRequest| {
                let mut n = 0;
                let mut r = HttpResponse::stream(
                    200,
                    None,
                    Box::new(move || {
                        n += 1;
                        if n <= 3 {
                            Ok(Some(vec![n as u8; 10]))
                        } else {
                            Ok(None)
                        }
                    }),
                );
                // A handler-supplied content-length must NOT leak into
                // a chunked response (satellite: never both framings).
                r.headers.insert("content-length".into(), "999".into());
                r
            }),
        )
        .unwrap();
        let client = HttpClient::new(&server.addr().to_string());
        let resp = client.get("/o", &[]).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.headers.get("transfer-encoding").unwrap(), "chunked");
        assert!(
            !resp.headers.contains_key("content-length"),
            "content-length XOR transfer-encoding"
        );
        let mut expect = Vec::new();
        for n in 1..=3u8 {
            expect.extend_from_slice(&[n; 10]);
        }
        assert_eq!(resp.body, expect);
    }

    #[test]
    fn streamed_response_short_stream_aborts_connection() {
        // A stream that dies before delivering its declared length must
        // not look like a complete body to the client.
        let server = HttpServer::serve(
            "127.0.0.1:0",
            2,
            Arc::new(move |_req: HttpRequest| {
                let mut sent = false;
                HttpResponse::stream(
                    200,
                    Some(1000),
                    Box::new(move || {
                        if sent {
                            Err(Error::Unavailable("container died mid-stream".into()))
                        } else {
                            sent = true;
                            Ok(Some(vec![7u8; 100]))
                        }
                    }),
                )
            }),
        )
        .unwrap();
        let client = HttpClient::new(&server.addr().to_string());
        match client.get("/o", &[]) {
            Err(_) => {}
            Ok(resp) => {
                assert_ne!(resp.body.len(), 1000, "short stream must not yield a full body")
            }
        }
    }

    #[test]
    fn threaded_engine_roundtrips_and_closes_per_request() {
        // The fallback engine serves the same requests but never keeps
        // connections alive (one request per connection, by design).
        let mut server = HttpServer::serve_with_options(
            "127.0.0.1:0",
            2,
            Arc::new(|req: HttpRequest| HttpResponse::bytes(200, req.body)),
            ServerLimits::default(),
            ServerOptions { engine: ServerEngine::Threaded, ..Default::default() },
        )
        .unwrap();
        assert_eq!(server.engine(), ServerEngine::Threaded);
        let client = HttpClient::new(&server.addr().to_string());
        let resp = client.put("/o", &[], b"abc").unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"abc");
        assert_eq!(
            resp.headers.get("connection").map(|s| s.as_str()),
            Some("close"),
            "threaded engine closes after every request"
        );
        // Shutdown must return promptly: the blocking accept loop is
        // unblocked by the wake connect, not by a poll timeout.
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(t0.elapsed() < std::time::Duration::from_secs(2), "shutdown stalled");
    }

    #[test]
    fn engine_parse_and_platform_resolution() {
        assert_eq!(ServerEngine::parse("reactor"), Some(ServerEngine::Reactor));
        assert_eq!(ServerEngine::parse("EPOLL"), Some(ServerEngine::Reactor));
        assert_eq!(ServerEngine::parse("threaded"), Some(ServerEngine::Threaded));
        assert_eq!(ServerEngine::parse("bogus"), None);
        if cfg!(target_os = "linux") {
            assert_eq!(ServerEngine::Reactor.resolved(), ServerEngine::Reactor);
        } else {
            assert_eq!(ServerEngine::Reactor.resolved(), ServerEngine::Threaded);
        }
    }

    #[test]
    fn client_sends_host_and_connection_headers() {
        // RFC 7230 §5.4/§6.1: every request carries Host and an
        // explicit Connection header. Captured by a hand-rolled
        // one-shot server so the exact wire bytes are visible.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let capture = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut head = Vec::new();
            let mut byte = [0u8; 1];
            while !head.ends_with(b"\r\n\r\n") {
                if s.read(&mut byte).unwrap() == 0 {
                    break;
                }
                head.push(byte[0]);
            }
            s.write_all(b"HTTP/1.1 200 OK\r\ncontent-length: 0\r\nconnection: close\r\n\r\n")
                .unwrap();
            String::from_utf8_lossy(&head).to_string()
        });
        let client = HttpClient::new(&addr.to_string());
        let resp = client.get("/probe", &[]).unwrap();
        assert_eq!(resp.status, 200);
        let head = capture.join().unwrap();
        assert!(head.contains(&format!("host: {addr}")), "missing Host header: {head}");
        assert!(head.contains("connection: "), "missing Connection header: {head}");
    }

    #[test]
    fn close_delimited_error_response_tolerated() {
        // RFC 7230 §3.3.3 case 7: a server that answers with neither
        // content-length nor chunked framing delimits the body by
        // closing the connection. Minimal/error-path servers do this;
        // the client must return the body, not an error.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let serve = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut sink = [0u8; 4096];
            let _ = s.read(&mut sink).unwrap();
            s.write_all(b"HTTP/1.1 500 Internal Server Error\r\n\r\noops").unwrap();
            // Drop closes the socket — that close IS the framing.
        });
        let client = HttpClient::new(&addr.to_string());
        let resp = client.get("/x", &[]).unwrap();
        serve.join().unwrap();
        assert_eq!(resp.status, 500);
        assert_eq!(resp.body, b"oops");
    }

    #[test]
    fn http10_request_gets_connection_close() {
        // An HTTP/1.0 request without keep-alive opt-in must be
        // answered connection: close and actually closed.
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.write_all(b"GET /hello HTTP/1.0\r\nhost: t\r\n\r\n").unwrap();
        let mut reply = Vec::new();
        stream.read_to_end(&mut reply).unwrap(); // EOF = server closed
        let text = String::from_utf8_lossy(&reply);
        assert!(text.contains("200"), "{text}");
        assert!(text.contains("connection: close"), "{text}");
        assert!(text.ends_with("world"), "{text}");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn keepalive_reuse_is_counted_by_reactor() {
        let server = echo_server();
        assert_eq!(server.engine(), ServerEngine::Reactor);
        let client = HttpClient::new(&server.addr().to_string());
        for _ in 0..4 {
            assert_eq!(client.get("/hello", &[]).unwrap().status, 200);
        }
        let reuses = server.stats().keepalive_reuses.load(Ordering::Relaxed);
        assert!(reuses >= 2, "expected keep-alive reuse on sequential requests, saw {reuses}");
        assert!(server.stats().conns_accepted.load(Ordering::Relaxed) >= 1);
    }
}
