//! HTTP/1.1 subset: server (request routing via a handler fn) + client.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::net::ThreadPool;
use crate::{Error, Result};

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    /// `Authorization: Bearer <token>` extraction. The scheme is
    /// case-insensitive per RFC 7235 §2.1 (`bearer`, `BEARER`, … all
    /// match).
    pub fn bearer_token(&self) -> Option<&str> {
        let header = self.header("authorization")?;
        let (scheme, rest) = header.split_once(|c: char| c.is_ascii_whitespace())?;
        if scheme.eq_ignore_ascii_case("bearer") {
            let token = rest.trim();
            if token.is_empty() {
                None
            } else {
                Some(token)
            }
        } else {
            None
        }
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn new(status: u16) -> Self {
        HttpResponse { status, headers: BTreeMap::new(), body: Vec::new() }
    }

    pub fn json(status: u16, body: &crate::json::Value) -> Self {
        let mut r = HttpResponse::new(status);
        r.headers.insert("content-type".into(), "application/json".into());
        r.body = crate::json::to_string(body).into_bytes();
        r
    }

    pub fn bytes(status: u16, body: Vec<u8>) -> Self {
        let mut r = HttpResponse::new(status);
        r.headers.insert("content-type".into(), "application/octet-stream".into());
        r.body = body;
        r
    }

    pub fn text(status: u16, body: &str) -> Self {
        let mut r = HttpResponse::new(status);
        r.headers.insert("content-type".into(), "text/plain".into());
        r.body = body.as_bytes().to_vec();
        r
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            204 => "No Content",
            206 => "Partial Content",
            304 => "Not Modified",
            400 => "Bad Request",
            401 => "Unauthorized",
            403 => "Forbidden",
            404 => "Not Found",
            408 => "Request Timeout",
            409 => "Conflict",
            413 => "Payload Too Large",
            416 => "Range Not Satisfiable",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            507 => "Insufficient Storage",
            _ => "Status",
        }
    }

    fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let mut head = format!("HTTP/1.1 {} {}\r\n", self.status, self.reason());
        for (k, v) in &self.headers {
            if k == "content-length" {
                continue; // emitted once below (possibly overridden)
            }
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        // A handler-set `content-length` wins over the body length: HEAD
        // responses advertise the full object size while carrying no
        // body (RFC 9110 §9.3.2). Everything else frames on the body.
        let declared = self
            .headers
            .get("content-length")
            .cloned()
            .unwrap_or_else(|| self.body.len().to_string());
        head.push_str(&format!("content-length: {declared}\r\nconnection: close\r\n\r\n"));
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body)?;
        stream.flush()
    }
}

type Handler = dyn Fn(HttpRequest) -> HttpResponse + Send + Sync + 'static;

/// Largest request body [`HttpServer::serve`] accepts: 64 MiB. A
/// client-supplied `content-length` drives a buffer allocation, so an
/// unchecked header would let one bogus request OOM the process; bigger
/// deployments pick their own cap via [`HttpServer::serve_with_limit`].
pub const DEFAULT_MAX_BODY: usize = 64 << 20;

/// Default per-connection socket read/write timeout: the slowloris
/// guard. A client that trickles (or stops sending) its request holds a
/// handler thread at most this long before the server answers `408
/// Request Timeout` and reclaims the thread; a client that stops
/// reading its response is cut off by the matching write timeout.
pub const DEFAULT_CONN_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(10);

/// Per-connection resource limits for [`HttpServer::serve_with_limits`].
#[derive(Debug, Clone, Copy)]
pub struct ServerLimits {
    /// Largest accepted request body (413 beyond).
    pub max_body: usize,
    /// Socket read/write timeout (408 on header-read expiry).
    pub conn_timeout: std::time::Duration,
}

impl Default for ServerLimits {
    fn default() -> Self {
        ServerLimits { max_body: DEFAULT_MAX_BODY, conn_timeout: DEFAULT_CONN_TIMEOUT }
    }
}

/// Threaded HTTP server.
pub struct HttpServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` ("127.0.0.1:0" for an ephemeral port) and serve with
    /// `workers` handler threads and the [`DEFAULT_MAX_BODY`] cap.
    pub fn serve(
        addr: &str,
        workers: usize,
        handler: Arc<Handler>,
    ) -> Result<HttpServer> {
        Self::serve_with_limits(addr, workers, handler, ServerLimits::default())
    }

    /// [`HttpServer::serve`] with an explicit request-body cap: any
    /// request declaring a larger `content-length` is answered `413
    /// Payload Too Large` without allocating for (or reading) its body.
    pub fn serve_with_limit(
        addr: &str,
        workers: usize,
        handler: Arc<Handler>,
        max_body: usize,
    ) -> Result<HttpServer> {
        Self::serve_with_limits(
            addr,
            workers,
            handler,
            ServerLimits { max_body, ..Default::default() },
        )
    }

    /// [`HttpServer::serve`] with explicit per-connection limits (body
    /// cap + slowloris socket timeout).
    pub fn serve_with_limits(
        addr: &str,
        workers: usize,
        handler: Arc<Handler>,
        limits: ServerLimits,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("http-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(workers);
                loop {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let handler = Arc::clone(&handler);
                            pool.execute(move || handle_conn(stream, handler, limits));
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn accept thread");
        Ok(HttpServer { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Why a request could not be parsed into an [`HttpRequest`].
enum ParseFailure {
    /// Declared `content-length` exceeds the server's cap — answered
    /// 413 without allocating for the body.
    TooLarge { declared: u64, cap: usize },
    /// The socket read timed out before a complete request arrived —
    /// the slowloris case, answered 408 so the thread is reclaimed.
    SlowClient,
    Malformed(Error),
}

/// Classify an I/O failure: a socket-timeout expiry is a slow client
/// (408), anything else is a malformed/broken request (400).
fn read_failure(e: std::io::Error) -> ParseFailure {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => ParseFailure::SlowClient,
        _ => ParseFailure::Malformed(Error::Io(e)),
    }
}

impl From<Error> for ParseFailure {
    fn from(e: Error) -> Self {
        ParseFailure::Malformed(e)
    }
}

impl From<std::io::Error> for ParseFailure {
    fn from(e: std::io::Error) -> Self {
        read_failure(e)
    }
}

fn handle_conn(mut stream: TcpStream, handler: Arc<Handler>, limits: ServerLimits) {
    // The write half gets the same timeout: a client that stops reading
    // its response must not pin a handler thread either.
    let _ = stream.set_write_timeout(Some(limits.conn_timeout));
    let peer = stream.try_clone();
    let request = match peer {
        Ok(read_half) => parse_request(read_half, limits),
        Err(e) => Err(ParseFailure::Malformed(Error::Io(e))),
    };
    let (response, unread_body) = match request {
        Ok(req) => (handler(req), 0u64),
        Err(ParseFailure::TooLarge { declared, cap }) => (
            HttpResponse::text(
                413,
                &format!("declared body of {declared} bytes exceeds the {cap}-byte limit"),
            ),
            declared,
        ),
        Err(ParseFailure::SlowClient) => (
            HttpResponse::text(
                408,
                &format!(
                    "request not received within {:?} — connection closed",
                    limits.conn_timeout
                ),
            ),
            0,
        ),
        Err(ParseFailure::Malformed(e)) => {
            (HttpResponse::text(400, &format!("bad request: {e}")), 0)
        }
    };
    let _ = response.write_to(&mut stream);
    if unread_body > 0 {
        // Drain (bounded) what the client already sent before closing:
        // closing with unread data can RST the connection and discard
        // the 413 sitting in the client's receive buffer.
        let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(2)));
        let mut sink = [0u8; 8192];
        let mut remaining = unread_body.min(1 << 20);
        while remaining > 0 {
            match stream.read(&mut sink) {
                Ok(0) | Err(_) => break,
                Ok(n) => remaining = remaining.saturating_sub(n as u64),
            }
        }
    }
}

fn parse_request(
    stream: TcpStream,
    limits: ServerLimits,
) -> std::result::Result<HttpRequest, ParseFailure> {
    let max_body = limits.max_body;
    stream.set_read_timeout(Some(limits.conn_timeout))?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.trim_end().split_whitespace();
    let method = parts.next().ok_or_else(|| Error::Net("missing method".into()))?.to_string();
    let path = parts.next().ok_or_else(|| Error::Net("missing path".into()))?.to_string();

    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    // Never trust the client's content-length with an allocation: cap
    // it BEFORE `vec![0u8; len]` — one bogus header must not OOM the
    // gateway. Parse as u64 so a length beyond usize (32-bit hosts)
    // can't wrap; a malformed value is a malformed request.
    let len: u64 = match headers.get("content-length") {
        None => 0,
        Some(v) => v
            .trim()
            .parse()
            .map_err(|_| Error::Net(format!("bad content-length '{v}'")))?,
    };
    if len > max_body as u64 {
        return Err(ParseFailure::TooLarge { declared: len, cap: max_body });
    }
    let mut body = vec![0u8; len as usize];
    if len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(HttpRequest { method, path, headers, body })
}

/// Blocking HTTP client for the CLI, tests, and remote container
/// channels.
pub struct HttpClient {
    base: String,
    /// Connect/read/write timeout; `None` blocks indefinitely (CLI use).
    timeout: Option<std::time::Duration>,
}

impl HttpClient {
    /// `base` like `127.0.0.1:8080`.
    pub fn new(base: &str) -> Self {
        HttpClient { base: base.to_string(), timeout: None }
    }

    /// A client whose connects, reads, and writes all fail after
    /// `timeout` — so a dead endpoint surfaces as an error instead of a
    /// hung dispatch thread.
    pub fn with_timeout(base: &str, timeout: std::time::Duration) -> Self {
        HttpClient { base: base.to_string(), timeout: Some(timeout) }
    }

    fn connect(&self, timeout: Option<std::time::Duration>) -> Result<TcpStream> {
        match timeout {
            None => Ok(TcpStream::connect(&self.base)?),
            Some(t) => {
                use std::net::ToSocketAddrs;
                let addr = self
                    .base
                    .to_socket_addrs()?
                    .next()
                    .ok_or_else(|| Error::Net(format!("cannot resolve '{}'", self.base)))?;
                let stream = TcpStream::connect_timeout(&addr, t)?;
                stream.set_read_timeout(Some(t))?;
                stream.set_write_timeout(Some(t))?;
                Ok(stream)
            }
        }
    }

    pub fn request(
        &self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> Result<HttpResponse> {
        self.request_with_timeout(method, path, headers, body, self.timeout)
    }

    /// [`HttpClient::request`] with a per-request timeout override: the
    /// deadline-propagation path clamps each hop's wait to the request's
    /// remaining budget instead of the client's configured default.
    pub fn request_with_timeout(
        &self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
        timeout: Option<std::time::Duration>,
    ) -> Result<HttpResponse> {
        let mut stream = self.connect(timeout)?;
        let mut head = format!("{method} {path} HTTP/1.1\r\nhost: {}\r\n", self.base);
        for (k, v) in headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str(&format!("content-length: {}\r\nconnection: close\r\n\r\n", body.len()));
        stream.write_all(head.as_bytes())?;
        stream.write_all(body)?;
        stream.flush()?;

        let mut reader = BufReader::new(stream);
        let mut status_line = String::new();
        reader.read_line(&mut status_line)?;
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Net(format!("bad status line '{status_line}'")))?;
        let mut headers = BTreeMap::new();
        loop {
            let mut h = String::new();
            reader.read_line(&mut h)?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
            }
        }
        // HEAD responses and 204/304 have no body by definition — their
        // content-length (HEAD advertises the object size) must not be
        // read off the wire.
        let bodiless = method.eq_ignore_ascii_case("HEAD") || status == 204 || status == 304;
        let len: usize = if bodiless {
            0
        } else {
            headers.get("content-length").and_then(|v| v.parse().ok()).unwrap_or(0)
        };
        let mut body = vec![0u8; len];
        if len > 0 {
            reader.read_exact(&mut body)?;
        }
        Ok(HttpResponse { status, headers, body })
    }

    pub fn get(&self, path: &str, headers: &[(&str, &str)]) -> Result<HttpResponse> {
        self.request("GET", path, headers, &[])
    }

    pub fn put(&self, path: &str, headers: &[(&str, &str)], body: &[u8]) -> Result<HttpResponse> {
        self.request("PUT", path, headers, body)
    }

    pub fn post(&self, path: &str, headers: &[(&str, &str)], body: &[u8]) -> Result<HttpResponse> {
        self.request("POST", path, headers, body)
    }

    pub fn delete(&self, path: &str, headers: &[(&str, &str)]) -> Result<HttpResponse> {
        self.request("DELETE", path, headers, &[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> HttpServer {
        HttpServer::serve(
            "127.0.0.1:0",
            2,
            Arc::new(|req: HttpRequest| {
                if req.path == "/hello" {
                    HttpResponse::text(200, "world")
                } else if req.method == "PUT" {
                    HttpResponse::bytes(201, req.body)
                } else {
                    HttpResponse::text(404, "nope")
                }
            }),
        )
        .unwrap()
    }

    #[test]
    fn get_roundtrip() {
        let server = echo_server();
        let client = HttpClient::new(&server.addr().to_string());
        let resp = client.get("/hello", &[]).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"world");
    }

    #[test]
    fn put_echoes_binary_body() {
        let server = echo_server();
        let client = HttpClient::new(&server.addr().to_string());
        let payload: Vec<u8> = (0..=255u8).cycle().take(70_000).collect();
        let resp = client.put("/obj", &[("x-test", "1")], &payload).unwrap();
        assert_eq!(resp.status, 201);
        assert_eq!(resp.body, payload, "binary body intact");
    }

    #[test]
    fn not_found_and_headers() {
        let server = echo_server();
        let client = HttpClient::new(&server.addr().to_string());
        let resp = client.get("/missing", &[]).unwrap();
        assert_eq!(resp.status, 404);
        assert_eq!(resp.headers.get("content-type").unwrap(), "text/plain");
    }

    #[test]
    fn concurrent_requests() {
        let server = echo_server();
        let addr = server.addr().to_string();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let client = HttpClient::new(&addr);
                    let body = vec![i as u8; 1000];
                    let resp = client.put("/o", &[], &body).unwrap();
                    assert_eq!(resp.body, body);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn timeout_client_still_roundtrips() {
        let server = echo_server();
        let client = HttpClient::with_timeout(
            &server.addr().to_string(),
            std::time::Duration::from_secs(5),
        );
        let resp = client.get("/hello", &[]).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"world");
    }

    #[test]
    fn timeout_client_fails_fast_on_dead_endpoint() {
        let client =
            HttpClient::with_timeout("127.0.0.1:1", std::time::Duration::from_millis(500));
        let t0 = std::time::Instant::now();
        assert!(client.get("/x", &[]).is_err());
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
    }

    #[test]
    fn bearer_token_parsing() {
        let with_auth = |value: &str| HttpRequest {
            method: "GET".into(),
            path: "/".into(),
            headers: [("authorization".to_string(), value.to_string())]
                .into_iter()
                .collect(),
            body: vec![],
        };
        assert_eq!(with_auth("Bearer abc.def").bearer_token(), Some("abc.def"));
        // RFC 7235: the scheme is case-insensitive.
        assert_eq!(with_auth("bearer abc.def").bearer_token(), Some("abc.def"));
        assert_eq!(with_auth("BEARER abc.def").bearer_token(), Some("abc.def"));
        assert_eq!(with_auth("BeArEr  spaced ").bearer_token(), Some("spaced"));
        // Other schemes and empty credentials are not bearer tokens.
        assert_eq!(with_auth("Basic dXNlcg==").bearer_token(), None);
        assert_eq!(with_auth("Bearer ").bearer_token(), None);
        assert_eq!(with_auth("Bearer").bearer_token(), None);
    }

    #[test]
    fn head_advertises_length_without_body() {
        // A handler-set content-length overrides body framing, and the
        // client must not try to read a HEAD body off the wire.
        let server = HttpServer::serve(
            "127.0.0.1:0",
            2,
            Arc::new(|req: HttpRequest| {
                if req.method == "HEAD" {
                    let mut r = HttpResponse::new(200);
                    r.headers.insert("content-length".into(), "12345".into());
                    r.headers.insert("etag".into(), "\"abc\"".into());
                    r
                } else {
                    HttpResponse::text(200, "body")
                }
            }),
        )
        .unwrap();
        let client = HttpClient::new(&server.addr().to_string());
        let head = client.request("HEAD", "/o", &[], &[]).unwrap();
        assert_eq!(head.status, 200);
        assert_eq!(head.headers.get("content-length").unwrap(), "12345");
        assert!(head.body.is_empty(), "HEAD carries no body");
        // The connection still works for normal GETs.
        let got = client.get("/o", &[]).unwrap();
        assert_eq!(got.body, b"body");
    }

    #[test]
    fn oversized_declared_body_gets_413() {
        let server = HttpServer::serve_with_limit(
            "127.0.0.1:0",
            2,
            Arc::new(|req: HttpRequest| HttpResponse::bytes(201, req.body)),
            1_000,
        )
        .unwrap();
        let client = HttpClient::new(&server.addr().to_string());
        // Under the cap: normal echo.
        let resp = client.put("/o", &[], &[7u8; 900]).unwrap();
        assert_eq!(resp.status, 201);
        // Over the cap: 413 with the right reason phrase, body unread.
        let resp = client.put("/o", &[], &[7u8; 5_000]).unwrap();
        assert_eq!(resp.status, 413);
    }

    #[test]
    fn slow_client_gets_408_and_server_survives() {
        let server = HttpServer::serve_with_limits(
            "127.0.0.1:0",
            2,
            Arc::new(|_req: HttpRequest| HttpResponse::text(200, "ok")),
            ServerLimits {
                max_body: DEFAULT_MAX_BODY,
                conn_timeout: std::time::Duration::from_millis(100),
            },
        )
        .unwrap();
        // A slowloris connection: open, trickle half a request line, stall.
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.write_all(b"GET /stalled HTT").unwrap();
        let mut reply = String::new();
        let mut reader = BufReader::new(&mut stream);
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("408"), "{reply}");
        assert!(reply.contains("Request Timeout"), "{reply}");
        // A stalled *header* section (complete request line) times out too.
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.write_all(b"GET /h HTTP/1.1\r\nhost: t\r\nx-part").unwrap();
        let mut reply = String::new();
        let mut reader = BufReader::new(&mut stream);
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("408"), "{reply}");
        // The handler thread was reclaimed: normal requests still work.
        let client = HttpClient::new(&server.addr().to_string());
        assert_eq!(client.get("/fine", &[]).unwrap().status, 200);
    }

    #[test]
    fn per_request_timeout_override() {
        let server = echo_server();
        // Client default: no timeout. Per-request: tight but sufficient.
        let client = HttpClient::new(&server.addr().to_string());
        let resp = client
            .request_with_timeout(
                "GET",
                "/hello",
                &[],
                &[],
                Some(std::time::Duration::from_secs(5)),
            )
            .unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"world");
    }

    #[test]
    fn absurd_content_length_header_rejected_without_allocation() {
        // A bogus header claiming an 8 EiB body must be answered with
        // 413, not a vec![0u8; 2^63] allocation.
        let server = HttpServer::serve(
            "127.0.0.1:0",
            1,
            Arc::new(|_req: HttpRequest| HttpResponse::new(200)),
        )
        .unwrap();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream
            .write_all(
                b"PUT /objects/x HTTP/1.1\r\nhost: t\r\ncontent-length: 9223372036854775807\r\n\r\n",
            )
            .unwrap();
        let mut reply = String::new();
        let mut reader = BufReader::new(&mut stream);
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("413"), "{reply}");
        assert!(reply.contains("Payload Too Large"), "{reply}");
        // Garbage content-length is a 400, not a silent zero.
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream
            .write_all(b"PUT /x HTTP/1.1\r\nhost: t\r\ncontent-length: banana\r\n\r\n")
            .unwrap();
        let mut reply = String::new();
        let mut reader = BufReader::new(&mut stream);
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("400"), "{reply}");
    }
}
