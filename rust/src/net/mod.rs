//! Minimal HTTP/1.1 substrate (server + client) over `std::net`.
//!
//! The paper's DynoStore exposes REST APIs over HTTP "as it is widely
//! allowed across firewalls and NATs" (§V). The vendored crate set has
//! no tokio/hyper, so this module implements the needed HTTP/1.1 subset
//! from scratch: request line + headers + Content-Length bodies, keep-
//! alive off, a fixed worker pool on the server side. It backs the
//! [`crate::gateway`] REST service and the CLI client.

mod http;
mod pool;

pub use http::{
    is_over_cap, BodyReader, BodyStream, HttpClient, HttpRequest, HttpResponse, HttpServer,
    ServerLimits, StreamHandler, DEFAULT_CONN_TIMEOUT, DEFAULT_MAX_BODY, DRAIN_BUDGET,
};
pub use pool::{JobHandle, ThreadPool};
