//! Minimal HTTP/1.1 substrate (server + client) over `std::net`.
//!
//! The paper's DynoStore exposes REST APIs over HTTP "as it is widely
//! allowed across firewalls and NATs" (§V). The vendored crate set has
//! no tokio/hyper, so this module implements the needed HTTP/1.1 subset
//! from scratch: request line + headers + Content-Length bodies, keep-
//! alive off, a fixed worker pool on the server side. It backs the
//! [`crate::gateway`] REST service and the CLI client.

mod http;
mod pool;

pub use http::{
    HttpClient, HttpRequest, HttpResponse, HttpServer, ServerLimits, DEFAULT_CONN_TIMEOUT,
    DEFAULT_MAX_BODY,
};
pub use pool::ThreadPool;
