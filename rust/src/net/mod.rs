//! HTTP/1.1 substrate (server + client) over `std::net`, with an
//! event-driven connection core.
//!
//! The paper's DynoStore exposes REST APIs over HTTP "as it is widely
//! allowed across firewalls and NATs" (§V). The vendored crate set has
//! no tokio/hyper, so this module implements the needed HTTP/1.1 subset
//! from scratch: request line + headers, `Content-Length` and chunked
//! bodies, streamed request/response bodies, and HTTP/1.1 keep-alive.
//!
//! Connection handling is pluggable ([`ServerEngine`]): the default
//! Linux engine is an epoll readiness reactor ([`reactor`]) — one event
//! loop owns every socket, complete requests are dispatched to a fixed
//! worker pool, and idle keep-alive connections cost a file descriptor
//! rather than a thread — with the original thread-per-request loop
//! kept as the portable fallback. The client side pools keep-alive
//! connections per host ([`cpool`]). Admission control (connection and
//! in-flight caps shedding `503`/`429` + `Retry-After`) bounds both.
//! It backs the [`crate::gateway`] REST service, the container agents,
//! and the CLI client.

mod cpool;
mod http;
mod pool;
#[cfg(target_os = "linux")]
mod reactor;

pub use cpool::{global as client_pool, ClientPool, PoolStats, DEFAULT_POOL_PER_HOST};
pub use http::{
    is_over_cap, BodyReader, BodyStream, HttpClient, HttpRequest, HttpResponse, HttpServer,
    NetStats, ServerEngine, ServerLimits, ServerOptions, StreamHandler, DEFAULT_CONN_TIMEOUT,
    DEFAULT_KEEPALIVE_IDLE, DEFAULT_MAX_BODY, DEFAULT_MAX_CONNECTIONS, DEFAULT_MAX_INFLIGHT,
    DRAIN_BUDGET,
};
pub use pool::{JobHandle, ThreadPool};
