//! Deterministic PRNG (xoshiro256**, seeded via SplitMix64).
//!
//! The vendored crate set has no `rand`, and the simulators, benchmarks
//! and property tests all need *reproducible* randomness anyway — every
//! experiment in EXPERIMENTS.md records its seed.

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`; bound must be > 0. Lemire-style rejection
    /// keeps it unbiased.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "Rng::below(0)");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a byte slice with pseudo-random data.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Random byte vector of length `n`.
    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        let mut v = vec![0u8; n];
        self.fill_bytes(&mut v);
        v
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }

    /// The generator's full 256-bit state (metadata snapshots persist it
    /// so a recovered store keeps drawing the same UUID sequence).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a previously captured [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(3);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn fill_bytes_handles_odd_lengths() {
        let mut r = Rng::new(9);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65] {
            assert_eq!(r.bytes(n).len(), n);
        }
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(11);
        let s = r.sample_indices(20, 7);
        assert_eq!(s.len(), 7);
        for w in s.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn state_roundtrip_resumes_sequence() {
        let mut a = Rng::new(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
