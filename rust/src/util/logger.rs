//! Minimal stderr logger backing the `log` crate facade.
//!
//! The vendored crate set has no `env_logger`; this is the small
//! equivalent: level from `DYNOSTORE_LOG` (error|warn|info|debug|trace),
//! defaulting to `info`, with a wall-clock-offset prefix.

use log::{Level, LevelFilter, Log, Metadata, Record};

struct StderrLogger {
    level: LevelFilter,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = crate::util::now_ns() as f64 / 1e9;
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:10.3}] {lvl} {}: {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger once; later calls are no-ops. Returns the level.
pub fn init() -> LevelFilter {
    let level = match std::env::var("DYNOSTORE_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Info,
    };
    let logger = Box::new(StderrLogger { level });
    if log::set_boxed_logger(logger).is_ok() {
        log::set_max_level(level);
    }
    level
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        let a = super::init();
        let b = super::init();
        // Second init is a no-op but must not panic; levels agree.
        assert_eq!(a, b);
        log::info!("logger smoke line");
    }
}
