//! Minimal self-contained stderr logger (the vendored crate set has no
//! `log`/`env_logger`, and the crate builds with zero external
//! dependencies): level from `DYNOSTORE_LOG`
//! (off|error|warn|info|debug|trace), defaulting to `info`, with a
//! wall-clock-offset prefix. Use via the [`crate::log_info!`] /
//! [`crate::log_warn!`] / [`crate::log_error!`] / [`crate::log_debug!`]
//! macros.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Log severity, ordered: a message is emitted when its level is at or
/// below the configured maximum.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Off => "OFF  ",
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Install the logger once; later calls are no-ops. Returns the level.
pub fn init() -> Level {
    static INIT: OnceLock<Level> = OnceLock::new();
    *INIT.get_or_init(|| {
        let level = match std::env::var("DYNOSTORE_LOG").as_deref() {
            Ok("off") => Level::Off,
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        };
        MAX_LEVEL.store(level as u8, Ordering::Relaxed);
        level
    })
}

/// Is `level` currently emitted?
pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed) && level != Level::Off
}

/// Emit one record (used by the `log_*!` macros; callable directly).
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = crate::util::now_ns() as f64 / 1e9;
    eprintln!("[{t:10.3}] {} {target}: {args}", level.label());
}

/// Log at INFO against the calling module's path.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logger::log(
            $crate::util::logger::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at WARN against the calling module's path.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logger::log(
            $crate::util::logger::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at ERROR against the calling module's path.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logger::log(
            $crate::util::logger::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at DEBUG against the calling module's path.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logger::log(
            $crate::util::logger::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        let a = init();
        let b = init();
        // Second init is a no-op but must not panic; levels agree.
        assert_eq!(a, b);
        crate::log_info!("logger smoke line");
    }

    #[test]
    fn levels_order_and_gate() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        init();
        // Off never prints regardless of the configured max.
        assert!(!enabled(Level::Off));
        // Trace is above the default info level.
        if init() == Level::Info {
            assert!(enabled(Level::Warn));
            assert!(!enabled(Level::Trace));
        }
    }
}
