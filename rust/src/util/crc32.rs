//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the checksum
//! framing the durability WAL's records. Table-driven, built at compile
//! time; no external crates (the vendored set has no crc32fast).

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// Continue a CRC-32 over `data` from a previous [`crc32`] result —
/// lets the WAL checksum a record's sequence header and payload without
/// concatenating them.
pub fn crc32_update(crc: u32, data: &[u8]) -> u32 {
    let mut c = !crc;
    for &b in data {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// CRC-32 of `data` (equivalent to `crc32_update(0, data)`).
pub fn crc32(data: &[u8]) -> u32 {
    crc32_update(0, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical check value of CRC-32/ISO-HDLC.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn update_composes_like_concatenation() {
        let whole = crc32(b"hello world");
        let split = crc32_update(crc32(b"hello "), b"world");
        assert_eq!(whole, split);
    }

    #[test]
    fn single_bit_flip_changes_crc() {
        let base = b"record payload".to_vec();
        let good = crc32(&base);
        for i in 0..base.len() {
            let mut bad = base.clone();
            bad[i] ^= 1;
            assert_ne!(crc32(&bad), good, "flip at byte {i}");
        }
    }
}
