//! Hex encoding/decoding (object hashes, UUIDs, token signatures).

/// Lowercase hex string of a byte slice.
pub fn to_hex(bytes: &[u8]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(HEX[(b >> 4) as usize] as char);
        s.push(HEX[(b & 0xf) as usize] as char);
    }
    s
}

/// Decode a hex string (case-insensitive); `None` on bad length/char.
pub fn from_hex(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    let nib = |c: u8| -> Option<u8> {
        match c {
            b'0'..=b'9' => Some(c - b'0'),
            b'a'..=b'f' => Some(c - b'a' + 10),
            b'A'..=b'F' => Some(c - b'A' + 10),
            _ => None,
        }
    };
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len() / 2);
    for pair in b.chunks_exact(2) {
        out.push((nib(pair[0])? << 4) | nib(pair[1])?);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let data = [0x00, 0x01, 0xab, 0xcd, 0xef, 0xff];
        let h = to_hex(&data);
        assert_eq!(h, "0001abcdefff");
        assert_eq!(from_hex(&h).unwrap(), data);
    }

    #[test]
    fn uppercase_accepted() {
        assert_eq!(from_hex("ABCD").unwrap(), vec![0xab, 0xcd]);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(from_hex("abc").is_none(), "odd length");
        assert!(from_hex("zz").is_none(), "bad char");
    }

    #[test]
    fn empty_ok() {
        assert_eq!(to_hex(&[]), "");
        assert_eq!(from_hex("").unwrap(), Vec::<u8>::new());
    }
}
