//! Small shared utilities: deterministic PRNG, hex, byte-size formatting,
//! monotonic wall time, and a minimal stderr logger.

pub mod crc32;
pub mod hexfmt;
pub mod logger;
pub mod rng;

pub use crc32::{crc32, crc32_update};
pub use hexfmt::{from_hex, to_hex};
pub use rng::Rng;

/// Monotonic nanoseconds since process start (real wall clock).
pub fn now_ns() -> u64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static START: OnceLock<Instant> = OnceLock::new();
    let start = *START.get_or_init(Instant::now);
    Instant::now().duration_since(start).as_nanos() as u64
}

/// Unix epoch seconds (used for token expiry and version GC timestamps).
pub fn unix_secs() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Human-readable byte size, e.g. `1.50 MiB`.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[unit])
    }
}

/// Human-readable duration from nanoseconds, e.g. `3.21 ms`.
pub fn human_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2} s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1536), "1.50 KiB");
        assert_eq!(human_bytes(10 * 1024 * 1024), "10.00 MiB");
        assert_eq!(human_bytes(3 * 1024 * 1024 * 1024), "3.00 GiB");
    }

    #[test]
    fn human_ns_units() {
        assert_eq!(human_ns(42), "42 ns");
        assert_eq!(human_ns(42_000), "42.00 us");
        assert_eq!(human_ns(42_000_000), "42.00 ms");
        assert_eq!(human_ns(1_500_000_000), "1.50 s");
    }

    #[test]
    fn now_ns_is_monotonic() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }
}
