//! Paxos-based metadata replication (paper §III-C, §IV-B).
//!
//! The paper replicates the metadata service and coordinates updates
//! with Paxos: a proposer sends the object's UUID + a timestamp to the
//! replicas, each replica accepts if the timestamp is newer than its
//! last recorded update, and on a majority of acceptances the proposer
//! commits and broadcasts. Reads are locked while an update is in
//! flight, giving strong read-after-write consistency.
//!
//! [`PaxosGroup`] implements single-decree Paxos per log slot (prepare /
//! promise with ballot, accept / accepted, choose on majority) over
//! in-process acceptors with failure injection. [`ReplicatedMeta`]
//! layers the metadata state machine on top: commands are serialized to
//! JSON, sequenced through the Paxos log, and applied to every replica
//! in slot order. Replica state machines are deterministic (seeded UUID
//! generation), so all replicas converge to identical stores.

//! The sharded plane ([`ShardedMeta`]) scales this out: N independent
//! Paxos groups, each owning a consistent-hash arc of the namespace
//! keyspace ([`crate::metadata::Ring`]), so distinct namespaces commit
//! through distinct groups concurrently while every shard keeps the
//! single-group guarantees above.

mod group;
mod replicated;
mod sharded;

pub use group::{Acceptor, PaxosGroup};
pub use replicated::{CommandOutcome, MetaCommand, ReplicatedMeta};
pub use sharded::{shard_seed, ShardedMeta};
