//! The sharded metadata plane: N independent Paxos groups behind one
//! router.
//!
//! One [`ReplicatedMeta`] serializes every mutation in the deployment
//! through a single Paxos log — O(deployment) coordination that walls
//! well before the ROADMAP's millions-of-objects target. [`ShardedMeta`]
//! splits the catalog by *namespace*: a consistent-hash ring
//! ([`crate::metadata::Ring`]) maps the namespace owner (the first path
//! segment of a collection path) to one of N shards, and that shard's
//! Paxos group alone sequences, logs, and snapshots everything under
//! the namespace. Distinct namespaces on distinct shards commit
//! concurrently, recover in parallel, and fail independently: a torn
//! WAL tail or poisoned log on one shard degrades only that shard's
//! namespaces.
//!
//! Routing by namespace (not full collection path) is load-bearing:
//! permission checks walk the ancestor collection chain and
//! `create_collection` requires its parent, so a namespace must be
//! wholly shard-local for every single-group invariant — including
//! `submit_guarded`'s precheck-inside-the-commit-lock — to carry over
//! unchanged per shard.
//!
//! # Cross-shard contract (weaker, documented)
//!
//! Anything confined to one namespace keeps the full §IV-B guarantees
//! (strong read-after-write, linearizable commits). Operations that
//! span shards are **per-shard snapshot-consistent, globally
//! best-effort**:
//!
//! * [`ShardedMeta::read`] answers from the first shard that returns
//!   `Ok` — use [`ShardedMeta::read_at`] (or `read_upload` /
//!   `read_uuid`) when the closure targets a specific namespace,
//!   upload, or object.
//! * [`ShardedMeta::all_objects`] and [`ShardedMeta::global_page`]
//!   merge per-shard views taken at different instants; each shard's
//!   slice is consistent, the union is not a single cut.
//! * `Gc` broadcasts to every shard and merges the collected records;
//!   shards that fail are skipped (their retention clock just keeps
//!   ticking until a later pass).
//!
//! With one shard (`meta_shards = 1`, the default) every method
//! delegates straight to the single group and behavior is
//! byte-identical to the unsharded plane.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::metadata::{namespace_owner, normalize_path, MetadataStore, ObjectMeta, ObjectPage, Ring};
use crate::paxos::{CommandOutcome, MetaCommand, ReplicatedMeta};
use crate::{Error, Result};

/// Per-shard seed derivation: shard 0 keeps the deployment seed (so a
/// single-shard `ShardedMeta` is byte-identical to the legacy plane),
/// higher shards offset by the 64-bit golden ratio so their UUID
/// streams are disjoint.
pub fn shard_seed(seed: u64, shard: usize) -> u64 {
    seed.wrapping_add((shard as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Where a command must commit.
enum Route {
    Shard(usize),
    /// `Gc` touches every shard's catalog.
    Broadcast,
}

/// Key→shard routing index for commands addressed by upload id or
/// object UUID — neither carries a collection path, so without an index
/// every such command pays an O(shards) scan of the replicated stores.
///
/// The index is *derived state*, not a second source of truth: it is
/// seeded from each shard's committed catalog at assembly
/// ([`MetadataStore::routing_keys`]), updated from committed submit
/// outcomes (`PutObject`/`MultipartInit` insert, `Complete`/`Abort`/
/// `Evict`/`Gc` retire), and any miss falls back to the legacy scan,
/// caching what the scan finds. A stale entry is harmless: the command
/// fails on the indexed shard exactly as it would have failed on shard
/// 0 after a scan miss (the key is gone from every shard).
struct RouteIndex {
    /// `uuid → shard` for object versions, `upload id → shard` for
    /// open multipart uploads (ids come from disjoint RNG streams and
    /// never collide; one map keeps the lock footprint minimal).
    keys: RwLock<HashMap<String, usize>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl RouteIndex {
    fn new() -> RouteIndex {
        RouteIndex {
            keys: RwLock::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    fn get(&self, key: &str) -> Option<usize> {
        self.keys.read().unwrap().get(key).copied()
    }

    fn insert(&self, key: &str, shard: usize) {
        self.keys.write().unwrap().insert(key.to_string(), shard);
    }

    fn remove(&self, key: &str) {
        self.keys.write().unwrap().remove(key);
    }
}

/// Router over N independent [`ReplicatedMeta`] Paxos groups.
pub struct ShardedMeta {
    shards: Vec<Arc<ReplicatedMeta>>,
    ring: Ring,
    /// Commands committed through each shard's group since this process
    /// started (the `/metrics` per-shard commit counters — and the test
    /// hook proving distinct namespaces use distinct groups).
    commits: Vec<AtomicU64>,
    /// uuid/upload-id → shard routing (empty and unused for a single
    /// shard, where routing is trivial and behavior stays legacy).
    routes: RouteIndex,
}

impl ShardedMeta {
    /// In-memory sharded plane: `shard_count` groups of `replica_count`
    /// replicas each (tests, benches, simulators).
    pub fn memory(shard_count: usize, replica_count: usize, seed: u64) -> Arc<Self> {
        let shard_count = shard_count.max(1);
        Self::from_groups(
            (0..shard_count)
                .map(|i| ReplicatedMeta::new(replica_count, shard_seed(seed, i)))
                .collect(),
        )
    }

    /// Wrap one existing group as a single-shard plane — the legacy
    /// durable layout stays byte-identical because every call delegates
    /// straight to it.
    pub fn single(group: Arc<ReplicatedMeta>) -> Arc<Self> {
        Self::from_groups(vec![group])
    }

    /// Assemble the router from already-opened groups (the coordinator
    /// builds durable shards with [`ReplicatedMeta::durable_keyed`] and
    /// hands them over here). All groups must have the same replica
    /// count.
    pub fn from_groups(shards: Vec<Arc<ReplicatedMeta>>) -> Arc<Self> {
        assert!(!shards.is_empty(), "at least one metadata shard");
        assert!(
            shards.iter().all(|s| s.replica_count() == shards[0].replica_count()),
            "uniform replica count across shards"
        );
        let ring = Ring::new(shards.len());
        let commits = (0..shards.len()).map(|_| AtomicU64::new(0)).collect();
        let routes = RouteIndex::new();
        if shards.len() > 1 {
            // Seed from each shard's committed catalog (durable restarts
            // arrive with populated stores); a shard that can't answer
            // just leaves its keys to the scan-and-cache fallback.
            for (i, s) in shards.iter().enumerate() {
                if let Ok((uuids, uploads)) = s.read(|st| Ok(st.routing_keys())) {
                    let mut map = routes.keys.write().unwrap();
                    for u in uuids {
                        map.insert(u, i);
                    }
                    for u in uploads {
                        map.insert(u, i);
                    }
                }
            }
        }
        Arc::new(ShardedMeta { shards, ring, commits, routes })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard owning a collection path (by its namespace owner).
    /// Unparseable paths route to shard 0, where the command fails with
    /// the same validation error the unsharded plane would produce.
    pub fn shard_of(&self, collection: &str) -> usize {
        match normalize_path(collection) {
            Ok(p) => self.ring.route(namespace_owner(&p)),
            Err(_) => 0,
        }
    }

    /// One shard's group (health/metrics surfaces, tests).
    pub fn shard(&self, i: usize) -> &Arc<ReplicatedMeta> {
        &self.shards[i]
    }

    /// Commands committed through shard `i` since process start.
    pub fn shard_commits(&self, i: usize) -> u64 {
        self.commits[i].load(Ordering::Relaxed)
    }

    /// Which shard holds an open upload: the route index answers in
    /// O(1); a miss (index evicted, seeded before this key existed)
    /// falls back to the legacy scan and caches what it finds. A key on
    /// no shard (completed/aborted meanwhile, or never existed) routes
    /// to shard 0, where the command fails with the legacy NotFound.
    fn shard_with_upload(&self, id: &str) -> usize {
        self.shard_with_key(id, |st, key| st.has_upload(key))
    }

    /// Which shard holds an object version, by UUID (same contract as
    /// [`Self::shard_with_upload`]).
    fn shard_with_uuid(&self, uuid: &str) -> usize {
        self.shard_with_key(uuid, |st, key| st.has_uuid(key))
    }

    fn shard_with_key(
        &self,
        key: &str,
        has: impl Fn(&MetadataStore, &str) -> bool,
    ) -> usize {
        if self.shards.len() <= 1 {
            return 0;
        }
        if let Some(i) = self.routes.get(key) {
            self.routes.hits.fetch_add(1, Ordering::Relaxed);
            return i;
        }
        self.routes.misses.fetch_add(1, Ordering::Relaxed);
        for (i, s) in self.shards.iter().enumerate() {
            if s.read(|st| Ok(has(st, key))).unwrap_or(false) {
                self.routes.insert(key, i);
                return i;
            }
        }
        0
    }

    /// Route-index hit/miss counters since process start (`/metrics`).
    pub fn route_index_stats(&self) -> (u64, u64, usize) {
        (
            self.routes.hits.load(Ordering::Relaxed),
            self.routes.misses.load(Ordering::Relaxed),
            self.routes.keys.read().unwrap().len(),
        )
    }

    /// Fold a committed outcome into the route index: keys are born on
    /// `PutObject`/`MultipartInit`, move from upload to uuid on
    /// `MultipartComplete`, and die on `Abort`/`Evict` (`Gc` retires
    /// its keys in the broadcast arm).
    fn index_outcome(&self, cmd: &MetaCommand, out: &CommandOutcome, shard: usize) {
        if self.shards.len() <= 1 {
            return;
        }
        match (cmd, out) {
            (MetaCommand::PutObject { .. }, CommandOutcome::Meta(m)) => {
                self.routes.insert(&m.uuid, shard);
            }
            (MetaCommand::MultipartInit { .. }, CommandOutcome::UploadId(id)) => {
                self.routes.insert(id, shard);
            }
            (MetaCommand::MultipartComplete { upload_id, .. }, CommandOutcome::Meta(m)) => {
                self.routes.remove(upload_id);
                self.routes.insert(&m.uuid, shard);
            }
            (MetaCommand::MultipartAbort { upload_id, .. }, CommandOutcome::Aborted(_)) => {
                self.routes.remove(upload_id);
            }
            (MetaCommand::Evict { .. }, CommandOutcome::Evicted(metas)) => {
                for m in metas {
                    self.routes.remove(&m.uuid);
                }
            }
            _ => {}
        }
    }

    fn route(&self, cmd: &MetaCommand) -> Route {
        match cmd {
            MetaCommand::CreateNamespace { user } => Route::Shard(self.ring.route(user)),
            MetaCommand::CreateCollection { path, .. }
            | MetaCommand::Grant { path, .. }
            | MetaCommand::Revoke { path, .. } => Route::Shard(self.shard_of(path)),
            MetaCommand::PutObject { collection, .. }
            | MetaCommand::Evict { collection, .. }
            | MetaCommand::MultipartInit { collection, .. } => {
                Route::Shard(self.shard_of(collection))
            }
            MetaCommand::Gc { .. } => Route::Broadcast,
            MetaCommand::UpdatePlacement { uuid, .. } => {
                Route::Shard(self.shard_with_uuid(uuid))
            }
            MetaCommand::MultipartPut { upload_id, .. }
            | MetaCommand::MultipartComplete { upload_id, .. }
            | MetaCommand::MultipartAbort { upload_id, .. } => {
                Route::Shard(self.shard_with_upload(upload_id))
            }
        }
    }

    /// Propose a command through its owning shard's Paxos group.
    pub fn submit(&self, cmd: MetaCommand) -> Result<CommandOutcome> {
        self.submit_guarded(cmd, || Ok(()))
    }

    /// Like [`Self::submit`], but run `precheck` under the owning
    /// shard's exclusive commit lock first — the single-group
    /// precheck-inside-the-lock semantics, preserved per shard.
    pub fn submit_guarded(
        &self,
        cmd: MetaCommand,
        precheck: impl FnOnce() -> Result<()>,
    ) -> Result<CommandOutcome> {
        match self.route(&cmd) {
            Route::Shard(i) => {
                let out = self.shards[i].submit_guarded(cmd.clone(), precheck)?;
                self.commits[i].fetch_add(1, Ordering::Relaxed);
                self.index_outcome(&cmd, &out, i);
                Ok(out)
            }
            Route::Broadcast => {
                precheck()?;
                let mut collected: Vec<ObjectMeta> = Vec::new();
                let mut first_err: Option<Error> = None;
                let mut any_ok = false;
                for (i, s) in self.shards.iter().enumerate() {
                    match s.submit(cmd.clone()) {
                        Ok(out) => {
                            any_ok = true;
                            self.commits[i].fetch_add(1, Ordering::Relaxed);
                            if let CommandOutcome::Collected(mut v) = out {
                                collected.append(&mut v);
                            }
                        }
                        Err(e) => {
                            if first_err.is_none() {
                                first_err = Some(e);
                            }
                        }
                    }
                }
                if self.shards.len() > 1 {
                    for m in &collected {
                        self.routes.remove(&m.uuid);
                    }
                }
                match (any_ok, first_err) {
                    // Every shard refused (with one shard this is the
                    // legacy error, verbatim).
                    (false, Some(e)) => Err(e),
                    _ => Ok(CommandOutcome::Collected(collected)),
                }
            }
        }
    }

    /// Best-effort unrouted read: the first shard that answers `Ok`
    /// wins. Correct for shard-agnostic closures; anything keyed to a
    /// namespace, upload, or UUID should use [`Self::read_at`],
    /// [`Self::read_upload`], or [`Self::read_uuid`]. When every shard
    /// errors, `Unavailable` (a shard that *might* hold the answer is
    /// down) outranks `NotFound`, which outranks the rest.
    pub fn read<T>(&self, f: impl Fn(&MetadataStore) -> Result<T>) -> Result<T> {
        if self.shards.len() == 1 {
            return self.shards[0].read(f);
        }
        let mut unavailable: Option<Error> = None;
        let mut not_found: Option<Error> = None;
        let mut other: Option<Error> = None;
        for s in &self.shards {
            match s.read(&f) {
                Ok(v) => return Ok(v),
                Err(e) => match e {
                    Error::Unavailable(_) if unavailable.is_none() => unavailable = Some(e),
                    Error::NotFound(_) if not_found.is_none() => not_found = Some(e),
                    _ if other.is_none() => other = Some(e),
                    _ => {}
                },
            }
        }
        Err(unavailable
            .or(not_found)
            .or(other)
            .expect("at least one shard produced an error"))
    }

    /// Read against the shard owning `collection` — full single-group
    /// read semantics for namespace-local queries.
    pub fn read_at<T>(
        &self,
        collection: &str,
        f: impl Fn(&MetadataStore) -> Result<T>,
    ) -> Result<T> {
        self.shards[self.shard_of(collection)].read(f)
    }

    /// Read against the shard owning upload `id`.
    pub fn read_upload<T>(
        &self,
        id: &str,
        f: impl Fn(&MetadataStore) -> Result<T>,
    ) -> Result<T> {
        self.shards[self.shard_with_upload(id)].read(f)
    }

    /// Read against the shard holding object version `uuid`.
    pub fn read_uuid<T>(
        &self,
        uuid: &str,
        f: impl Fn(&MetadataStore) -> Result<T>,
    ) -> Result<T> {
        self.shards[self.shard_with_uuid(uuid)].read(f)
    }

    /// Every live object version across all shards, uuid-sorted. Fails
    /// if any shard can't answer — repair/scrub sweeps need the full
    /// census or none. Cross-shard contract: each shard's slice is a
    /// consistent cut, the union is not.
    pub fn all_objects(&self) -> Result<Vec<ObjectMeta>> {
        let mut out: Vec<ObjectMeta> = Vec::new();
        for s in &self.shards {
            out.extend(s.read(|st| Ok(st.all_objects()))?);
        }
        out.sort_by(|a, b| a.uuid.cmp(&b.uuid));
        Ok(out)
    }

    /// Open multipart uploads across all shards (the `multipart_open`
    /// gauge); shards that can't answer contribute 0.
    pub fn open_upload_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read(|st| Ok(st.open_upload_count())).unwrap_or(0))
            .sum()
    }

    /// Merged global listing page: uuid-keyset pagination across every
    /// shard. Each shard contributes its records after the cursor; the
    /// merge re-sorts by uuid, so `after = objects.last().uuid` resumes
    /// stably — uuid order never changes under interleaved writes.
    /// Cross-shard contract: per-shard snapshot-consistent, globally
    /// best-effort.
    pub fn global_page(&self, after: Option<&str>, limit: usize) -> Result<ObjectPage> {
        let fetch = limit.saturating_add(1);
        let mut merged: Vec<ObjectMeta> = Vec::new();
        for s in &self.shards {
            merged.extend(s.read(|st| Ok(st.objects_after(after, fetch)))?);
        }
        merged.sort_by(|a, b| a.uuid.cmp(&b.uuid));
        let truncated = merged.len() > limit;
        merged.truncate(limit);
        Ok(ObjectPage { objects: merged, truncated })
    }

    /// Crash/revive replica `id` in EVERY shard's group (chaos hooks
    /// model machine-level failure: one machine hosts replica `id` of
    /// every shard).
    pub fn set_replica_alive(&self, id: usize, alive: bool) {
        for s in &self.shards {
            s.set_replica_alive(id, alive);
        }
    }

    /// Replicas per shard group (uniform across shards).
    pub fn replica_count(&self) -> usize {
        self.shards[0].replica_count()
    }

    /// Direct store access on shard 0 (tests; with one shard this is
    /// the whole catalog, the legacy contract).
    pub fn replica_store(&self, id: usize) -> &MetadataStore {
        self.shards[0].replica_store(id)
    }

    /// Shard 0's applied cursor (tests, legacy contract).
    pub fn applied_cursor(&self, id: usize) -> u64 {
        self.shards[0].applied_cursor(id)
    }

    pub fn is_durable(&self) -> bool {
        self.shards[0].is_durable()
    }

    /// Total WAL records across shards (the `/health` aggregate).
    pub fn wal_len(&self) -> u64 {
        self.shards.iter().map(|s| s.wal_len()).sum()
    }

    /// Oldest per-shard snapshot time (0 if any shard never snapshot) —
    /// the conservative aggregate for the legacy `/health` field.
    pub fn last_snapshot_unix(&self) -> u64 {
        self.shards.iter().map(|s| s.last_snapshot_unix()).min().unwrap_or(0)
    }

    /// Total commands ever committed across shards and restarts.
    pub fn committed_seq(&self) -> u64 {
        self.shards.iter().map(|s| s.committed_seq()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metadata::ObjectPlacement;

    fn put_cmd(col: &str, name: &str, t: u64) -> MetaCommand {
        MetaCommand::PutObject {
            caller: namespace_owner(col).to_string(),
            collection: col.into(),
            name: name.into(),
            size: 42,
            sha3: [7; 32],
            placement: ObjectPlacement::Single { container: 1 },
            now: t,
        }
    }

    /// Find `n` users the ring spreads over distinct shards.
    fn users_on_distinct_shards(m: &ShardedMeta, n: usize) -> Vec<String> {
        let mut by_shard: Vec<Option<String>> = vec![None; m.shard_count()];
        for i in 0.. {
            let user = format!("User{i}");
            let shard = m.shard_of(&format!("/{user}"));
            if by_shard[shard].is_none() {
                by_shard[shard] = Some(user);
            }
            if by_shard.iter().filter(|u| u.is_some()).count() >= n {
                break;
            }
        }
        by_shard.into_iter().flatten().take(n).collect()
    }

    #[test]
    fn single_shard_is_byte_identical_to_replicated_meta() {
        let sharded = ShardedMeta::memory(1, 3, 99);
        let legacy = ReplicatedMeta::new(3, 99);
        let cmds = [
            MetaCommand::CreateNamespace { user: "UserA".into() },
            put_cmd("/UserA", "o1", 1),
            put_cmd("/UserA", "o2", 2),
            MetaCommand::Evict {
                caller: "UserA".into(),
                collection: "/UserA".into(),
                name: "o1".into(),
            },
        ];
        for cmd in &cmds {
            sharded.submit(cmd.clone()).unwrap();
            legacy.submit(cmd.clone()).unwrap();
        }
        assert_eq!(
            crate::json::to_string(&sharded.replica_store(0).snapshot_value()),
            crate::json::to_string(&legacy.replica_store(0).snapshot_value()),
        );
    }

    #[test]
    fn distinct_namespaces_commit_through_distinct_groups() {
        let m = ShardedMeta::memory(4, 3, 7);
        let users = users_on_distinct_shards(&m, 3);
        assert!(users.len() >= 2, "ring spreads namespaces");
        for u in &users {
            m.submit(MetaCommand::CreateNamespace { user: u.clone() }).unwrap();
            m.submit(put_cmd(&format!("/{u}"), "obj", 1)).unwrap();
        }
        // Each user's commits landed on their own shard — and ONLY
        // there: per-shard commit counters match, untouched shards are
        // zero.
        let mut touched = 0;
        for i in 0..m.shard_count() {
            let expected =
                users.iter().filter(|u| m.shard_of(&format!("/{u}")) == i).count() as u64;
            assert_eq!(m.shard_commits(i), expected * 2, "shard {i}");
            if expected > 0 {
                touched += 1;
            }
        }
        assert!(touched >= 2);
        // Routed reads see each namespace with full strength.
        for u in &users {
            let meta = m
                .read_at(&format!("/{u}"), |s| s.get_latest(u, &format!("/{u}"), "obj"))
                .unwrap();
            assert_eq!(meta.size, 42);
        }
    }

    #[test]
    fn whole_namespace_routes_to_one_shard() {
        let m = ShardedMeta::memory(4, 3, 7);
        let shard = m.shard_of("/UserA");
        assert_eq!(m.shard_of("/UserA/Col"), shard);
        assert_eq!(m.shard_of("/UserA/Col/Deep/Nested"), shard);
        // Nested collections (parent lookups, inherited ACLs) therefore
        // work exactly as unsharded.
        m.submit(MetaCommand::CreateNamespace { user: "UserA".into() }).unwrap();
        m.submit(MetaCommand::CreateCollection {
            caller: "UserA".into(),
            path: "/UserA/Col".into(),
        })
        .unwrap();
        m.submit(put_cmd("/UserA/Col", "o", 1)).unwrap();
        let meta = m.read_at("/UserA/Col", |s| s.get_latest("UserA", "/UserA/Col", "o"));
        assert!(meta.is_ok());
    }

    #[test]
    fn upload_and_uuid_commands_route_by_index() {
        let m = ShardedMeta::memory(4, 3, 7);
        let users = users_on_distinct_shards(&m, 2);
        for u in &users {
            m.submit(MetaCommand::CreateNamespace { user: u.clone() }).unwrap();
        }
        let (ua, ub) = (&users[0], &users[1]);
        // Open an upload in ua's namespace, then address it purely by
        // upload id — the router must find the owning shard.
        let id = match m
            .submit(MetaCommand::MultipartInit {
                caller: ua.clone(),
                collection: format!("/{ua}"),
                name: "big".into(),
                now: 1,
            })
            .unwrap()
        {
            CommandOutcome::UploadId(id) => id,
            other => panic!("unexpected outcome {other:?}"),
        };
        let up = m.read_upload(&id, |s| s.multipart_parts(ua, &id)).unwrap();
        assert_eq!(up.name, "big");
        match m
            .submit(MetaCommand::MultipartAbort { caller: ua.clone(), upload_id: id.clone() })
            .unwrap()
        {
            CommandOutcome::Aborted(_) => {}
            other => panic!("unexpected outcome {other:?}"),
        }
        // UUID-addressed placement update on ub's shard.
        let meta = match m.submit(put_cmd(&format!("/{ub}"), "obj", 1)).unwrap() {
            CommandOutcome::Meta(meta) => meta,
            other => panic!("unexpected outcome {other:?}"),
        };
        let out = m
            .submit(MetaCommand::UpdatePlacement {
                uuid: meta.uuid.clone(),
                placement: ObjectPlacement::Single { container: 9 },
                expect: Some(meta.placement.clone()),
            })
            .unwrap();
        assert!(matches!(out, CommandOutcome::Ok));
        let read = m.read_uuid(&meta.uuid, |s| s.get_by_uuid(&meta.uuid)).unwrap();
        assert_eq!(read.placement, ObjectPlacement::Single { container: 9 });
        // Every routed lookup above was answered by the index, not a
        // per-shard scan: the only misses allowed are for keys that
        // exist on no shard.
        let (hits, misses, len) = m.route_index_stats();
        assert!(hits >= 4, "read_upload/abort/update/read_uuid all hit: {hits}");
        assert_eq!(misses, 0);
        assert_eq!(len, 1, "upload retired on abort, uuid still live");
        // A bogus upload id misses the index, falls back to the scan,
        // and lands on shard 0 failing like the unsharded plane.
        let err = m
            .submit(MetaCommand::MultipartAbort {
                caller: ua.clone(),
                upload_id: "no-such-upload".into(),
            })
            .unwrap();
        assert!(matches!(err, CommandOutcome::Failed(_)));
        let (_, misses, _) = m.route_index_stats();
        assert_eq!(misses, 1);
    }

    #[test]
    fn route_index_reseeds_from_committed_catalogs() {
        // Simulate a restart: commit through one router, then assemble
        // a fresh router over the same groups. The new index must be
        // seeded from the shard stores — uuid lookups hit immediately.
        let m = ShardedMeta::memory(4, 3, 7);
        let users = users_on_distinct_shards(&m, 2);
        let mut uuids = Vec::new();
        for u in &users {
            m.submit(MetaCommand::CreateNamespace { user: u.clone() }).unwrap();
            match m.submit(put_cmd(&format!("/{u}"), "obj", 1)).unwrap() {
                CommandOutcome::Meta(meta) => uuids.push(meta.uuid.clone()),
                other => panic!("unexpected outcome {other:?}"),
            }
        }
        let reborn =
            ShardedMeta::from_groups((0..m.shard_count()).map(|i| m.shard(i).clone()).collect());
        let (_, _, len) = reborn.route_index_stats();
        assert_eq!(len, uuids.len(), "seeded from committed catalogs");
        for uuid in &uuids {
            let read = reborn.read_uuid(uuid, |s| s.get_by_uuid(uuid)).unwrap();
            assert_eq!(read.size, 42);
        }
        let (hits, misses, _) = reborn.route_index_stats();
        assert_eq!(hits, uuids.len() as u64);
        assert_eq!(misses, 0);
        // Eviction retires the key on the reborn router too.
        let u0 = &users[0];
        reborn
            .submit(MetaCommand::Evict {
                caller: u0.clone(),
                collection: format!("/{u0}"),
                name: "obj".into(),
            })
            .unwrap();
        let (_, _, len) = reborn.route_index_stats();
        assert_eq!(len, uuids.len() - 1);
    }

    #[test]
    fn gc_broadcasts_and_merges_collected_records() {
        let m = ShardedMeta::memory(4, 3, 7);
        let users = users_on_distinct_shards(&m, 2);
        for u in &users {
            m.submit(MetaCommand::CreateNamespace { user: u.clone() }).unwrap();
            // Two versions: v0 superseded at t=10, collectible.
            m.submit(put_cmd(&format!("/{u}"), "obj", 5)).unwrap();
            m.submit(put_cmd(&format!("/{u}"), "obj", 10)).unwrap();
        }
        let out = m.submit(MetaCommand::Gc { now: 100, retention_secs: 50 }).unwrap();
        match out {
            CommandOutcome::Collected(recs) => {
                assert_eq!(recs.len(), users.len(), "one superseded version per namespace");
            }
            other => panic!("unexpected outcome {other:?}"),
        }
    }

    #[test]
    fn global_page_merges_shards_with_stable_cursors() {
        let m = ShardedMeta::memory(4, 3, 7);
        let users = users_on_distinct_shards(&m, 3);
        let mut expected = 0;
        for u in &users {
            m.submit(MetaCommand::CreateNamespace { user: u.clone() }).unwrap();
            for i in 0..4 {
                m.submit(put_cmd(&format!("/{u}"), &format!("o{i}"), i)).unwrap();
                expected += 1;
            }
        }
        // Walk the merged listing with a page size that straddles shard
        // boundaries; the union must be exact and uuid-sorted.
        let mut seen: Vec<String> = Vec::new();
        let mut after: Option<String> = None;
        loop {
            let page = m.global_page(after.as_deref(), 5).unwrap();
            for o in &page.objects {
                seen.push(o.uuid.clone());
            }
            if !page.truncated {
                break;
            }
            after = Some(seen.last().unwrap().clone());
        }
        assert_eq!(seen.len(), expected);
        let mut sorted = seen.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(seen, sorted, "uuid-sorted, duplicate-free walk");
        // Matches the unpaged census.
        let all = m.all_objects().unwrap();
        assert_eq!(all.len(), expected);
        assert_eq!(all.iter().map(|o| o.uuid.clone()).collect::<Vec<_>>(), seen);
    }

    #[test]
    fn replica_failure_spans_every_shard() {
        let m = ShardedMeta::memory(2, 3, 7);
        let users = users_on_distinct_shards(&m, 2);
        // Kill a minority replica on every shard: all namespaces still
        // commit.
        m.set_replica_alive(2, false);
        for u in &users {
            m.submit(MetaCommand::CreateNamespace { user: u.clone() }).unwrap();
        }
        // Kill a majority: every shard refuses.
        m.set_replica_alive(1, false);
        let err = m.submit(MetaCommand::CreateNamespace { user: "Late".into() });
        assert!(matches!(err, Err(Error::Consensus(_))));
        m.set_replica_alive(1, true);
        m.set_replica_alive(2, true);
    }

    #[test]
    fn aggregates_sum_over_shards() {
        let m = ShardedMeta::memory(3, 3, 7);
        assert!(!m.is_durable());
        assert_eq!(m.wal_len(), 0);
        assert_eq!(m.committed_seq(), 0);
        assert_eq!(m.last_snapshot_unix(), 0);
        assert_eq!(m.replica_count(), 3);
        assert_eq!(m.open_upload_count(), 0);
    }
}
