//! Single-decree Paxos per log slot, over in-process acceptors.
//!
//! Message passing is direct method invocation; failure injection drops
//! "messages" to dead acceptors (the paper's partial-failure scenario).
//! Ballot numbers encode (round, proposer id) so concurrent proposers
//! never tie.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::{Error, Result};

/// Chosen-value log entry (opaque payload; `ReplicatedMeta` stores JSON).
type Value = String;

#[derive(Debug, Default, Clone)]
struct SlotState {
    /// Highest ballot promised (phase 1).
    promised: u64,
    /// Highest-ballot accepted proposal (phase 2): (ballot, value).
    accepted: Option<(u64, Value)>,
    /// Learned chosen value.
    chosen: Option<Value>,
}

/// One Paxos acceptor (a metadata replica's consensus half).
pub struct Acceptor {
    pub id: usize,
    alive: AtomicBool,
    slots: Mutex<HashMap<u64, SlotState>>,
}

impl Acceptor {
    fn new(id: usize) -> Arc<Self> {
        Arc::new(Acceptor { id, alive: AtomicBool::new(true), slots: Mutex::new(HashMap::new()) })
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    /// Simulate crash / recovery. State survives (crash-recovery model
    /// with persistent acceptor state, as Paxos requires).
    pub fn set_alive(&self, alive: bool) {
        self.alive.store(alive, Ordering::SeqCst);
    }

    /// Phase 1: prepare(ballot). Returns promise + previously accepted
    /// proposal, or None if the "message is dropped" (dead) or rejected.
    fn prepare(&self, slot: u64, ballot: u64) -> Option<Option<(u64, Value)>> {
        if !self.is_alive() {
            return None;
        }
        let mut slots = self.slots.lock().unwrap();
        let st = slots.entry(slot).or_default();
        if ballot > st.promised {
            st.promised = ballot;
            Some(st.accepted.clone())
        } else {
            None
        }
    }

    /// Phase 2: accept(ballot, value). True iff accepted.
    fn accept(&self, slot: u64, ballot: u64, value: &Value) -> bool {
        if !self.is_alive() {
            return false;
        }
        let mut slots = self.slots.lock().unwrap();
        let st = slots.entry(slot).or_default();
        if ballot >= st.promised {
            st.promised = ballot;
            st.accepted = Some((ballot, value.clone()));
            true
        } else {
            false
        }
    }

    /// Learn broadcast.
    fn learn(&self, slot: u64, value: &Value) {
        if !self.is_alive() {
            return;
        }
        let mut slots = self.slots.lock().unwrap();
        slots.entry(slot).or_default().chosen = Some(value.clone());
    }

    /// Chosen value for a slot, if this acceptor has learned it.
    pub fn chosen(&self, slot: u64) -> Option<Value> {
        self.slots.lock().unwrap().get(&slot).and_then(|s| s.chosen.clone())
    }
}

/// A replica group running Paxos per log slot.
pub struct PaxosGroup {
    acceptors: Vec<Arc<Acceptor>>,
    /// Committed log cache: slot → value (learned by a majority path).
    log: Mutex<Vec<Option<Value>>>,
}

impl PaxosGroup {
    pub fn new(replicas: usize) -> Self {
        assert!(replicas >= 1 && replicas % 2 == 1, "odd replica count required");
        PaxosGroup {
            acceptors: (0..replicas).map(Acceptor::new).collect(),
            log: Mutex::new(Vec::new()),
        }
    }

    pub fn acceptor(&self, id: usize) -> &Arc<Acceptor> {
        &self.acceptors[id]
    }

    pub fn replicas(&self) -> usize {
        self.acceptors.len()
    }

    pub fn majority(&self) -> usize {
        self.acceptors.len() / 2 + 1
    }

    pub fn live_count(&self) -> usize {
        self.acceptors.iter().filter(|a| a.is_alive()).count()
    }

    /// Propose `value`; returns the slot where a value was CHOSEN and
    /// the value actually chosen there (Paxos may choose an earlier
    /// competing proposal — the caller must check and retry for its own
    /// value, which [`propose_owned`](Self::propose_owned) does).
    pub fn propose_once(&self, proposer: usize, slot: u64, value: &Value) -> Result<Value> {
        let n = self.acceptors.len() as u64;
        let mut round: u64 = 1;
        loop {
            if round > 64 {
                return Err(Error::Consensus("paxos livelock guard tripped".into()));
            }
            let ballot = round * n + proposer as u64;
            // Phase 1: prepare.
            let mut promises = 0usize;
            let mut best_accepted: Option<(u64, Value)> = None;
            for a in &self.acceptors {
                if let Some(prev) = a.prepare(slot, ballot) {
                    promises += 1;
                    if let Some((b, v)) = prev {
                        if best_accepted.as_ref().map_or(true, |(bb, _)| b > *bb) {
                            best_accepted = Some((b, v));
                        }
                    }
                }
            }
            if promises < self.majority() {
                if self.live_count() < self.majority() {
                    return Err(Error::Consensus(format!(
                        "no quorum: {} live of {}",
                        self.live_count(),
                        self.acceptors.len()
                    )));
                }
                round += 1;
                continue;
            }
            // Phase 2: accept — must propose any already-accepted value.
            let candidate = best_accepted.map(|(_, v)| v).unwrap_or_else(|| value.clone());
            let mut accepts = 0usize;
            for a in &self.acceptors {
                if a.accept(slot, ballot, &candidate) {
                    accepts += 1;
                }
            }
            if accepts >= self.majority() {
                // Chosen. Learn everywhere + record in the log cache.
                for a in &self.acceptors {
                    a.learn(slot, &candidate);
                }
                let mut log = self.log.lock().unwrap();
                if log.len() as u64 <= slot {
                    log.resize(slot as usize + 1, None);
                }
                log[slot as usize] = Some(candidate.clone());
                return Ok(candidate);
            }
            round += 1;
        }
    }

    /// Propose until OUR value is chosen in some slot; returns that slot.
    /// This is the multi-Paxos append: competing proposals that win a
    /// slot push ours to the next one. The slot is always the first
    /// unchosen position of the committed log, so a failed proposal
    /// (no quorum) never burns a slot and the log never has holes —
    /// replica state machines rely on that to apply in order.
    pub fn propose_owned(&self, proposer: usize, value: Value) -> Result<u64> {
        loop {
            let slot = self.log.lock().unwrap().len() as u64;
            let chosen = self.propose_once(proposer, slot, &value)?;
            if chosen == value {
                return Ok(slot);
            }
            // Someone else's value took this slot; try the next.
        }
    }

    /// The committed log prefix (None = hole not yet chosen/learned).
    pub fn log_snapshot(&self) -> Vec<Option<Value>> {
        self.log.lock().unwrap().clone()
    }

    /// Chosen value at `slot` from the group's perspective.
    pub fn chosen(&self, slot: u64) -> Option<Value> {
        self.log.lock().unwrap().get(slot as usize).cloned().flatten()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_proposer_chooses_value() {
        let g = PaxosGroup::new(3);
        let slot = g.propose_owned(0, "v1".into()).unwrap();
        assert_eq!(g.chosen(slot).unwrap(), "v1");
        // All live acceptors learned it.
        for i in 0..3 {
            assert_eq!(g.acceptor(i).chosen(slot).unwrap(), "v1");
        }
    }

    #[test]
    fn survives_minority_failure() {
        let g = PaxosGroup::new(5);
        g.acceptor(0).set_alive(false);
        g.acceptor(1).set_alive(false);
        let slot = g.propose_owned(0, "update".into()).unwrap();
        assert_eq!(g.chosen(slot).unwrap(), "update");
    }

    #[test]
    fn majority_failure_blocks_consensus() {
        let g = PaxosGroup::new(3);
        g.acceptor(0).set_alive(false);
        g.acceptor(1).set_alive(false);
        let err = g.propose_owned(0, "nope".into()).unwrap_err();
        assert!(matches!(err, Error::Consensus(_)), "{err}");
    }

    #[test]
    fn recovery_restores_quorum() {
        let g = PaxosGroup::new(3);
        g.acceptor(0).set_alive(false);
        g.acceptor(1).set_alive(false);
        assert!(g.propose_owned(0, "x".into()).is_err());
        g.acceptor(0).set_alive(true);
        let slot = g.propose_owned(0, "x".into()).unwrap();
        assert_eq!(g.chosen(slot).unwrap(), "x");
    }

    #[test]
    fn competing_proposals_all_get_slots() {
        // Sequential competing proposers: every value must land in some
        // distinct slot, none lost.
        let g = PaxosGroup::new(3);
        let mut slots = Vec::new();
        for p in 0..5 {
            let v = format!("value-{p}");
            let slot = g.propose_owned(p, v.clone()).unwrap();
            assert_eq!(g.chosen(slot).unwrap(), v);
            slots.push(slot);
        }
        slots.sort_unstable();
        slots.dedup();
        assert_eq!(slots.len(), 5, "each value in its own slot");
    }

    #[test]
    fn concurrent_proposers_converge() {
        let g = Arc::new(PaxosGroup::new(5));
        let mut handles = Vec::new();
        for p in 0..8usize {
            let g = Arc::clone(&g);
            handles.push(std::thread::spawn(move || {
                g.propose_owned(p, format!("t{p}")).unwrap()
            }));
        }
        let slots: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // All 8 values chosen in 8 distinct slots.
        let mut uniq = slots.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 8);
        for (p, slot) in slots.iter().enumerate() {
            assert_eq!(g.chosen(*slot).unwrap(), format!("t{p}"));
        }
    }

    #[test]
    fn chosen_value_is_stable_across_ballots() {
        // Once chosen, later proposals for the same slot must re-choose
        // the same value (safety core of Paxos).
        let g = PaxosGroup::new(3);
        let chosen = g.propose_once(0, 0, &"first".into()).unwrap();
        assert_eq!(chosen, "first");
        let rechosen = g.propose_once(1, 0, &"second".into()).unwrap();
        assert_eq!(rechosen, "first", "slot 0 value must not change");
    }

    #[test]
    #[should_panic(expected = "odd replica count")]
    fn even_replica_count_rejected() {
        PaxosGroup::new(4);
    }
}
