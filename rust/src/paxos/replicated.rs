//! Replicated metadata: the paper's §IV-B update protocol end to end.
//!
//! Commands are serialized to JSON, sequenced through [`PaxosGroup`],
//! and applied to N deterministic [`MetadataStore`] replicas in slot
//! order. A writer holds the exclusive side of an RwLock through
//! propose + apply — the paper's "read operations are temporarily locked
//! until the metadata is fully updated" — so reads (shared side) always
//! observe fully committed state: strong read-after-write.
//!
//! Replica crash/recovery: a dead replica misses applies; on revival,
//! [`ReplicatedMeta::sync`] replays the chosen log from its applied
//! cursor. Determinism (same seed, same command order) guarantees
//! convergence to byte-identical stores — asserted by tests.
//!
//! *Process* crash/recovery (the whole coordinator dying) is covered by
//! the durability hook: built via [`ReplicatedMeta::durable`], every
//! Paxos-committed command is appended to a CRC-framed, fsync'd
//! write-ahead log **before** it is applied or acknowledged, and the
//! store state is periodically compacted into an atomic snapshot (see
//! [`crate::durability`]). A restart replays snapshot + WAL tail and
//! resumes with byte-identical metadata.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::durability::{snapshot, DurabilityOpts, KvStore, RecoveryReport, Wal, WAL_FILE};
use crate::json::{obj, parse, to_string, Value};
use crate::metadata::{MetadataStore, ObjectMeta, ObjectPlacement, PartManifest, Permission};
use crate::paxos::PaxosGroup;
use crate::util::{from_hex, to_hex, unix_secs};
use crate::{Error, Result};

/// A metadata mutation, serializable for the Paxos log.
#[derive(Debug, Clone, PartialEq)]
pub enum MetaCommand {
    CreateNamespace { user: String },
    CreateCollection { caller: String, path: String },
    Grant { caller: String, path: String, user: String, perm: Permission },
    Revoke { caller: String, path: String, user: String, perm: Permission },
    PutObject {
        caller: String,
        collection: String,
        name: String,
        size: u64,
        sha3: [u8; 32],
        placement: ObjectPlacement,
        now: u64,
    },
    Evict { caller: String, collection: String, name: String },
    Gc { now: u64, retention_secs: u64 },
    /// Health-repair / migration placement update (not a user-facing
    /// op). `expect` makes the commit a compare-and-swap: it fails if
    /// the stored placement no longer matches, so concurrent
    /// repair/migration writers can't overwrite each other (`None` =
    /// unconditional).
    UpdatePlacement {
        uuid: String,
        placement: ObjectPlacement,
        expect: Option<ObjectPlacement>,
    },
    /// Open a multipart upload (S3-style). The upload id is minted by
    /// the store's deterministic RNG, so every replica agrees on it.
    MultipartInit { caller: String, collection: String, name: String, now: u64 },
    /// Record one uploaded part's manifest on an open upload.
    MultipartPut { caller: String, upload_id: String, part: PartManifest },
    /// Assemble the parts into a Striped object version.
    MultipartComplete { caller: String, upload_id: String, now: u64 },
    /// Discard an open upload; outcome carries the orphaned manifests.
    MultipartAbort { caller: String, upload_id: String },
}

impl MetaCommand {
    pub fn to_json(&self) -> String {
        let v = match self {
            MetaCommand::CreateNamespace { user } => {
                obj(vec![("op", "create_ns".into()), ("user", user.as_str().into())])
            }
            MetaCommand::CreateCollection { caller, path } => obj(vec![
                ("op", "create_col".into()),
                ("caller", caller.as_str().into()),
                ("path", path.as_str().into()),
            ]),
            MetaCommand::Grant { caller, path, user, perm } => obj(vec![
                ("op", "grant".into()),
                ("caller", caller.as_str().into()),
                ("path", path.as_str().into()),
                ("user", user.as_str().into()),
                ("perm", perm.as_str().into()),
            ]),
            MetaCommand::Revoke { caller, path, user, perm } => obj(vec![
                ("op", "revoke".into()),
                ("caller", caller.as_str().into()),
                ("path", path.as_str().into()),
                ("user", user.as_str().into()),
                ("perm", perm.as_str().into()),
            ]),
            MetaCommand::PutObject { caller, collection, name, size, sha3, placement, now } => {
                obj(vec![
                    ("op", "put".into()),
                    ("caller", caller.as_str().into()),
                    ("collection", collection.as_str().into()),
                    ("name", name.as_str().into()),
                    ("size", (*size).into()),
                    ("sha3", to_hex(sha3).into()),
                    ("placement", placement.to_json()),
                    ("now", (*now).into()),
                ])
            }
            MetaCommand::Evict { caller, collection, name } => obj(vec![
                ("op", "evict".into()),
                ("caller", caller.as_str().into()),
                ("collection", collection.as_str().into()),
                ("name", name.as_str().into()),
            ]),
            MetaCommand::Gc { now, retention_secs } => obj(vec![
                ("op", "gc".into()),
                ("now", (*now).into()),
                ("retention", (*retention_secs).into()),
            ]),
            MetaCommand::UpdatePlacement { uuid, placement, expect } => {
                let mut fields = vec![
                    ("op", "update_placement".into()),
                    ("uuid", uuid.as_str().into()),
                    ("placement", placement.to_json()),
                ];
                if let Some(exp) = expect {
                    fields.push(("expect", exp.to_json()));
                }
                obj(fields)
            }
            MetaCommand::MultipartInit { caller, collection, name, now } => obj(vec![
                ("op", "mp_init".into()),
                ("caller", caller.as_str().into()),
                ("collection", collection.as_str().into()),
                ("name", name.as_str().into()),
                ("now", (*now).into()),
            ]),
            MetaCommand::MultipartPut { caller, upload_id, part } => obj(vec![
                ("op", "mp_put".into()),
                ("caller", caller.as_str().into()),
                ("upload_id", upload_id.as_str().into()),
                ("part", part.to_json()),
            ]),
            MetaCommand::MultipartComplete { caller, upload_id, now } => obj(vec![
                ("op", "mp_complete".into()),
                ("caller", caller.as_str().into()),
                ("upload_id", upload_id.as_str().into()),
                ("now", (*now).into()),
            ]),
            MetaCommand::MultipartAbort { caller, upload_id } => obj(vec![
                ("op", "mp_abort".into()),
                ("caller", caller.as_str().into()),
                ("upload_id", upload_id.as_str().into()),
            ]),
        };
        to_string(&v)
    }

    pub fn from_json(text: &str) -> Result<MetaCommand> {
        let v = parse(text)?;
        let op = v.req_str("op")?;
        Ok(match op {
            "create_ns" => MetaCommand::CreateNamespace { user: v.req_str("user")?.into() },
            "create_col" => MetaCommand::CreateCollection {
                caller: v.req_str("caller")?.into(),
                path: v.req_str("path")?.into(),
            },
            "grant" | "revoke" => {
                let perm = Permission::parse(v.req_str("perm")?)?;
                let (caller, path, user) = (
                    v.req_str("caller")?.to_string(),
                    v.req_str("path")?.to_string(),
                    v.req_str("user")?.to_string(),
                );
                if op == "grant" {
                    MetaCommand::Grant { caller, path, user, perm }
                } else {
                    MetaCommand::Revoke { caller, path, user, perm }
                }
            }
            "put" => {
                let sha3_vec = from_hex(v.req_str("sha3")?)
                    .ok_or_else(|| Error::Json("bad sha3 hex".into()))?;
                let sha3: [u8; 32] =
                    sha3_vec.try_into().map_err(|_| Error::Json("sha3 length".into()))?;
                MetaCommand::PutObject {
                    caller: v.req_str("caller")?.into(),
                    collection: v.req_str("collection")?.into(),
                    name: v.req_str("name")?.into(),
                    size: v.req_u64("size")?,
                    sha3,
                    placement: ObjectPlacement::from_json(v.get("placement"))?,
                    now: v.req_u64("now")?,
                }
            }
            "evict" => MetaCommand::Evict {
                caller: v.req_str("caller")?.into(),
                collection: v.req_str("collection")?.into(),
                name: v.req_str("name")?.into(),
            },
            "gc" => MetaCommand::Gc {
                now: v.req_u64("now")?,
                retention_secs: v.req_u64("retention")?,
            },
            "update_placement" => MetaCommand::UpdatePlacement {
                uuid: v.req_str("uuid")?.into(),
                placement: ObjectPlacement::from_json(v.get("placement"))?,
                expect: match v.get("expect") {
                    Value::Null => None,
                    other => Some(ObjectPlacement::from_json(other)?),
                },
            },
            "mp_init" => MetaCommand::MultipartInit {
                caller: v.req_str("caller")?.into(),
                collection: v.req_str("collection")?.into(),
                name: v.req_str("name")?.into(),
                now: v.req_u64("now")?,
            },
            "mp_put" => MetaCommand::MultipartPut {
                caller: v.req_str("caller")?.into(),
                upload_id: v.req_str("upload_id")?.into(),
                part: PartManifest::from_json(v.get("part"))?,
            },
            "mp_complete" => MetaCommand::MultipartComplete {
                caller: v.req_str("caller")?.into(),
                upload_id: v.req_str("upload_id")?.into(),
                now: v.req_u64("now")?,
            },
            "mp_abort" => MetaCommand::MultipartAbort {
                caller: v.req_str("caller")?.into(),
                upload_id: v.req_str("upload_id")?.into(),
            },
            other => return Err(Error::Json(format!("unknown op '{other}'"))),
        })
    }
}

/// One metadata replica: deterministic store + applied-log cursor.
struct Replica {
    store: MetadataStore,
    applied: AtomicU64,
    alive: AtomicBool,
}

/// Persistence half of a durable deployment: the open WAL plus the
/// snapshot cadence bookkeeping. Mutated only under the exclusive
/// metadata write lock (its own mutex exists so read-only accessors
/// like [`ReplicatedMeta::wal_len`] don't need the write lock).
struct DurabilityState {
    wal: Wal,
    dir: std::path::PathBuf,
    snapshot_every: u64,
    /// Global commit sequence of the next command (== total commands
    /// ever committed by this deployment, across restarts).
    next_seq: u64,
    commits_since_snapshot: u64,
    last_snapshot_unix: u64,
    sink: SnapshotSink,
}

/// How a compacting snapshot is persisted.
enum SnapshotSink {
    /// Legacy single-shard layout: the full store serialized to one
    /// JSON document in `meta.snapshot`.
    FullJson,
    /// Sharded layout ([`ReplicatedMeta::durable_keyed`]): only the
    /// keys dirtied since the last snapshot, appended as a CRC-framed
    /// segment to the keyed store — O(delta) on the commit path instead
    /// of O(catalog).
    Keyed(KvStore),
}

/// The replicated metadata service.
pub struct ReplicatedMeta {
    group: PaxosGroup,
    replicas: Vec<Replica>,
    /// Writers exclusive through propose+apply; readers shared — the
    /// §IV-B read lock during updates.
    rw: RwLock<()>,
    /// Present on durable deployments ([`ReplicatedMeta::durable`]).
    durability: Option<Mutex<DurabilityState>>,
}

impl ReplicatedMeta {
    /// `replica_count` must be odd (Paxos quorums). In-memory only —
    /// tests, benches, simulators; see [`ReplicatedMeta::durable`] for
    /// the persistent form.
    pub fn new(replica_count: usize, seed: u64) -> Arc<Self> {
        Arc::new(ReplicatedMeta {
            group: PaxosGroup::new(replica_count),
            replicas: (0..replica_count)
                .map(|_| Replica {
                    store: MetadataStore::new(seed),
                    applied: AtomicU64::new(0),
                    alive: AtomicBool::new(true),
                })
                .collect(),
            rw: RwLock::new(()),
            durability: None,
        })
    }

    /// Open (or create) a durable deployment rooted at `opts.dir`:
    /// load the snapshot if one exists, open the WAL (truncating any
    /// torn tail at the first bad CRC), replay the WAL records the
    /// snapshot doesn't already cover through Paxos onto every replica,
    /// and return the service positioned to log every further commit.
    ///
    /// All replicas restore from the same snapshot bytes and replay the
    /// same command order, so they converge to byte-identical stores —
    /// including the UUID RNG state, so post-recovery commands mint the
    /// same UUIDs they would have without the crash.
    pub fn durable(
        replica_count: usize,
        seed: u64,
        opts: DurabilityOpts,
    ) -> Result<(Arc<Self>, RecoveryReport)> {
        // A crash between snapshot temp-write and rename strands a
        // `*.tmp` file; reclaim it before loading.
        crate::durability::sweep_tmp(&opts.dir)?;
        let snap = snapshot::load(&opts.dir)?;
        let (wal, walrec) = Wal::open(opts.dir.join(WAL_FILE))?;
        let (base_commits, last_snapshot_unix, snapshot_loaded, stores) = match &snap {
            Some((info, store_v)) => {
                let stores = (0..replica_count)
                    .map(|_| MetadataStore::restore(store_v))
                    .collect::<Result<Vec<_>>>()?;
                (info.commits, info.taken_at, true, stores)
            }
            None => (
                0,
                0,
                false,
                (0..replica_count).map(|_| MetadataStore::new(seed)).collect(),
            ),
        };
        let meta = Arc::new(ReplicatedMeta {
            group: PaxosGroup::new(replica_count),
            replicas: stores
                .into_iter()
                .map(|store| Replica {
                    store,
                    applied: AtomicU64::new(0),
                    alive: AtomicBool::new(true),
                })
                .collect(),
            rw: RwLock::new(()),
            durability: Some(Mutex::new(DurabilityState {
                wal,
                dir: opts.dir.clone(),
                snapshot_every: opts.snapshot_every.max(1),
                next_seq: base_commits,
                commits_since_snapshot: 0,
                last_snapshot_unix,
                sink: SnapshotSink::FullJson,
            })),
        });
        // Replay the WAL tail: records with seq < base_commits are
        // already folded into the snapshot (a crash between snapshot
        // write and WAL reset leaves them behind) and must be skipped —
        // commands are not idempotent.
        let mut replayed = 0u64;
        {
            let _w = meta.rw.write().unwrap();
            for rec in &walrec.records {
                if rec.seq < base_commits {
                    continue;
                }
                meta.group.propose_owned(0, rec.payload.clone())?;
                replayed += 1;
            }
            meta.apply_backlog()?;
            let mut d = meta.durability.as_ref().unwrap().lock().unwrap();
            d.next_seq = base_commits + replayed;
            d.commits_since_snapshot = replayed;
        }
        let report = RecoveryReport {
            snapshot_loaded,
            snapshot_commits: base_commits,
            wal_records: walrec.records.len() as u64,
            wal_replayed: replayed,
            wal_truncated: walrec.truncated,
        };
        Ok((meta, report))
    }

    /// Open (or create) a durable deployment whose snapshots go through
    /// the keyed incremental store ([`crate::durability::KvStore`])
    /// instead of full-state JSON — one metadata shard of the sharded
    /// plane. Recovery folds `kv.base` + delta segments into the
    /// starting state (torn segment tails truncated like the WAL's),
    /// then replays the WAL tail above the folded watermark, exactly
    /// like [`ReplicatedMeta::durable`]. The no-acked-mutation-lost
    /// invariant is unchanged: commands still hit the fsync'd WAL
    /// before acknowledgement, and the WAL is only reset after the
    /// covering segment is fsync'd.
    pub fn durable_keyed(
        replica_count: usize,
        seed: u64,
        opts: DurabilityOpts,
    ) -> Result<(Arc<Self>, RecoveryReport)> {
        let (kv, kvrec) = KvStore::open(&opts.dir)?;
        let (wal, walrec) = Wal::open(opts.dir.join(WAL_FILE))?;
        let base_commits = kvrec.watermark;
        let stores = if kvrec.loaded {
            (0..replica_count)
                .map(|_| MetadataStore::restore_from_kv(&kvrec.entries))
                .collect::<Result<Vec<_>>>()?
        } else {
            (0..replica_count).map(|_| MetadataStore::new(seed)).collect()
        };
        let meta = Arc::new(ReplicatedMeta {
            group: PaxosGroup::new(replica_count),
            replicas: stores
                .into_iter()
                .map(|store| Replica {
                    store,
                    applied: AtomicU64::new(0),
                    alive: AtomicBool::new(true),
                })
                .collect(),
            rw: RwLock::new(()),
            durability: Some(Mutex::new(DurabilityState {
                wal,
                dir: opts.dir.clone(),
                snapshot_every: opts.snapshot_every.max(1),
                next_seq: base_commits,
                commits_since_snapshot: 0,
                // Segment watermarks don't carry wall-clock; the gauge
                // restarts at 0 and updates on the next snapshot.
                last_snapshot_unix: 0,
                sink: SnapshotSink::Keyed(kv),
            })),
        });
        // Same watermark discipline as the legacy path: records below
        // the folded segment watermark are already covered and must be
        // skipped (commands are not idempotent).
        let mut replayed = 0u64;
        {
            let _w = meta.rw.write().unwrap();
            for rec in &walrec.records {
                if rec.seq < base_commits {
                    continue;
                }
                meta.group.propose_owned(0, rec.payload.clone())?;
                replayed += 1;
            }
            meta.apply_backlog()?;
            let mut d = meta.durability.as_ref().unwrap().lock().unwrap();
            d.next_seq = base_commits + replayed;
            d.commits_since_snapshot = replayed;
        }
        // A base holding nothing but `sys:` seeds (the shape shard
        // migration writes for a fresh shard) is a seed, not recovered
        // state — a fresh sharded boot must report `recovered() ==
        // false` exactly like a fresh single-shard boot.
        let base_has_state =
            base_commits > 0 || kvrec.entries.iter().any(|(k, _)| !k.starts_with("sys:"));
        let report = RecoveryReport {
            snapshot_loaded: kvrec.loaded && base_has_state,
            snapshot_commits: base_commits,
            wal_records: walrec.records.len() as u64,
            wal_replayed: replayed,
            wal_truncated: walrec.truncated || kvrec.truncated,
        };
        Ok((meta, report))
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Crash/revive a replica (both its acceptor and state machine).
    pub fn set_replica_alive(&self, id: usize, alive: bool) {
        self.group.acceptor(id).set_alive(alive);
        self.replicas[id].alive.store(alive, Ordering::SeqCst);
        if alive {
            // Catch up a revived replica under the write lock.
            let _w = self.rw.write().unwrap();
            self.sync(id);
        }
    }

    /// Replay the chosen log onto replica `id` from its cursor.
    fn sync(&self, id: usize) {
        let log = self.group.log_snapshot();
        let r = &self.replicas[id];
        let mut cursor = r.applied.load(Ordering::SeqCst);
        while (cursor as usize) < log.len() {
            match &log[cursor as usize] {
                Some(entry) => {
                    if let Ok(cmd) = MetaCommand::from_json(entry) {
                        let _ = apply(&r.store, &cmd); // deterministic
                    }
                    cursor += 1;
                }
                None => break, // hole: stop (never happens with serialized writers)
            }
        }
        r.applied.store(cursor, Ordering::SeqCst);
    }

    /// Propose a command through Paxos and apply it on every live
    /// replica. Returns the command's own result (from the first live
    /// replica). Fails with `Consensus` if no quorum.
    pub fn submit(&self, cmd: MetaCommand) -> Result<CommandOutcome> {
        self.submit_guarded(cmd, || Ok(()))
    }

    /// Like [`ReplicatedMeta::submit`], but run `precheck` under the
    /// exclusive metadata lock first, aborting the proposal (no slot
    /// consumed) if it fails. Readers and writers serialize against the
    /// same lock, so the precheck is atomic with the commit — push uses
    /// this to validate placement targets against the registry's
    /// draining state at the last possible instant.
    pub fn submit_guarded(
        &self,
        cmd: MetaCommand,
        precheck: impl FnOnce() -> Result<()>,
    ) -> Result<CommandOutcome> {
        let _w = self.rw.write().unwrap();
        precheck()?;
        // A poisoned WAL (earlier fsync failure) makes the deployment
        // read-only until restart: fail BEFORE proposing, so the Paxos
        // log doesn't grow unapplied slots that would wedge reads away
        // from the last consistent state.
        if let Some(d) = &self.durability {
            if d.lock().unwrap().wal.is_poisoned() {
                return Err(Error::Unavailable(
                    "metadata WAL failed an earlier fsync; deployment is read-only \
                     until restarted"
                        .into(),
                ));
            }
        }
        let payload = cmd.to_json();
        let _slot = self.group.propose_owned(0, payload.clone())?;
        // Log-before-ack: the chosen command hits the fsync'd WAL
        // before any replica applies it and before the caller sees an
        // outcome. If the append fails the command is NOT acknowledged
        // (error out here; the WAL poisons itself so no later commit
        // can be acknowledged either — see `Wal::append`).
        if let Some(d) = &self.durability {
            let mut d = d.lock().unwrap();
            let seq = d.next_seq;
            d.wal.append(seq, &payload)?;
            d.next_seq += 1;
        }
        let outcome = self.apply_backlog()?;
        if outcome.is_some() {
            self.maybe_snapshot();
        }
        outcome.ok_or_else(|| Error::Consensus("no live replica applied the command".into()))
    }

    /// Apply every unapplied chosen log entry to every live replica.
    /// Returns the outcome of the **last** entry applied on the first
    /// live replica (in `submit` that is exactly the just-committed
    /// command: live replicas are always fully applied beforehand).
    /// Caller must hold the exclusive write lock.
    fn apply_backlog(&self) -> Result<Option<CommandOutcome>> {
        let mut outcome: Option<CommandOutcome> = None;
        let mut first_live = true;
        for r in &self.replicas {
            if !r.alive.load(Ordering::SeqCst) {
                continue;
            }
            let log = self.group.log_snapshot();
            let mut cursor = r.applied.load(Ordering::SeqCst);
            while (cursor as usize) < log.len() {
                if let Some(entry) = &log[cursor as usize] {
                    let parsed = MetaCommand::from_json(entry)?;
                    let res = apply(&r.store, &parsed);
                    if first_live {
                        outcome = Some(res);
                    }
                    cursor += 1;
                } else {
                    break;
                }
            }
            r.applied.store(cursor, Ordering::SeqCst);
            first_live = false;
        }
        Ok(outcome)
    }

    /// Snapshot cadence: after `snapshot_every` commits, persist the
    /// full store state atomically and reset the WAL. Failures are
    /// logged and non-fatal — the WAL still covers everything, so the
    /// commit being acknowledged stays durable either way. Caller must
    /// hold the exclusive write lock (the store must be quiescent while
    /// it serializes).
    fn maybe_snapshot(&self) {
        let Some(d) = &self.durability else { return };
        let mut d = d.lock().unwrap();
        d.commits_since_snapshot += 1;
        if d.commits_since_snapshot < d.snapshot_every {
            return;
        }
        let target = self.group.log_snapshot().len() as u64;
        let Some(r) = self.replicas.iter().find(|r| {
            r.alive.load(Ordering::SeqCst) && r.applied.load(Ordering::SeqCst) >= target
        }) else {
            return; // no fully-applied live replica to serialize
        };
        let now = unix_secs();
        let d = &mut *d;
        let result = match &mut d.sink {
            SnapshotSink::FullJson => {
                snapshot::save(&d.dir, d.next_seq, now, r.store.snapshot_value())
            }
            SnapshotSink::Keyed(kv) => {
                // Incremental: persist only the keys dirtied since the
                // last drain. The segment is appended even when the
                // delta is empty — its seq is the watermark that makes
                // the WAL reset below safe.
                let delta = r.store.kv_delta();
                match kv.append_delta(d.next_seq, &delta) {
                    Ok(()) => {
                        if let Err(e) = kv.maybe_compact() {
                            crate::log_warn!("kv segment rotation failed: {e}");
                        }
                        Ok(())
                    }
                    Err(e) => {
                        // Re-arm so the next cadence retries these keys.
                        r.store.kv_mark_dirty(delta.into_iter().map(|(k, _)| k));
                        Err(e)
                    }
                }
            }
        };
        match result {
            Ok(()) => {
                if matches!(d.sink, SnapshotSink::FullJson) {
                    // The full snapshot covered everything; drop the
                    // (unused) dirty tracking so it can't grow without
                    // bound on legacy deployments.
                    for rep in &self.replicas {
                        rep.store.kv_clear_dirty();
                    }
                }
                if let Err(e) = d.wal.reset() {
                    // Stale records are harmless: their seq numbers are
                    // below the snapshot's commit watermark.
                    crate::log_warn!("wal reset after snapshot failed: {e}");
                }
                d.commits_since_snapshot = 0;
                d.last_snapshot_unix = now;
            }
            Err(e) => {
                crate::log_warn!("metadata snapshot failed (wal retained): {e}");
                // Retry after another snapshot_every commits.
                d.commits_since_snapshot = 0;
            }
        }
    }

    /// Read from the first live, fully-applied replica (shared lock —
    /// blocks while a writer is mid-update, per §IV-B).
    pub fn read<T>(&self, f: impl Fn(&MetadataStore) -> Result<T>) -> Result<T> {
        let _r = self.rw.read().unwrap();
        let target = self.group.log_snapshot().len() as u64;
        for r in &self.replicas {
            if r.alive.load(Ordering::SeqCst) && r.applied.load(Ordering::SeqCst) >= target {
                return f(&r.store);
            }
        }
        Err(Error::Unavailable("no up-to-date metadata replica".into()))
    }

    /// Direct store access for invariant checks in tests.
    pub fn replica_store(&self, id: usize) -> &MetadataStore {
        &self.replicas[id].store
    }

    pub fn applied_cursor(&self, id: usize) -> u64 {
        self.replicas[id].applied.load(Ordering::SeqCst)
    }

    /// Whether commits are persisted to a WAL + snapshot pair.
    pub fn is_durable(&self) -> bool {
        self.durability.is_some()
    }

    /// Records currently in the WAL (0 when not durable). Grows per
    /// commit, drops to 0 at each compacting snapshot.
    pub fn wal_len(&self) -> u64 {
        self.durability.as_ref().map_or(0, |d| d.lock().unwrap().wal.len())
    }

    /// Unix seconds of the last compacting snapshot (0 = never).
    pub fn last_snapshot_unix(&self) -> u64 {
        self.durability.as_ref().map_or(0, |d| d.lock().unwrap().last_snapshot_unix)
    }

    /// Total commands ever committed by this deployment, across
    /// restarts (0 when not durable).
    pub fn committed_seq(&self) -> u64 {
        self.durability.as_ref().map_or(0, |d| d.lock().unwrap().next_seq)
    }
}

/// Result of applying a command to a store (deterministic per replica).
#[derive(Debug, Clone)]
pub enum CommandOutcome {
    Ok,
    Meta(Box<ObjectMeta>),
    Evicted(Vec<ObjectMeta>),
    Collected(Vec<ObjectMeta>),
    /// MultipartInit: the replica-agreed upload id.
    UploadId(String),
    /// MultipartPut: the displaced manifest when a part was re-uploaded
    /// (its chunks are now orphans the caller may GC).
    PartReplaced(Option<Box<PartManifest>>),
    /// MultipartAbort: the orphaned manifests to GC.
    Aborted(Vec<PartManifest>),
    Failed(String),
}

fn apply(store: &MetadataStore, cmd: &MetaCommand) -> CommandOutcome {
    let as_outcome = |r: Result<()>| match r {
        Ok(()) => CommandOutcome::Ok,
        Err(e) => CommandOutcome::Failed(e.to_string()),
    };
    match cmd {
        MetaCommand::CreateNamespace { user } => {
            as_outcome(store.create_namespace(user).map(|_| ()))
        }
        MetaCommand::CreateCollection { caller, path } => {
            as_outcome(store.create_collection(caller, path).map(|_| ()))
        }
        MetaCommand::Grant { caller, path, user, perm } => {
            as_outcome(store.grant(caller, path, user, *perm))
        }
        MetaCommand::Revoke { caller, path, user, perm } => {
            as_outcome(store.revoke(caller, path, user, *perm))
        }
        MetaCommand::PutObject { caller, collection, name, size, sha3, placement, now } => {
            match store.put_object(caller, collection, name, *size, *sha3, placement.clone(), *now)
            {
                Ok(meta) => CommandOutcome::Meta(Box::new(meta)),
                Err(e) => CommandOutcome::Failed(e.to_string()),
            }
        }
        MetaCommand::Evict { caller, collection, name } => {
            match store.evict(caller, collection, name) {
                Ok(metas) => CommandOutcome::Evicted(metas),
                Err(e) => CommandOutcome::Failed(e.to_string()),
            }
        }
        MetaCommand::Gc { now, retention_secs } => {
            CommandOutcome::Collected(store.gc(*now, *retention_secs))
        }
        MetaCommand::UpdatePlacement { uuid, placement, expect } => {
            as_outcome(store.update_placement(uuid, placement.clone(), expect.as_ref()))
        }
        MetaCommand::MultipartInit { caller, collection, name, now } => {
            match store.multipart_init(caller, collection, name, *now) {
                Ok(id) => CommandOutcome::UploadId(id),
                Err(e) => CommandOutcome::Failed(e.to_string()),
            }
        }
        MetaCommand::MultipartPut { caller, upload_id, part } => {
            match store.multipart_put(caller, upload_id, part.clone()) {
                Ok(displaced) => CommandOutcome::PartReplaced(displaced.map(Box::new)),
                Err(e) => CommandOutcome::Failed(e.to_string()),
            }
        }
        MetaCommand::MultipartComplete { caller, upload_id, now } => {
            match store.multipart_complete(caller, upload_id, *now) {
                Ok(meta) => CommandOutcome::Meta(Box::new(meta)),
                Err(e) => CommandOutcome::Failed(e.to_string()),
            }
        }
        MetaCommand::MultipartAbort { caller, upload_id } => {
            match store.multipart_abort(caller, upload_id) {
                Ok(parts) => CommandOutcome::Aborted(parts),
                Err(e) => CommandOutcome::Failed(e.to_string()),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn put_cmd(name: &str, t: u64) -> MetaCommand {
        MetaCommand::PutObject {
            caller: "UserA".into(),
            collection: "/UserA".into(),
            name: name.into(),
            size: 42,
            sha3: [7; 32],
            placement: ObjectPlacement::Erasure {
                n: 3,
                k: 2,
                chunks: vec![(0, 1), (1, 2), (2, 3)],
            },
            now: t,
        }
    }

    fn setup(replicas: usize) -> Arc<ReplicatedMeta> {
        let m = ReplicatedMeta::new(replicas, 99);
        m.submit(MetaCommand::CreateNamespace { user: "UserA".into() }).unwrap();
        m
    }

    #[test]
    fn command_json_roundtrip() {
        let cmds = vec![
            MetaCommand::CreateNamespace { user: "u".into() },
            MetaCommand::CreateCollection { caller: "u".into(), path: "/u/c".into() },
            MetaCommand::Grant {
                caller: "u".into(),
                path: "/u/c".into(),
                user: "v".into(),
                perm: Permission::Read,
            },
            MetaCommand::Revoke {
                caller: "u".into(),
                path: "/u/c".into(),
                user: "v".into(),
                perm: Permission::Write,
            },
            put_cmd("obj", 5),
            MetaCommand::Evict { caller: "u".into(), collection: "/u".into(), name: "o".into() },
            MetaCommand::Gc { now: 100, retention_secs: 60 },
            MetaCommand::UpdatePlacement {
                uuid: "u-1".into(),
                placement: ObjectPlacement::Single { container: 4 },
                expect: None,
            },
            MetaCommand::UpdatePlacement {
                uuid: "u-2".into(),
                placement: ObjectPlacement::Erasure {
                    n: 3,
                    k: 2,
                    chunks: vec![(0, 1), (1, 2), (2, 3)],
                },
                expect: Some(ObjectPlacement::Erasure {
                    n: 3,
                    k: 2,
                    chunks: vec![(0, 1), (1, 2), (2, 9)],
                }),
            },
            MetaCommand::MultipartInit {
                caller: "u".into(),
                collection: "/u".into(),
                name: "big".into(),
                now: 7,
            },
            MetaCommand::MultipartPut {
                caller: "u".into(),
                upload_id: "up-1".into(),
                part: PartManifest {
                    number: 2,
                    size: 1024,
                    sha3: [3; 32],
                    n: 3,
                    k: 2,
                    chunks: vec![(0, 1), (1, 2), (2, 3)],
                },
            },
            MetaCommand::MultipartComplete {
                caller: "u".into(),
                upload_id: "up-1".into(),
                now: 9,
            },
            MetaCommand::MultipartAbort { caller: "u".into(), upload_id: "up-1".into() },
        ];
        for cmd in cmds {
            let json = cmd.to_json();
            assert_eq!(MetaCommand::from_json(&json).unwrap(), cmd, "{json}");
        }
    }

    #[test]
    fn replicas_converge_to_identical_state() {
        let m = setup(3);
        for i in 0..10 {
            m.submit(put_cmd(&format!("obj{i}"), i)).unwrap();
        }
        // Every replica applied every slot; stores agree on uuids.
        for name in ["obj0", "obj5", "obj9"] {
            let metas: Vec<ObjectMeta> = (0..3)
                .map(|r| m.replica_store(r).get_latest("UserA", "/UserA", name).unwrap())
                .collect();
            assert_eq!(metas[0], metas[1]);
            assert_eq!(metas[1], metas[2]);
        }
    }

    #[test]
    fn read_after_write_sees_latest() {
        let m = setup(3);
        let out = m.submit(put_cmd("obj", 1)).unwrap();
        let uuid = match out {
            CommandOutcome::Meta(meta) => meta.uuid,
            other => panic!("unexpected outcome {other:?}"),
        };
        let read =
            m.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        assert_eq!(read.uuid, uuid);
    }

    #[test]
    fn survives_minority_replica_failure() {
        let m = setup(5);
        m.set_replica_alive(4, false);
        m.set_replica_alive(3, false);
        m.submit(put_cmd("obj", 1)).unwrap();
        let meta = m.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        assert_eq!(meta.size, 42);
    }

    #[test]
    fn majority_failure_rejects_writes() {
        let m = setup(3);
        m.set_replica_alive(1, false);
        m.set_replica_alive(2, false);
        let err = m.submit(put_cmd("obj", 1)).unwrap_err();
        assert!(matches!(err, Error::Consensus(_)));
    }

    #[test]
    fn revived_replica_catches_up() {
        let m = setup(5);
        m.set_replica_alive(2, false);
        for i in 0..5 {
            m.submit(put_cmd(&format!("o{i}"), i)).unwrap();
        }
        assert!(m.applied_cursor(2) < m.applied_cursor(0));
        m.set_replica_alive(2, true);
        assert_eq!(m.applied_cursor(2), m.applied_cursor(0));
        // And its state matches replica 0 exactly.
        let a = m.replica_store(0).get_latest("UserA", "/UserA", "o4").unwrap();
        let b = m.replica_store(2).get_latest("UserA", "/UserA", "o4").unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn failed_commands_replicate_deterministically() {
        let m = setup(3);
        // Permission failure must not desync replicas.
        let out = m
            .submit(MetaCommand::CreateCollection {
                caller: "Mallory".into(),
                path: "/UserA/Steal".into(),
            })
            .unwrap();
        assert!(matches!(out, CommandOutcome::Failed(_)));
        for r in 0..3 {
            assert!(!m.replica_store(r).collection_exists("/UserA/Steal"));
        }
        // System still writable.
        m.submit(put_cmd("obj", 1)).unwrap();
    }

    fn durable_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dynostore-repl-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn durable_opts(dir: &std::path::Path, every: u64) -> DurabilityOpts {
        DurabilityOpts::new(dir).snapshot_every(every)
    }

    #[test]
    fn durable_restart_replays_every_acknowledged_command() {
        let dir = durable_dir("replay");
        {
            let (m, rec) = ReplicatedMeta::durable(3, 99, durable_opts(&dir, 1000)).unwrap();
            assert!(!rec.recovered());
            m.submit(MetaCommand::CreateNamespace { user: "UserA".into() }).unwrap();
            for i in 0..5 {
                m.submit(put_cmd(&format!("o{i}"), i)).unwrap();
            }
            assert_eq!(m.wal_len(), 6);
            // Hard drop: no shutdown hook, nothing flushed beyond the
            // per-commit fsyncs.
        }
        let (m, rec) = ReplicatedMeta::durable(3, 99, durable_opts(&dir, 1000)).unwrap();
        assert!(rec.recovered());
        assert!(!rec.snapshot_loaded);
        assert_eq!(rec.wal_replayed, 6);
        assert!(!rec.wal_truncated);
        for i in 0..5 {
            let meta =
                m.read(|s| s.get_latest("UserA", "/UserA", &format!("o{i}"))).unwrap();
            assert_eq!(meta.size, 42);
        }
        // All replicas converged after replay.
        for r in 0..3 {
            assert_eq!(m.replica_store(r).object_count(), 5);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_compacts_wal_and_restart_uses_it() {
        let dir = durable_dir("compact");
        let uuid_before;
        {
            let (m, _) = ReplicatedMeta::durable(3, 99, durable_opts(&dir, 4)).unwrap();
            m.submit(MetaCommand::CreateNamespace { user: "UserA".into() }).unwrap();
            for i in 0..9 {
                m.submit(put_cmd(&format!("o{i}"), i)).unwrap();
            }
            // 10 commits, snapshot_every=4 → snapshots at 4 and 8; WAL
            // holds the 2 commits after the last snapshot.
            assert_eq!(m.wal_len(), 2);
            assert!(m.last_snapshot_unix() > 0);
            assert_eq!(m.committed_seq(), 10);
            uuid_before = m.read(|s| s.get_latest("UserA", "/UserA", "o8")).unwrap().uuid;
        }
        let (m, rec) = ReplicatedMeta::durable(3, 99, durable_opts(&dir, 4)).unwrap();
        assert!(rec.snapshot_loaded);
        assert_eq!(rec.snapshot_commits, 8);
        assert_eq!(rec.wal_replayed, 2);
        assert_eq!(m.committed_seq(), 10);
        let after = m.read(|s| s.get_latest("UserA", "/UserA", "o8")).unwrap();
        assert_eq!(after.uuid, uuid_before, "uuid sequence survives recovery");
        // The recovered deployment keeps committing and snapshotting.
        for i in 9..15 {
            m.submit(put_cmd(&format!("o{i}"), i)).unwrap();
        }
        assert_eq!(m.read(|s| Ok(s.object_count())).unwrap(), 15);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_wal_records_below_snapshot_watermark_are_skipped() {
        // Simulate a crash BETWEEN snapshot write and WAL reset: the
        // WAL still holds records the snapshot covers. Replaying them
        // would double-apply (PutObject mints a fresh version).
        let dir = durable_dir("watermark");
        {
            let (m, _) = ReplicatedMeta::durable(3, 99, durable_opts(&dir, 1000)).unwrap();
            m.submit(MetaCommand::CreateNamespace { user: "UserA".into() }).unwrap();
            for i in 0..4 {
                m.submit(put_cmd(&format!("o{i}"), i)).unwrap();
            }
            // Hand-write the snapshot covering all 5 commits but leave
            // the WAL un-reset — exactly the crash window.
            crate::durability::snapshot::save(
                &dir,
                5,
                111,
                m.replica_store(0).snapshot_value(),
            )
            .unwrap();
        }
        let (m, rec) = ReplicatedMeta::durable(3, 99, durable_opts(&dir, 1000)).unwrap();
        assert!(rec.snapshot_loaded);
        assert_eq!(rec.wal_records, 5);
        assert_eq!(rec.wal_replayed, 0, "covered records skipped");
        assert_eq!(m.read(|s| Ok(s.object_count())).unwrap(), 4);
        // No duplicate versions: each object has exactly version 0.
        let meta = m.read(|s| s.get_latest("UserA", "/UserA", "o0")).unwrap();
        assert_eq!(meta.version, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_wal_tail_recovers_the_intact_prefix() {
        let dir = durable_dir("torn");
        {
            let (m, _) = ReplicatedMeta::durable(3, 99, durable_opts(&dir, 1000)).unwrap();
            m.submit(MetaCommand::CreateNamespace { user: "UserA".into() }).unwrap();
            for i in 0..3 {
                m.submit(put_cmd(&format!("o{i}"), i)).unwrap();
            }
        }
        // Tear the last record (crash mid-append).
        let wal_path = dir.join(WAL_FILE);
        let len = std::fs::metadata(&wal_path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&wal_path).unwrap();
        f.set_len(len - 2).unwrap();
        drop(f);
        let (m, rec) = ReplicatedMeta::durable(3, 99, durable_opts(&dir, 1000)).unwrap();
        assert!(rec.wal_truncated);
        assert_eq!(rec.wal_replayed, 3, "namespace + first two puts survive");
        assert_eq!(m.read(|s| Ok(s.object_count())).unwrap(), 2);
        assert!(m.read(|s| s.get_latest("UserA", "/UserA", "o2")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn keyed_durability_snapshots_incrementally_and_recovers() {
        let dir = durable_dir("keyed");
        let uuid_before;
        {
            let (m, rec) =
                ReplicatedMeta::durable_keyed(3, 99, durable_opts(&dir, 4)).unwrap();
            assert!(!rec.recovered());
            m.submit(MetaCommand::CreateNamespace { user: "UserA".into() }).unwrap();
            for i in 0..9 {
                m.submit(put_cmd(&format!("o{i}"), i)).unwrap();
            }
            // Same cadence arithmetic as the full-JSON path: snapshots
            // at commits 4 and 8 reset the WAL; 2 commits remain.
            assert_eq!(m.wal_len(), 2);
            assert_eq!(m.committed_seq(), 10);
            assert!(m.last_snapshot_unix() > 0);
            uuid_before =
                m.read(|s| s.get_latest("UserA", "/UserA", "o8")).unwrap().uuid;
        }
        let (m, rec) = ReplicatedMeta::durable_keyed(3, 99, durable_opts(&dir, 4)).unwrap();
        assert!(rec.snapshot_loaded);
        assert_eq!(rec.snapshot_commits, 8);
        assert_eq!(rec.wal_replayed, 2);
        assert_eq!(m.committed_seq(), 10);
        let after = m.read(|s| s.get_latest("UserA", "/UserA", "o8")).unwrap();
        assert_eq!(after.uuid, uuid_before, "uuid sequence survives keyed recovery");
        // The recovered deployment keeps committing and snapshotting.
        for i in 9..15 {
            m.submit(put_cmd(&format!("o{i}"), i)).unwrap();
        }
        assert_eq!(m.read(|s| Ok(s.object_count())).unwrap(), 15);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn keyed_and_full_json_recover_identical_state() {
        let a_dir = durable_dir("keyed-eq-a");
        let b_dir = durable_dir("keyed-eq-b");
        {
            let (a, _) = ReplicatedMeta::durable(1, 99, durable_opts(&a_dir, 3)).unwrap();
            let (b, _) =
                ReplicatedMeta::durable_keyed(1, 99, durable_opts(&b_dir, 3)).unwrap();
            for m in [&a, &b] {
                m.submit(MetaCommand::CreateNamespace { user: "UserA".into() }).unwrap();
                for i in 0..7 {
                    m.submit(put_cmd(&format!("o{i}"), i)).unwrap();
                }
                m.submit(MetaCommand::Evict {
                    caller: "UserA".into(),
                    collection: "/UserA".into(),
                    name: "o3".into(),
                })
                .unwrap();
            }
        }
        let (a, _) = ReplicatedMeta::durable(1, 99, durable_opts(&a_dir, 3)).unwrap();
        let (b, _) = ReplicatedMeta::durable_keyed(1, 99, durable_opts(&b_dir, 3)).unwrap();
        // Both durability formats recover byte-identical metadata —
        // including tombstoned records and the RNG state.
        assert_eq!(
            to_string(&a.replica_store(0).snapshot_value()),
            to_string(&b.replica_store(0).snapshot_value())
        );
        std::fs::remove_dir_all(&a_dir).ok();
        std::fs::remove_dir_all(&b_dir).ok();
    }

    #[test]
    fn keyed_torn_wal_tail_recovers_the_intact_prefix() {
        let dir = durable_dir("keyed-torn");
        {
            let (m, _) =
                ReplicatedMeta::durable_keyed(3, 99, durable_opts(&dir, 1000)).unwrap();
            m.submit(MetaCommand::CreateNamespace { user: "UserA".into() }).unwrap();
            for i in 0..3 {
                m.submit(put_cmd(&format!("o{i}"), i)).unwrap();
            }
        }
        let wal_path = dir.join(WAL_FILE);
        let len = std::fs::metadata(&wal_path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&wal_path).unwrap();
        f.set_len(len - 2).unwrap();
        drop(f);
        let (m, rec) = ReplicatedMeta::durable_keyed(3, 99, durable_opts(&dir, 1000)).unwrap();
        assert!(rec.wal_truncated);
        assert_eq!(rec.wal_replayed, 3);
        assert_eq!(m.read(|s| Ok(s.object_count())).unwrap(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn non_durable_meta_reports_inert_durability() {
        let m = setup(3);
        assert!(!m.is_durable());
        assert_eq!(m.wal_len(), 0);
        assert_eq!(m.last_snapshot_unix(), 0);
        assert_eq!(m.committed_seq(), 0);
    }

    #[test]
    fn multipart_replicates_and_survives_restart() {
        let dir = durable_dir("multipart");
        let upload_id;
        {
            let (m, _) = ReplicatedMeta::durable(3, 99, durable_opts(&dir, 1000)).unwrap();
            m.submit(MetaCommand::CreateNamespace { user: "UserA".into() }).unwrap();
            upload_id = match m
                .submit(MetaCommand::MultipartInit {
                    caller: "UserA".into(),
                    collection: "/UserA".into(),
                    name: "big".into(),
                    now: 1,
                })
                .unwrap()
            {
                CommandOutcome::UploadId(id) => id,
                other => panic!("unexpected outcome {other:?}"),
            };
            // All replicas minted the same id from the shared RNG seed.
            for r in 0..3 {
                assert_eq!(m.replica_store(r).open_upload_count(), 1);
            }
            m.submit(MetaCommand::MultipartPut {
                caller: "UserA".into(),
                upload_id: upload_id.clone(),
                part: PartManifest {
                    number: 1,
                    size: 10,
                    sha3: [1; 32],
                    n: 3,
                    k: 2,
                    chunks: vec![(0, 1), (1, 2), (2, 3)],
                },
            })
            .unwrap();
            // Hard drop mid-upload: resumability is the point.
        }
        let (m, rec) = ReplicatedMeta::durable(3, 99, durable_opts(&dir, 1000)).unwrap();
        assert!(rec.recovered());
        let up = m.read(|s| s.multipart_parts("UserA", &upload_id)).unwrap();
        assert_eq!(up.parts.keys().copied().collect::<Vec<_>>(), vec![1]);
        let out = m
            .submit(MetaCommand::MultipartComplete {
                caller: "UserA".into(),
                upload_id: upload_id.clone(),
                now: 2,
            })
            .unwrap();
        let meta = match out {
            CommandOutcome::Meta(meta) => meta,
            other => panic!("unexpected outcome {other:?}"),
        };
        assert_eq!(meta.size, 10);
        assert!(matches!(meta.placement, ObjectPlacement::Striped { .. }));
        for r in 0..3 {
            assert_eq!(m.replica_store(r).open_upload_count(), 0);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_writers_serialize() {
        let m = setup(3);
        let mut handles = Vec::new();
        for t in 0..4 {
            let m = Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for i in 0..5 {
                    m.submit(put_cmd(&format!("t{t}-o{i}"), i)).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let count = m.read(|s| Ok(s.object_count())).unwrap();
        assert_eq!(count, 20);
        // All replicas converged.
        for r in 0..3 {
            assert_eq!(m.replica_store(r).object_count(), 20);
        }
    }
}
