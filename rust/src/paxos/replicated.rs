//! Replicated metadata: the paper's §IV-B update protocol end to end.
//!
//! Commands are serialized to JSON, sequenced through [`PaxosGroup`],
//! and applied to N deterministic [`MetadataStore`] replicas in slot
//! order. A writer holds the exclusive side of an RwLock through
//! propose + apply — the paper's "read operations are temporarily locked
//! until the metadata is fully updated" — so reads (shared side) always
//! observe fully committed state: strong read-after-write.
//!
//! Replica crash/recovery: a dead replica misses applies; on revival,
//! [`ReplicatedMeta::sync`] replays the chosen log from its applied
//! cursor. Determinism (same seed, same command order) guarantees
//! convergence to byte-identical stores — asserted by tests.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::json::{obj, parse, to_string, Value};
use crate::metadata::{MetadataStore, ObjectMeta, ObjectPlacement, Permission};
use crate::paxos::PaxosGroup;
use crate::util::{from_hex, to_hex};
use crate::{Error, Result};

/// A metadata mutation, serializable for the Paxos log.
#[derive(Debug, Clone, PartialEq)]
pub enum MetaCommand {
    CreateNamespace { user: String },
    CreateCollection { caller: String, path: String },
    Grant { caller: String, path: String, user: String, perm: Permission },
    Revoke { caller: String, path: String, user: String, perm: Permission },
    PutObject {
        caller: String,
        collection: String,
        name: String,
        size: u64,
        sha3: [u8; 32],
        placement: ObjectPlacement,
        now: u64,
    },
    Evict { caller: String, collection: String, name: String },
    Gc { now: u64, retention_secs: u64 },
    /// Health-repair / migration placement update (not a user-facing
    /// op). `expect` makes the commit a compare-and-swap: it fails if
    /// the stored placement no longer matches, so concurrent
    /// repair/migration writers can't overwrite each other (`None` =
    /// unconditional).
    UpdatePlacement {
        uuid: String,
        placement: ObjectPlacement,
        expect: Option<ObjectPlacement>,
    },
}

impl MetaCommand {
    pub fn to_json(&self) -> String {
        let v = match self {
            MetaCommand::CreateNamespace { user } => {
                obj(vec![("op", "create_ns".into()), ("user", user.as_str().into())])
            }
            MetaCommand::CreateCollection { caller, path } => obj(vec![
                ("op", "create_col".into()),
                ("caller", caller.as_str().into()),
                ("path", path.as_str().into()),
            ]),
            MetaCommand::Grant { caller, path, user, perm } => obj(vec![
                ("op", "grant".into()),
                ("caller", caller.as_str().into()),
                ("path", path.as_str().into()),
                ("user", user.as_str().into()),
                ("perm", perm_str(*perm).into()),
            ]),
            MetaCommand::Revoke { caller, path, user, perm } => obj(vec![
                ("op", "revoke".into()),
                ("caller", caller.as_str().into()),
                ("path", path.as_str().into()),
                ("user", user.as_str().into()),
                ("perm", perm_str(*perm).into()),
            ]),
            MetaCommand::PutObject { caller, collection, name, size, sha3, placement, now } => {
                obj(vec![
                    ("op", "put".into()),
                    ("caller", caller.as_str().into()),
                    ("collection", collection.as_str().into()),
                    ("name", name.as_str().into()),
                    ("size", (*size).into()),
                    ("sha3", to_hex(sha3).into()),
                    ("placement", placement_json(placement)),
                    ("now", (*now).into()),
                ])
            }
            MetaCommand::Evict { caller, collection, name } => obj(vec![
                ("op", "evict".into()),
                ("caller", caller.as_str().into()),
                ("collection", collection.as_str().into()),
                ("name", name.as_str().into()),
            ]),
            MetaCommand::Gc { now, retention_secs } => obj(vec![
                ("op", "gc".into()),
                ("now", (*now).into()),
                ("retention", (*retention_secs).into()),
            ]),
            MetaCommand::UpdatePlacement { uuid, placement, expect } => {
                let mut fields = vec![
                    ("op", "update_placement".into()),
                    ("uuid", uuid.as_str().into()),
                    ("placement", placement_json(placement)),
                ];
                if let Some(exp) = expect {
                    fields.push(("expect", placement_json(exp)));
                }
                obj(fields)
            }
        };
        to_string(&v)
    }

    pub fn from_json(text: &str) -> Result<MetaCommand> {
        let v = parse(text)?;
        let op = v.req_str("op")?;
        Ok(match op {
            "create_ns" => MetaCommand::CreateNamespace { user: v.req_str("user")?.into() },
            "create_col" => MetaCommand::CreateCollection {
                caller: v.req_str("caller")?.into(),
                path: v.req_str("path")?.into(),
            },
            "grant" | "revoke" => {
                let perm = parse_perm(v.req_str("perm")?)?;
                let (caller, path, user) = (
                    v.req_str("caller")?.to_string(),
                    v.req_str("path")?.to_string(),
                    v.req_str("user")?.to_string(),
                );
                if op == "grant" {
                    MetaCommand::Grant { caller, path, user, perm }
                } else {
                    MetaCommand::Revoke { caller, path, user, perm }
                }
            }
            "put" => {
                let sha3_vec = from_hex(v.req_str("sha3")?)
                    .ok_or_else(|| Error::Json("bad sha3 hex".into()))?;
                let sha3: [u8; 32] =
                    sha3_vec.try_into().map_err(|_| Error::Json("sha3 length".into()))?;
                MetaCommand::PutObject {
                    caller: v.req_str("caller")?.into(),
                    collection: v.req_str("collection")?.into(),
                    name: v.req_str("name")?.into(),
                    size: v.req_u64("size")?,
                    sha3,
                    placement: placement_from_json(v.get("placement"))?,
                    now: v.req_u64("now")?,
                }
            }
            "evict" => MetaCommand::Evict {
                caller: v.req_str("caller")?.into(),
                collection: v.req_str("collection")?.into(),
                name: v.req_str("name")?.into(),
            },
            "gc" => MetaCommand::Gc {
                now: v.req_u64("now")?,
                retention_secs: v.req_u64("retention")?,
            },
            "update_placement" => MetaCommand::UpdatePlacement {
                uuid: v.req_str("uuid")?.into(),
                placement: placement_from_json(v.get("placement"))?,
                expect: match v.get("expect") {
                    Value::Null => None,
                    other => Some(placement_from_json(other)?),
                },
            },
            other => return Err(Error::Json(format!("unknown op '{other}'"))),
        })
    }
}

fn perm_str(p: Permission) -> &'static str {
    match p {
        Permission::Read => "read",
        Permission::Write => "write",
    }
}

fn parse_perm(s: &str) -> Result<Permission> {
    match s {
        "read" => Ok(Permission::Read),
        "write" => Ok(Permission::Write),
        _ => Err(Error::Json(format!("bad perm '{s}'"))),
    }
}

fn placement_json(p: &ObjectPlacement) -> Value {
    match p {
        ObjectPlacement::Single { container } => obj(vec![
            ("type", "single".into()),
            ("container", (*container as u64).into()),
        ]),
        ObjectPlacement::Erasure { n, k, chunks } => obj(vec![
            ("type", "erasure".into()),
            ("n", (*n).into()),
            ("k", (*k).into()),
            (
                "chunks",
                Value::Arr(
                    chunks
                        .iter()
                        .map(|&(i, c)| {
                            Value::Arr(vec![(i as u64).into(), (c as u64).into()])
                        })
                        .collect(),
                ),
            ),
        ]),
    }
}

fn placement_from_json(v: &Value) -> Result<ObjectPlacement> {
    match v.req_str("type")? {
        "single" => Ok(ObjectPlacement::Single { container: v.req_u64("container")? as u32 }),
        "erasure" => {
            let chunks = v
                .get("chunks")
                .as_arr()
                .ok_or_else(|| Error::Json("chunks".into()))?
                .iter()
                .map(|pair| {
                    let a = pair.as_arr().ok_or_else(|| Error::Json("chunk pair".into()))?;
                    Ok((
                        a[0].as_u64().ok_or_else(|| Error::Json("idx".into()))? as u8,
                        a[1].as_u64().ok_or_else(|| Error::Json("cid".into()))? as u32,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(ObjectPlacement::Erasure {
                n: v.req_u64("n")? as usize,
                k: v.req_u64("k")? as usize,
                chunks,
            })
        }
        other => Err(Error::Json(format!("bad placement type '{other}'"))),
    }
}

/// One metadata replica: deterministic store + applied-log cursor.
struct Replica {
    store: MetadataStore,
    applied: AtomicU64,
    alive: AtomicBool,
}

/// The replicated metadata service.
pub struct ReplicatedMeta {
    group: PaxosGroup,
    replicas: Vec<Replica>,
    /// Writers exclusive through propose+apply; readers shared — the
    /// §IV-B read lock during updates.
    rw: RwLock<()>,
}

impl ReplicatedMeta {
    /// `replica_count` must be odd (Paxos quorums).
    pub fn new(replica_count: usize, seed: u64) -> Arc<Self> {
        Arc::new(ReplicatedMeta {
            group: PaxosGroup::new(replica_count),
            replicas: (0..replica_count)
                .map(|_| Replica {
                    store: MetadataStore::new(seed),
                    applied: AtomicU64::new(0),
                    alive: AtomicBool::new(true),
                })
                .collect(),
            rw: RwLock::new(()),
        })
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Crash/revive a replica (both its acceptor and state machine).
    pub fn set_replica_alive(&self, id: usize, alive: bool) {
        self.group.acceptor(id).set_alive(alive);
        self.replicas[id].alive.store(alive, Ordering::SeqCst);
        if alive {
            // Catch up a revived replica under the write lock.
            let _w = self.rw.write().unwrap();
            self.sync(id);
        }
    }

    /// Replay the chosen log onto replica `id` from its cursor.
    fn sync(&self, id: usize) {
        let log = self.group.log_snapshot();
        let r = &self.replicas[id];
        let mut cursor = r.applied.load(Ordering::SeqCst);
        while (cursor as usize) < log.len() {
            match &log[cursor as usize] {
                Some(entry) => {
                    if let Ok(cmd) = MetaCommand::from_json(entry) {
                        let _ = apply(&r.store, &cmd); // deterministic
                    }
                    cursor += 1;
                }
                None => break, // hole: stop (never happens with serialized writers)
            }
        }
        r.applied.store(cursor, Ordering::SeqCst);
    }

    /// Propose a command through Paxos and apply it on every live
    /// replica. Returns the command's own result (from the first live
    /// replica). Fails with `Consensus` if no quorum.
    pub fn submit(&self, cmd: MetaCommand) -> Result<CommandOutcome> {
        self.submit_guarded(cmd, || Ok(()))
    }

    /// Like [`ReplicatedMeta::submit`], but run `precheck` under the
    /// exclusive metadata lock first, aborting the proposal (no slot
    /// consumed) if it fails. Readers and writers serialize against the
    /// same lock, so the precheck is atomic with the commit — push uses
    /// this to validate placement targets against the registry's
    /// draining state at the last possible instant.
    pub fn submit_guarded(
        &self,
        cmd: MetaCommand,
        precheck: impl FnOnce() -> Result<()>,
    ) -> Result<CommandOutcome> {
        let _w = self.rw.write().unwrap();
        precheck()?;
        let payload = cmd.to_json();
        let _slot = self.group.propose_owned(0, payload)?;
        let mut outcome: Option<CommandOutcome> = None;
        for r in &self.replicas {
            if !r.alive.load(Ordering::SeqCst) {
                continue;
            }
            // Apply any backlog first (revived replicas), then this.
            let log = self.group.log_snapshot();
            let mut cursor = r.applied.load(Ordering::SeqCst);
            while (cursor as usize) < log.len() {
                if let Some(entry) = &log[cursor as usize] {
                    let parsed = MetaCommand::from_json(entry)?;
                    let res = apply(&r.store, &parsed);
                    if outcome.is_none() {
                        outcome = Some(res);
                    }
                    cursor += 1;
                } else {
                    break;
                }
            }
            r.applied.store(cursor, Ordering::SeqCst);
        }
        outcome.ok_or_else(|| Error::Consensus("no live replica applied the command".into()))
    }

    /// Read from the first live, fully-applied replica (shared lock —
    /// blocks while a writer is mid-update, per §IV-B).
    pub fn read<T>(&self, f: impl Fn(&MetadataStore) -> Result<T>) -> Result<T> {
        let _r = self.rw.read().unwrap();
        let target = self.group.log_snapshot().len() as u64;
        for r in &self.replicas {
            if r.alive.load(Ordering::SeqCst) && r.applied.load(Ordering::SeqCst) >= target {
                return f(&r.store);
            }
        }
        Err(Error::Unavailable("no up-to-date metadata replica".into()))
    }

    /// Direct store access for invariant checks in tests.
    pub fn replica_store(&self, id: usize) -> &MetadataStore {
        &self.replicas[id].store
    }

    pub fn applied_cursor(&self, id: usize) -> u64 {
        self.replicas[id].applied.load(Ordering::SeqCst)
    }
}

/// Result of applying a command to a store (deterministic per replica).
#[derive(Debug, Clone)]
pub enum CommandOutcome {
    Ok,
    Meta(Box<ObjectMeta>),
    Evicted(Vec<ObjectMeta>),
    Collected(Vec<ObjectMeta>),
    Failed(String),
}

fn apply(store: &MetadataStore, cmd: &MetaCommand) -> CommandOutcome {
    let as_outcome = |r: Result<()>| match r {
        Ok(()) => CommandOutcome::Ok,
        Err(e) => CommandOutcome::Failed(e.to_string()),
    };
    match cmd {
        MetaCommand::CreateNamespace { user } => {
            as_outcome(store.create_namespace(user).map(|_| ()))
        }
        MetaCommand::CreateCollection { caller, path } => {
            as_outcome(store.create_collection(caller, path).map(|_| ()))
        }
        MetaCommand::Grant { caller, path, user, perm } => {
            as_outcome(store.grant(caller, path, user, *perm))
        }
        MetaCommand::Revoke { caller, path, user, perm } => {
            as_outcome(store.revoke(caller, path, user, *perm))
        }
        MetaCommand::PutObject { caller, collection, name, size, sha3, placement, now } => {
            match store.put_object(caller, collection, name, *size, *sha3, placement.clone(), *now)
            {
                Ok(meta) => CommandOutcome::Meta(Box::new(meta)),
                Err(e) => CommandOutcome::Failed(e.to_string()),
            }
        }
        MetaCommand::Evict { caller, collection, name } => {
            match store.evict(caller, collection, name) {
                Ok(metas) => CommandOutcome::Evicted(metas),
                Err(e) => CommandOutcome::Failed(e.to_string()),
            }
        }
        MetaCommand::Gc { now, retention_secs } => {
            CommandOutcome::Collected(store.gc(*now, *retention_secs))
        }
        MetaCommand::UpdatePlacement { uuid, placement, expect } => {
            as_outcome(store.update_placement(uuid, placement.clone(), expect.as_ref()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn put_cmd(name: &str, t: u64) -> MetaCommand {
        MetaCommand::PutObject {
            caller: "UserA".into(),
            collection: "/UserA".into(),
            name: name.into(),
            size: 42,
            sha3: [7; 32],
            placement: ObjectPlacement::Erasure {
                n: 3,
                k: 2,
                chunks: vec![(0, 1), (1, 2), (2, 3)],
            },
            now: t,
        }
    }

    fn setup(replicas: usize) -> Arc<ReplicatedMeta> {
        let m = ReplicatedMeta::new(replicas, 99);
        m.submit(MetaCommand::CreateNamespace { user: "UserA".into() }).unwrap();
        m
    }

    #[test]
    fn command_json_roundtrip() {
        let cmds = vec![
            MetaCommand::CreateNamespace { user: "u".into() },
            MetaCommand::CreateCollection { caller: "u".into(), path: "/u/c".into() },
            MetaCommand::Grant {
                caller: "u".into(),
                path: "/u/c".into(),
                user: "v".into(),
                perm: Permission::Read,
            },
            MetaCommand::Revoke {
                caller: "u".into(),
                path: "/u/c".into(),
                user: "v".into(),
                perm: Permission::Write,
            },
            put_cmd("obj", 5),
            MetaCommand::Evict { caller: "u".into(), collection: "/u".into(), name: "o".into() },
            MetaCommand::Gc { now: 100, retention_secs: 60 },
            MetaCommand::UpdatePlacement {
                uuid: "u-1".into(),
                placement: ObjectPlacement::Single { container: 4 },
                expect: None,
            },
            MetaCommand::UpdatePlacement {
                uuid: "u-2".into(),
                placement: ObjectPlacement::Erasure {
                    n: 3,
                    k: 2,
                    chunks: vec![(0, 1), (1, 2), (2, 3)],
                },
                expect: Some(ObjectPlacement::Erasure {
                    n: 3,
                    k: 2,
                    chunks: vec![(0, 1), (1, 2), (2, 9)],
                }),
            },
        ];
        for cmd in cmds {
            let json = cmd.to_json();
            assert_eq!(MetaCommand::from_json(&json).unwrap(), cmd, "{json}");
        }
    }

    #[test]
    fn replicas_converge_to_identical_state() {
        let m = setup(3);
        for i in 0..10 {
            m.submit(put_cmd(&format!("obj{i}"), i)).unwrap();
        }
        // Every replica applied every slot; stores agree on uuids.
        for name in ["obj0", "obj5", "obj9"] {
            let metas: Vec<ObjectMeta> = (0..3)
                .map(|r| m.replica_store(r).get_latest("UserA", "/UserA", name).unwrap())
                .collect();
            assert_eq!(metas[0], metas[1]);
            assert_eq!(metas[1], metas[2]);
        }
    }

    #[test]
    fn read_after_write_sees_latest() {
        let m = setup(3);
        let out = m.submit(put_cmd("obj", 1)).unwrap();
        let uuid = match out {
            CommandOutcome::Meta(meta) => meta.uuid,
            other => panic!("unexpected outcome {other:?}"),
        };
        let read =
            m.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        assert_eq!(read.uuid, uuid);
    }

    #[test]
    fn survives_minority_replica_failure() {
        let m = setup(5);
        m.set_replica_alive(4, false);
        m.set_replica_alive(3, false);
        m.submit(put_cmd("obj", 1)).unwrap();
        let meta = m.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        assert_eq!(meta.size, 42);
    }

    #[test]
    fn majority_failure_rejects_writes() {
        let m = setup(3);
        m.set_replica_alive(1, false);
        m.set_replica_alive(2, false);
        let err = m.submit(put_cmd("obj", 1)).unwrap_err();
        assert!(matches!(err, Error::Consensus(_)));
    }

    #[test]
    fn revived_replica_catches_up() {
        let m = setup(5);
        m.set_replica_alive(2, false);
        for i in 0..5 {
            m.submit(put_cmd(&format!("o{i}"), i)).unwrap();
        }
        assert!(m.applied_cursor(2) < m.applied_cursor(0));
        m.set_replica_alive(2, true);
        assert_eq!(m.applied_cursor(2), m.applied_cursor(0));
        // And its state matches replica 0 exactly.
        let a = m.replica_store(0).get_latest("UserA", "/UserA", "o4").unwrap();
        let b = m.replica_store(2).get_latest("UserA", "/UserA", "o4").unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn failed_commands_replicate_deterministically() {
        let m = setup(3);
        // Permission failure must not desync replicas.
        let out = m
            .submit(MetaCommand::CreateCollection {
                caller: "Mallory".into(),
                path: "/UserA/Steal".into(),
            })
            .unwrap();
        assert!(matches!(out, CommandOutcome::Failed(_)));
        for r in 0..3 {
            assert!(!m.replica_store(r).collection_exists("/UserA/Steal"));
        }
        // System still writable.
        m.submit(put_cmd("obj", 1)).unwrap();
    }

    #[test]
    fn concurrent_writers_serialize() {
        let m = setup(3);
        let mut handles = Vec::new();
        for t in 0..4 {
            let m = Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for i in 0..5 {
                    m.submit(put_cmd(&format!("t{t}-o{i}"), i)).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let count = m.read(|s| Ok(s.object_count())).unwrap();
        assert_eq!(count, 20);
        // All replicas converged.
        for r in 0..3 {
            assert_eq!(m.replica_store(r).object_count(), 20);
        }
    }
}
