//! Recursive-descent JSON parser (RFC 8259 subset: no \u surrogate-pair
//! exotica beyond BMP, numbers as f64).

use std::collections::BTreeMap;

use super::Value;
use crate::{Error, Result};

/// Parse a complete JSON document; trailing whitespace allowed, trailing
/// garbage is an error.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.i))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let code = self.hex4()?;
                            // BMP only; surrogate halves map to U+FFFD.
                            s.push(char::from_u32(code as u32).unwrap_or('\u{FFFD}'));
                            continue;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(_) => {
                    // Copy one UTF-8 scalar (input is &str, so valid UTF-8).
                    let start = self.i;
                    let rest = &self.b[start..];
                    let len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16> {
        let mut v: u16 = 0;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("eof in \\u"))?;
            let d = match c {
                b'0'..=b'9' => c - b'0',
                b'a'..=b'f' => c - b'a' + 10,
                b'A'..=b'F' => c - b'A' + 10,
                _ => return Err(self.err("bad hex digit")),
            };
            v = (v << 4) | d as u16;
            self.i += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::super::{obj, to_string, Value};
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn nested_document() {
        let doc = r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}, "f": true}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].req_str("b").unwrap(), "c");
        assert_eq!(v.get("d").get("e"), &Value::Null);
    }

    #[test]
    fn escapes() {
        let v = parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\A");
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"caf\u{e9} \u{4e16}\u{754c}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café 世界");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1,}").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_through_writer() {
        let v = obj(vec![
            ("x", 1u64.into()),
            ("s", "he\"llo\n".into()),
            ("a", Value::Arr(vec![Value::Null, false.into()])),
        ]);
        let text = to_string(&v);
        assert_eq!(parse(&text).unwrap(), v);
    }
}
