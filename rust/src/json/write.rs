//! JSON writer: compact and pretty forms, deterministic key order
//! (Value::Obj is a BTreeMap).

use super::Value;

/// Compact single-line JSON.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, None, 0);
    out
}

/// Pretty-printed JSON with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(2), 0);
    out
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_str(s, out),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Value::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_str(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no Inf/NaN
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::{obj, parse, Value};
    use super::*;

    #[test]
    fn compact_output() {
        let v = obj(vec![("b", 2u64.into()), ("a", Value::Arr(vec![1u64.into()]))]);
        assert_eq!(to_string(&v), r#"{"a":[1],"b":2}"#);
    }

    #[test]
    fn pretty_output_parses_back() {
        let v = obj(vec![
            ("outer", obj(vec![("inner", Value::Arr(vec![1u64.into(), 2u64.into()]))])),
        ]);
        let pretty = to_string_pretty(&v);
        assert!(pretty.contains('\n'));
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_have_no_decimal_point() {
        assert_eq!(to_string(&Value::Num(100.0)), "100");
        assert_eq!(to_string(&Value::Num(0.25)), "0.25");
    }

    #[test]
    fn control_chars_escaped() {
        let s = to_string(&Value::Str("a\u{1}b".into()));
        assert_eq!(s, "\"a\\u0001b\"");
        assert_eq!(parse(&s).unwrap(), Value::Str("a\u{1}b".into()));
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(to_string(&Value::Num(f64::NAN)), "null");
        assert_eq!(to_string(&Value::Num(f64::INFINITY)), "null");
    }
}
