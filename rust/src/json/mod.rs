//! Minimal JSON: a `Value` tree, a recursive-descent parser, and a
//! writer. Stands in for serde/serde_json (absent from the vendored
//! crate set); used by the config system, the artifact manifest loader,
//! the REST gateway, and the bench harness's machine-readable output.

mod parse;
mod write;

pub use parse::parse;
pub use write::{to_string, to_string_pretty};

use std::collections::BTreeMap;

use crate::{Error, Result};

/// A JSON value. Objects are ordered maps (BTreeMap) so output is
/// deterministic — important for golden tests and manifest diffs.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Null` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Typed field accessors that produce crate errors for config code.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| Error::Json(format!("missing string field '{key}'")))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.get(key)
            .as_u64()
            .ok_or_else(|| Error::Json(format!("missing integer field '{key}'")))
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).as_u64().unwrap_or(default)
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).as_f64().unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).as_str().unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).as_bool().unwrap_or(default)
    }
}

/// Convenience constructor for object values.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}

impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}

impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}

impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

impl From<Vec<Value>> for Value {
    fn from(a: Vec<Value>) -> Self {
        Value::Arr(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let v = obj(vec![
            ("name", "dyno".into()),
            ("n", 10u64.into()),
            ("ratio", 0.5.into()),
            ("on", true.into()),
            ("tags", Value::Arr(vec!["a".into(), "b".into()])),
        ]);
        assert_eq!(v.req_str("name").unwrap(), "dyno");
        assert_eq!(v.req_u64("n").unwrap(), 10);
        assert_eq!(v.opt_f64("ratio", 0.0), 0.5);
        assert!(v.opt_bool("on", false));
        assert_eq!(v.get("tags").as_arr().unwrap().len(), 2);
        assert_eq!(v.get("missing"), &Value::Null);
        assert!(v.req_str("missing").is_err());
    }

    #[test]
    fn u64_rejects_fractions_and_negatives() {
        assert_eq!(Value::Num(1.5).as_u64(), None);
        assert_eq!(Value::Num(-2.0).as_u64(), None);
        assert_eq!(Value::Num(7.0).as_u64(), Some(7));
    }
}
