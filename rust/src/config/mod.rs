//! Configuration system: a JSON cluster specification (the paper's
//! §III-A "configuration file that specifies the container's name,
//! storage path, and access parameters", plus the management-service
//! knobs) parsed into a [`Config`] and instantiable as a running
//! [`DynoStore`] deployment.
//!
//! ```json
//! {
//!   "gateway_site": "chameleon-uc",
//!   "metadata_replicas": 3,
//!   "meta_shards": 1,
//!   "policy": {"type": "erasure", "n": 10, "k": 7},
//!   "weights": {"w1_mem": 0.5, "w2_fs": 0.5},
//!   "engine": "swar-parallel",
//!   "containers": [
//!     {"name": "dc0", "site": "chameleon-tacc", "device": "chameleon-local",
//!      "mem_mb": 256, "fs_gb": 1024, "afr": 0.05,
//!      "faults": {"error_rate": 0.1, "corrupt_rate": 0.05}}
//!   ],
//!   "chaos_seed": 7,
//!   "scrub": {"interval_secs": 30, "sample": 64},
//!   "conn_timeout_secs": 10,
//!   "net": {"reactor": true, "max_connections": 4096, "max_inflight": 1024,
//!           "keepalive_idle_secs": 60, "client_pool_per_host": 8}
//! }
//! ```
//!
//! A container entry may carry a `faults` script (see
//! [`crate::sim::FaultSpec`]): its channel is wrapped in the chaos
//! plane's [`crate::sim::FaultChannel`], driven deterministically by
//! `chaos_seed` — the fault-injection harness of EXPERIMENTS.md §Faults.

use std::sync::Arc;

use crate::container::{
    deploy_containers, AgentSpec, Backend, DataContainer, FsBackend, LocalChannel,
    RemoteChannel, SimBackend,
};
use crate::coordinator::{DynoStore, GfEngine, DEFAULT_SCRUB_SAMPLE};
use crate::erasure::ErasureConfig;
use crate::json::{parse, Value};
use crate::placement::Weights;
use crate::policy::ResiliencePolicy;
use crate::sim::{Device, FaultChannel, FaultPlan, FaultSpec, Site};
use crate::tiering::{StorageTier, TierCycleOpts, DEFAULT_DURABILITY_NINES};
use crate::{Error, Result};

/// Parsed deployment configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub gateway_site: Site,
    pub metadata_replicas: usize,
    pub policy: ResiliencePolicy,
    pub weights: Weights,
    pub engine: GfEngine,
    pub containers: Vec<AgentSpec>,
    /// Remote container agents (`host:port` endpoints) to register over
    /// HTTP — entries of the `containers` array carrying an `endpoint`.
    pub remotes: Vec<String>,
    pub seed: u64,
    /// Metadata durability root (`wal.log` + `meta.snapshot`). `None`
    /// (the default) keeps the metadata plane in memory — tests and
    /// simulators; `dynostore serve --data-dir` sets it in production.
    pub data_dir: Option<String>,
    /// Compact the WAL into a snapshot every N commits.
    pub snapshot_every: u64,
    /// Independent metadata Paxos shards (1 = the legacy single-group
    /// plane and on-disk layout, byte-identical; >1 partitions the
    /// namespace keyspace with one WAL + keyed snapshot lineage per
    /// shard under `data_dir/shard-<i>/`).
    pub meta_shards: usize,
    /// Gateway request-body cap in MiB (bounds object size; a bogus
    /// `content-length` beyond it gets 413 instead of an allocation).
    pub max_body_mb: u64,
    /// Per-container fault scripts, parallel to `containers` (None =
    /// clean). Any Some wraps that container's channel in the chaos
    /// plane at build time.
    pub fault_specs: Vec<Option<FaultSpec>>,
    /// Seed driving every chaos-plane draw (deterministic fault
    /// schedules: same seed + same op sequence = same faults).
    pub chaos_seed: u64,
    /// Background scrubber cadence in seconds; 0 disables the thread
    /// (`dynostore serve` starts it when non-zero).
    pub scrub_interval_secs: u64,
    /// Objects verified per scrub cycle (0 = the whole keyspace).
    pub scrub_sample: usize,
    /// Gateway socket read/write timeout in seconds (slowloris guard;
    /// 408 when a client stalls mid-headers).
    pub conn_timeout_secs: u64,
    /// Streaming-ingest part size in MiB: object PUT bodies are
    /// erasure-coded and placed one part at a time as bytes arrive, so
    /// gateway memory per upload is ~2 parts, not the object size. Also
    /// the natural part size for client multipart uploads.
    pub part_size_mb: u64,
    /// Connection-core knobs: server engine, admission caps, keep-alive
    /// windows, client pooling (`"net": {...}`).
    pub net: NetConfig,
    /// Durability target in nines for the adaptive policy (`"policy":
    /// {"type": "adaptive"}`); also the default when a per-push
    /// `x-dyno-policy: adaptive` header omits its own target.
    pub durability_nines: f64,
    /// Per-local-container storage tiers, parallel to `containers`
    /// (None = the default `fs` tier; entries spell `"tier": "mem" |
    /// "ssd" | "fs" | "cold"`). Declaring any cache tier (mem/ssd)
    /// arms the promotion/demotion cycle.
    pub container_tiers: Vec<Option<StorageTier>>,
    /// Promotion/demotion knobs (`"tiering": {"hot_rate": …,
    /// "cold_after_secs": …, "max_objects": …, "max_moves": …}`).
    pub tier_cycle: TierCycleOpts,
}

/// Connection-core configuration (`"net"` object): which server engine
/// handles sockets, the admission-control caps, the keep-alive idle
/// window, and the outbound per-host connection-pool size.
#[derive(Debug, Clone, PartialEq)]
pub struct NetConfig {
    /// Socket engine: epoll reactor (default; falls back to threaded
    /// off Linux) or the thread-per-request loop. JSON spells it either
    /// `"engine": "reactor"|"threaded"` or `"reactor": true|false`.
    pub engine: crate::net::ServerEngine,
    /// Open-connection cap; accepts beyond it shed `503 + Retry-After`.
    pub max_connections: usize,
    /// In-flight request cap (reactor); requests beyond it shed
    /// `429 + Retry-After`.
    pub max_inflight: usize,
    /// Seconds an idle keep-alive connection may stay parked.
    pub keepalive_idle_secs: u64,
    /// Outbound keep-alive connections pooled per host; 0 disables
    /// client pooling (every request reconnects, `connection: close`).
    pub client_pool_per_host: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            engine: crate::net::ServerEngine::default(),
            max_connections: crate::net::DEFAULT_MAX_CONNECTIONS,
            max_inflight: crate::net::DEFAULT_MAX_INFLIGHT,
            keepalive_idle_secs: crate::net::DEFAULT_KEEPALIVE_IDLE.as_secs(),
            client_pool_per_host: crate::net::DEFAULT_POOL_PER_HOST,
        }
    }
}

impl NetConfig {
    /// The server-side options this configuration describes.
    pub fn server_options(&self) -> crate::net::ServerOptions {
        crate::net::ServerOptions {
            engine: self.engine,
            max_connections: self.max_connections,
            max_inflight: self.max_inflight,
            keepalive_idle: std::time::Duration::from_secs(self.keepalive_idle_secs),
            stats: None,
        }
    }
}

impl Default for Config {
    fn default() -> Self {
        Config {
            gateway_site: Site::ChameleonUc,
            metadata_replicas: 3,
            policy: ResiliencePolicy::Fixed(ErasureConfig::new(10, 7)),
            weights: Weights::default(),
            engine: GfEngine::PureRust,
            containers: Vec::new(),
            remotes: Vec::new(),
            seed: 0xD1_5705,
            data_dir: None,
            snapshot_every: crate::durability::DEFAULT_SNAPSHOT_EVERY,
            meta_shards: 1,
            max_body_mb: (crate::gateway::DEFAULT_GATEWAY_MAX_BODY >> 20) as u64,
            fault_specs: Vec::new(),
            chaos_seed: 0xC4A05,
            scrub_interval_secs: 0,
            scrub_sample: DEFAULT_SCRUB_SAMPLE,
            conn_timeout_secs: crate::net::DEFAULT_CONN_TIMEOUT.as_secs(),
            part_size_mb: (crate::gateway::DEFAULT_STREAM_PART_SIZE >> 20) as u64,
            net: NetConfig::default(),
            durability_nines: DEFAULT_DURABILITY_NINES,
            container_tiers: Vec::new(),
            tier_cycle: TierCycleOpts::default(),
        }
    }
}

impl Config {
    /// Parse a JSON configuration document.
    pub fn from_json(text: &str) -> Result<Config> {
        let v = parse(text)?;
        let mut cfg = Config::default();
        if let Some(site) = v.get("gateway_site").as_str() {
            cfg.gateway_site = Site::parse(site)
                .ok_or_else(|| Error::Config(format!("unknown site '{site}'")))?;
        }
        cfg.metadata_replicas = v.opt_u64("metadata_replicas", 3) as usize;
        if cfg.metadata_replicas % 2 == 0 {
            return Err(Error::Config("metadata_replicas must be odd".into()));
        }
        cfg.seed = v.opt_u64("seed", cfg.seed);
        cfg.durability_nines = v.opt_f64("durability_nines", cfg.durability_nines);
        if !cfg.durability_nines.is_finite()
            || cfg.durability_nines <= 0.0
            || cfg.durability_nines > 12.0
        {
            return Err(Error::Config(format!(
                "durability_nines must be in (0, 12], got {}",
                cfg.durability_nines
            )));
        }
        cfg.policy = parse_policy(v.get("policy"), cfg.durability_nines)?;
        let w = v.get("weights");
        cfg.weights = Weights {
            w1_mem: w.opt_f64("w1_mem", 0.5),
            w2_fs: w.opt_f64("w2_fs", 0.5),
        };
        let engine = v.opt_str("engine", "pure-rust");
        cfg.engine = GfEngine::parse(engine).ok_or_else(|| {
            Error::Config(format!(
                "unknown engine '{engine}' (expected pure-rust | swar | swar-parallel | pjrt)"
            ))
        })?;
        if let Some(dir) = v.get("data_dir").as_str() {
            cfg.data_dir = Some(dir.to_string());
        }
        cfg.snapshot_every = v.opt_u64("snapshot_every", cfg.snapshot_every).max(1);
        cfg.meta_shards = v.opt_u64("meta_shards", cfg.meta_shards as u64).max(1) as usize;
        cfg.max_body_mb = v.opt_u64("max_body_mb", cfg.max_body_mb).max(1);
        cfg.chaos_seed = v.opt_u64("chaos_seed", cfg.chaos_seed);
        let scrub = v.get("scrub");
        cfg.scrub_interval_secs = scrub.opt_u64("interval_secs", cfg.scrub_interval_secs);
        cfg.scrub_sample = scrub.opt_u64("sample", cfg.scrub_sample as u64) as usize;
        cfg.conn_timeout_secs =
            v.opt_u64("conn_timeout_secs", cfg.conn_timeout_secs).max(1);
        cfg.part_size_mb = v.opt_u64("part_size_mb", cfg.part_size_mb).max(1);
        let net = v.get("net");
        if let Some(engine) = net.get("engine").as_str() {
            cfg.net.engine = crate::net::ServerEngine::parse(engine).ok_or_else(|| {
                Error::Config(format!(
                    "unknown net engine '{engine}' (expected reactor | threaded)"
                ))
            })?;
        } else if let Some(reactor) = net.get("reactor").as_bool() {
            cfg.net.engine = if reactor {
                crate::net::ServerEngine::Reactor
            } else {
                crate::net::ServerEngine::Threaded
            };
        }
        cfg.net.max_connections =
            net.opt_u64("max_connections", cfg.net.max_connections as u64).max(1) as usize;
        cfg.net.max_inflight =
            net.opt_u64("max_inflight", cfg.net.max_inflight as u64).max(1) as usize;
        cfg.net.keepalive_idle_secs =
            net.opt_u64("keepalive_idle_secs", cfg.net.keepalive_idle_secs).max(1);
        // 0 is legal here: it disables client pooling entirely.
        cfg.net.client_pool_per_host =
            net.opt_u64("client_pool_per_host", cfg.net.client_pool_per_host as u64) as usize;
        let tiering = v.get("tiering");
        cfg.tier_cycle.hot_rate = tiering.opt_f64("hot_rate", cfg.tier_cycle.hot_rate);
        cfg.tier_cycle.cold_after_secs =
            tiering.opt_u64("cold_after_secs", cfg.tier_cycle.cold_after_secs);
        cfg.tier_cycle.max_objects =
            tiering.opt_u64("max_objects", cfg.tier_cycle.max_objects as u64) as usize;
        cfg.tier_cycle.max_moves =
            tiering.opt_u64("max_moves", cfg.tier_cycle.max_moves as u64) as usize;
        if let Some(arr) = v.get("containers").as_arr() {
            for c in arr {
                // An entry with an `endpoint` is a remote agent; local
                // entries are deployed in-process at build time.
                match c.get("endpoint").as_str() {
                    Some(ep) => {
                        if !matches!(c.get("faults"), &Value::Null) {
                            return Err(Error::Config(
                                "fault scripts only apply to local containers \
                                 (wrap the remote agent's own config instead)"
                                    .into(),
                            ));
                        }
                        if c.get("tier").as_str().is_some() {
                            return Err(Error::Config(
                                "storage tiers only apply to local containers \
                                 (a remote agent's id is unknown until connect)"
                                    .into(),
                            ));
                        }
                        cfg.remotes.push(ep.to_string());
                    }
                    None => {
                        cfg.containers.push(parse_container(c)?);
                        cfg.fault_specs.push(match c.get("faults") {
                            &Value::Null => None,
                            f => Some(FaultSpec::from_json(f)?),
                        });
                        cfg.container_tiers.push(match c.get("tier").as_str() {
                            Some(t) => Some(StorageTier::parse(t)?),
                            None => None,
                        });
                    }
                }
            }
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Config::from_json(&text)
    }

    /// Instantiate the deployment: build the coordinator (recovering
    /// the metadata plane from `data_dir` when configured), deploy and
    /// register every configured container, then — if any state was
    /// recovered — re-verify the recovered placements against what the
    /// containers actually hold and schedule repair for the gaps.
    pub fn build(&self) -> Result<Arc<DynoStore>> {
        // Process-wide side effect: the outbound keep-alive pool all
        // HttpClients share is sized by the deployment config.
        crate::net::client_pool().configure(self.net.client_pool_per_host);
        let mut builder = DynoStore::builder()
            .gateway_site(self.gateway_site)
            .replicas(self.metadata_replicas)
            .policy(self.policy)
            .weights(self.weights)
            .engine(self.engine)
            .seed(self.seed)
            .snapshot_every(self.snapshot_every)
            .meta_shards(self.meta_shards);
        if let Some(dir) = &self.data_dir {
            builder = builder.data_dir(dir);
        }
        let (ds, recovery) = builder.build_durable()?;
        let ds = Arc::new(ds);
        let hosts = self.containers.len().max(1);
        // Chaos plane: containers with a fault script get their channel
        // wrapped; clean ones register bare. Ids are assigned in spec
        // order by deploy_containers, so fault_specs lines up by index.
        let plan = FaultPlan::new(self.chaos_seed);
        for (i, spec) in self.fault_specs.iter().enumerate() {
            if let Some(spec) = spec {
                plan.set(i as u32, spec.clone());
            }
        }
        for c in deploy_containers(&self.containers, hosts, 0).containers {
            let channel: Arc<dyn crate::container::ContainerChannel> =
                Arc::new(LocalChannel::new(c));
            ds.add_channel(FaultChannel::wrap_if_scripted(channel, &plan))?;
        }
        // Storage tiers line up with local container ids the same way
        // fault_specs do: deploy_containers assigns ids in spec order.
        for (i, tier) in self.container_tiers.iter().enumerate() {
            if let Some(t) = tier {
                ds.set_container_tier(i as u32, *t)?;
            }
        }
        // Remote agents must be reachable at build time: the channel
        // adopts the agent's self-reported identity (id, site, capacity).
        for endpoint in &self.remotes {
            ds.add_channel(RemoteChannel::connect(endpoint)?)?;
        }
        if recovery.recovered() {
            crate::log_info!(
                "metadata recovered from {}: snapshot {} (covering {} commits), \
                 {} WAL records replayed{}",
                self.data_dir.as_deref().unwrap_or("?"),
                if recovery.snapshot_loaded { "loaded" } else { "absent" },
                recovery.snapshot_commits,
                recovery.wal_replayed,
                if recovery.wal_truncated { ", torn tail truncated" } else { "" }
            );
            let verify = ds.verify_recovered_placements()?;
            crate::log_info!(
                "recovered placements verified: {} objects, {} chunks missing, \
                 {} rewritten in place, {} lost{}",
                verify.objects,
                verify.chunks_missing,
                verify.chunks_rewritten,
                verify.objects_lost,
                if verify.repair_scheduled {
                    format!(
                        " (repair pass: {} repaired, {} chunks moved)",
                        verify.repair.repaired, verify.repair.chunks_moved
                    )
                } else {
                    String::new()
                }
            );
        }
        Ok(ds)
    }
}

fn parse_policy(v: &Value, default_nines: f64) -> Result<ResiliencePolicy> {
    match v.opt_str("type", "erasure") {
        "regular" => Ok(ResiliencePolicy::Regular),
        "erasure" => {
            let n = v.opt_u64("n", 10) as usize;
            let k = v.opt_u64("k", 7) as usize;
            let cfg = ErasureConfig::new(n, k);
            cfg.validate()?;
            Ok(ResiliencePolicy::Fixed(cfg))
        }
        "dynamic" => Ok(ResiliencePolicy::Dynamic {
            k: v.opt_u64("k", 4) as usize,
            target_loss: v.opt_f64("target_loss", crate::policy::PAPER_TARGET_LOSS),
        }),
        // Scorecard-driven per-object (k, n): the policy block may pin
        // its own target, else the deployment's `durability_nines`.
        "adaptive" => Ok(ResiliencePolicy::Adaptive {
            nines: v.opt_f64("nines", default_nines),
        }),
        other => Err(Error::Config(format!("unknown policy '{other}'"))),
    }
}

/// What backs a standalone container agent's storage.
#[derive(Debug, Clone, PartialEq)]
pub enum AgentBackend {
    /// Device-modeled in-memory store (the spec's `device` field).
    Device,
    /// A real directory — any POSIX/NFS mount.
    Fs { path: String },
}

/// Configuration of one standalone container agent (`dynostore agent
/// --config agent.json`): the §III-A "configuration file that specifies
/// the container's name, storage path, and access parameters".
///
/// ```json
/// {"id": 20, "name": "dc-nfs", "site": "aws-virginia",
///  "device": "ebs-ssd", "mem_mb": 256, "fs_gb": 512, "afr": 0.04,
///  "backend": "fs", "path": "/mnt/nfs/dynostore"}
/// ```
#[derive(Debug, Clone)]
pub struct AgentConfig {
    /// Registry id this container announces (must be unique across the
    /// deployment the gateway assembles).
    pub id: u32,
    pub spec: AgentSpec,
    pub backend: AgentBackend,
}

impl AgentConfig {
    pub fn from_json(text: &str) -> Result<AgentConfig> {
        let v = parse(text)?;
        let spec = parse_container(&v)?;
        let backend = match v.opt_str("backend", "device") {
            "device" => AgentBackend::Device,
            "fs" => AgentBackend::Fs { path: v.req_str("path")?.to_string() },
            other => {
                return Err(Error::Config(format!(
                    "unknown agent backend '{other}' (expected device | fs)"
                )))
            }
        };
        Ok(AgentConfig { id: v.opt_u64("id", 0) as u32, spec, backend })
    }

    pub fn from_file(path: &str) -> Result<AgentConfig> {
        let text = std::fs::read_to_string(path)?;
        AgentConfig::from_json(&text)
    }

    /// Instantiate the container this agent fronts.
    pub fn build(&self) -> Result<Arc<DataContainer>> {
        let backend: Box<dyn Backend> = match &self.backend {
            AgentBackend::Device => {
                Box::new(SimBackend::new(self.spec.device, self.spec.fs_capacity))
            }
            AgentBackend::Fs { path } => {
                Box::new(FsBackend::new(path.as_str(), self.spec.fs_capacity)?)
            }
        };
        Ok(DataContainer::with_afr(
            self.id,
            self.spec.name.clone(),
            self.spec.site,
            self.spec.mem_capacity,
            backend,
            self.spec.annual_failure_rate,
        ))
    }
}

fn parse_container(v: &Value) -> Result<AgentSpec> {
    let name = v.req_str("name")?;
    let site_name = v.opt_str("site", "chameleon-tacc");
    let site = Site::parse(site_name)
        .ok_or_else(|| Error::Config(format!("unknown site '{site_name}'")))?;
    let dev_name = v.opt_str("device", "chameleon-local");
    let device = Device::parse(dev_name)
        .ok_or_else(|| Error::Config(format!("unknown device '{dev_name}'")))?;
    Ok(AgentSpec::new(name, site, device)
        .mem(v.opt_u64("mem_mb", 256) << 20)
        .fs(v.opt_u64("fs_gb", 1024) << 30)
        .afr(v.get("afr").as_f64().unwrap_or(0.05)))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "gateway_site": "chameleon-uc",
        "metadata_replicas": 5,
        "policy": {"type": "erasure", "n": 6, "k": 3},
        "weights": {"w1_mem": 0.2, "w2_fs": 0.8},
        "containers": [
            {"name": "dc0", "site": "chameleon-tacc", "device": "ebs-ssd",
             "mem_mb": 64, "fs_gb": 10, "afr": 0.02},
            {"name": "dc1", "site": "aws-virginia", "device": "ebs-hdd"}
        ]
    }"#;

    #[test]
    fn parses_full_config() {
        let cfg = Config::from_json(SAMPLE).unwrap();
        assert_eq!(cfg.gateway_site, Site::ChameleonUc);
        assert_eq!(cfg.metadata_replicas, 5);
        assert_eq!(cfg.policy, ResiliencePolicy::Fixed(ErasureConfig::new(6, 3)));
        assert_eq!(cfg.weights.w2_fs, 0.8);
        assert_eq!(cfg.containers.len(), 2);
        assert_eq!(cfg.containers[0].fs_capacity, 10 << 30);
        assert_eq!(cfg.containers[1].site, Site::AwsVirginia);
    }

    #[test]
    fn builds_running_deployment() {
        let cfg = Config::from_json(SAMPLE).unwrap();
        let ds = cfg.build().unwrap();
        assert_eq!(ds.registry.len(), 2);
        assert_eq!(ds.meta.replica_count(), 5);
        let token = ds.register_user("u").unwrap();
        assert!(ds.tokens.validate(&token).is_ok());
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(Config::from_json("{\"metadata_replicas\": 2}").is_err());
        assert!(Config::from_json("{\"gateway_site\": \"mars\"}").is_err());
        assert!(Config::from_json("{\"policy\": {\"type\": \"erasure\", \"n\": 2, \"k\": 5}}")
            .is_err());
        assert!(Config::from_json("{\"engine\": \"cuda\"}").is_err());
        assert!(Config::from_json("not json").is_err());
    }

    #[test]
    fn engine_knob_selects_backend() {
        for (spelling, engine) in [
            ("pure-rust", GfEngine::PureRust),
            ("pure", GfEngine::PureRust),
            ("swar", GfEngine::Swar),
            ("swar-parallel", GfEngine::SwarParallel),
            ("pjrt", GfEngine::Pjrt),
        ] {
            let cfg =
                Config::from_json(&format!("{{\"engine\": \"{spelling}\"}}")).unwrap();
            assert_eq!(cfg.engine, engine, "{spelling}");
        }
        // A swar-parallel deployment builds and serves the data path.
        let cfg = Config::from_json(
            r#"{"engine": "swar-parallel",
                "containers": [
                    {"name": "dc0"}, {"name": "dc1"}, {"name": "dc2"},
                    {"name": "dc3"}, {"name": "dc4"}, {"name": "dc5"},
                    {"name": "dc6"}, {"name": "dc7"}, {"name": "dc8"},
                    {"name": "dc9"}, {"name": "dc10"}, {"name": "dc11"}
                ]}"#,
        )
        .unwrap();
        let ds = cfg.build().unwrap();
        assert_eq!(ds.backend_name(), "swar-parallel");
        let token = ds.register_user("u").unwrap();
        let report = ds
            .push(&token, "/u", "obj", &[7u8; 40_000], Default::default())
            .unwrap();
        assert_eq!(report.backend, "swar-parallel");
    }

    #[test]
    fn remote_container_entries_are_split_out() {
        let cfg = Config::from_json(
            r#"{"containers": [
                {"name": "dc0"},
                {"endpoint": "127.0.0.1:9100"},
                {"name": "dc1"},
                {"endpoint": "10.0.0.7:9100"}
            ]}"#,
        )
        .unwrap();
        assert_eq!(cfg.containers.len(), 2);
        assert_eq!(cfg.remotes, vec!["127.0.0.1:9100", "10.0.0.7:9100"]);
        // Building fails fast when a remote agent is unreachable.
        let bad = Config::from_json(r#"{"containers": [{"endpoint": "127.0.0.1:1"}]}"#)
            .unwrap();
        assert!(bad.build().is_err());
    }

    #[test]
    fn agent_config_parses_and_builds() {
        let cfg = AgentConfig::from_json(
            r#"{"id": 20, "name": "dc-agent", "site": "aws-virginia",
                "device": "ebs-ssd", "mem_mb": 64, "fs_gb": 1, "afr": 0.04}"#,
        )
        .unwrap();
        assert_eq!(cfg.id, 20);
        assert_eq!(cfg.backend, AgentBackend::Device);
        let c = cfg.build().unwrap();
        assert_eq!(c.id, 20);
        assert_eq!(c.site, Site::AwsVirginia);
        c.put("probe", b"ok").unwrap();
        assert_eq!(c.get("probe").unwrap().data.unwrap(), b"ok");
        // fs backend needs a path; unknown backends rejected.
        assert!(AgentConfig::from_json(r#"{"name": "x", "backend": "fs"}"#).is_err());
        assert!(AgentConfig::from_json(r#"{"name": "x", "backend": "tape"}"#).is_err());
        let dir = std::env::temp_dir().join(format!("dynostore-agent-{}", std::process::id()));
        let fs_cfg = AgentConfig::from_json(&format!(
            r#"{{"id": 1, "name": "dc-fs", "backend": "fs", "path": "{}"}}"#,
            dir.display()
        ))
        .unwrap();
        let c = fs_cfg.build().unwrap();
        c.put("k", b"v").unwrap();
        assert_eq!(c.get("k").unwrap().data.unwrap(), b"v");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn data_dir_and_snapshot_cadence_parse_and_build() {
        let cfg = Config::from_json("{}").unwrap();
        assert_eq!(cfg.data_dir, None);
        assert_eq!(cfg.snapshot_every, crate::durability::DEFAULT_SNAPSHOT_EVERY);
        assert_eq!(cfg.max_body_mb, 1024, "default gateway body cap is 1 GiB");
        assert_eq!(Config::from_json("{\"max_body_mb\": 8}").unwrap().max_body_mb, 8);

        let dir = std::env::temp_dir()
            .join(format!("dynostore-cfg-durable-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = Config::from_json(&format!(
            r#"{{"data_dir": "{}", "snapshot_every": 8,
                "containers": [{{"name": "dc0"}}, {{"name": "dc1"}}]}}"#,
            dir.display()
        ))
        .unwrap();
        assert_eq!(cfg.data_dir.as_deref(), Some(dir.to_str().unwrap()));
        assert_eq!(cfg.snapshot_every, 8);
        // Fresh dir: builds, nothing recovered, metadata is durable.
        let ds = cfg.build().unwrap();
        assert!(ds.meta.is_durable());
        assert!(!ds.recovery_report().unwrap().recovered());
        ds.register_user("u").unwrap();
        assert_eq!(ds.meta.wal_len(), 1);
        drop(ds);
        // Same dir again: the namespace is recovered.
        let ds = cfg.build().unwrap();
        assert!(ds.recovery_report().unwrap().recovered());
        assert!(ds.meta.read(|s| Ok(s.collection_exists("/u"))).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn meta_shards_parse_and_build_sharded() {
        assert_eq!(Config::from_json("{}").unwrap().meta_shards, 1);
        assert_eq!(Config::from_json("{\"meta_shards\": 0}").unwrap().meta_shards, 1);
        assert_eq!(Config::from_json("{\"meta_shards\": 4}").unwrap().meta_shards, 4);

        let dir = std::env::temp_dir()
            .join(format!("dynostore-cfg-sharded-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = Config::from_json(&format!(
            r#"{{"data_dir": "{}", "meta_shards": 4, "snapshot_every": 4,
                "containers": [{{"name": "dc0"}}, {{"name": "dc1"}}]}}"#,
            dir.display()
        ))
        .unwrap();
        let ds = cfg.build().unwrap();
        assert!(ds.meta.is_durable());
        assert_eq!(ds.meta.shard_count(), 4);
        assert_eq!(ds.recovery_shard_reports().map(|r| r.len()), Some(4));
        ds.register_user("u").unwrap();
        drop(ds);
        // Restart recovers the sharded plane; the layout marker pins
        // the shard count against mismatched reopens.
        let ds = cfg.build().unwrap();
        assert!(ds.meta.read_at("/u", |s| Ok(s.collection_exists("/u"))).unwrap());
        drop(ds);
        let one = Config::from_json(&format!(
            r#"{{"data_dir": "{}", "containers": [{{"name": "dc0"}}]}}"#,
            dir.display()
        ))
        .unwrap();
        assert!(one.build().is_err(), "reopening 4 shards as 1 must refuse");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fault_scripts_wrap_scripted_containers_in_the_chaos_plane() {
        let cfg = Config::from_json(
            r#"{"chaos_seed": 42,
                "containers": [
                    {"name": "dc0", "faults": {"error_rate": 1.0}},
                    {"name": "dc1"}
                ]}"#,
        )
        .unwrap();
        assert_eq!(cfg.chaos_seed, 42);
        assert_eq!(cfg.fault_specs.len(), 2);
        assert!(cfg.fault_specs[0].is_some());
        assert!(cfg.fault_specs[1].is_none());

        let ds = cfg.build().unwrap();
        // Scripted container registers behind the chaos transport; the
        // clean one keeps its bare local channel.
        assert_eq!(ds.channel_of(0).unwrap().transport(), "chaos");
        assert_eq!(ds.channel_of(1).unwrap().transport(), "local");
        // error_rate 1.0: every op on dc0 fails, dc1 works.
        assert!(ds.channel_of(0).unwrap().put("k", b"v").is_err());
        assert!(ds.channel_of(1).unwrap().put("k", b"v").is_ok());

        // Invalid scripts are config errors, not silent clamps.
        assert!(Config::from_json(
            r#"{"containers": [{"name": "x", "faults": {"error_rate": 1.5}}]}"#
        )
        .is_err());
        // Remote entries cannot carry fault scripts.
        assert!(Config::from_json(
            r#"{"containers": [{"endpoint": "h:1", "faults": {"error_rate": 0.1}}]}"#
        )
        .is_err());
    }

    #[test]
    fn resilience_knobs_parse_with_defaults() {
        let cfg = Config::from_json("{}").unwrap();
        assert_eq!(cfg.scrub_interval_secs, 0, "scrubber off by default");
        assert_eq!(cfg.scrub_sample, DEFAULT_SCRUB_SAMPLE);
        assert_eq!(cfg.conn_timeout_secs, crate::net::DEFAULT_CONN_TIMEOUT.as_secs());

        let cfg = Config::from_json(
            r#"{"scrub": {"interval_secs": 7, "sample": 16}, "conn_timeout_secs": 3}"#,
        )
        .unwrap();
        assert_eq!(cfg.scrub_interval_secs, 7);
        assert_eq!(cfg.scrub_sample, 16);
        assert_eq!(cfg.conn_timeout_secs, 3);
    }

    #[test]
    fn net_knobs_parse_with_defaults() {
        let cfg = Config::from_json("{}").unwrap();
        assert_eq!(cfg.net, NetConfig::default());
        assert_eq!(cfg.net.engine, crate::net::ServerEngine::default());
        assert_eq!(cfg.net.max_connections, crate::net::DEFAULT_MAX_CONNECTIONS);
        assert_eq!(cfg.net.max_inflight, crate::net::DEFAULT_MAX_INFLIGHT);
        assert_eq!(
            cfg.net.keepalive_idle_secs,
            crate::net::DEFAULT_KEEPALIVE_IDLE.as_secs()
        );
        assert_eq!(cfg.net.client_pool_per_host, crate::net::DEFAULT_POOL_PER_HOST);

        let cfg = Config::from_json(
            r#"{"net": {"engine": "threaded", "max_connections": 64, "max_inflight": 8,
                        "keepalive_idle_secs": 5, "client_pool_per_host": 0}}"#,
        )
        .unwrap();
        assert_eq!(cfg.net.engine, crate::net::ServerEngine::Threaded);
        assert_eq!(cfg.net.max_connections, 64);
        assert_eq!(cfg.net.max_inflight, 8);
        assert_eq!(cfg.net.keepalive_idle_secs, 5);
        assert_eq!(cfg.net.client_pool_per_host, 0, "0 disables client pooling");

        // Boolean spelling of the engine knob, per the paper-repro config
        // shape: {"net": {"reactor": false}}.
        let cfg = Config::from_json(r#"{"net": {"reactor": false}}"#).unwrap();
        assert_eq!(cfg.net.engine, crate::net::ServerEngine::Threaded);
        let cfg = Config::from_json(r#"{"net": {"reactor": true}}"#).unwrap();
        assert_eq!(cfg.net.engine, crate::net::ServerEngine::Reactor);
        // "engine" wins over "reactor" when both are present.
        let cfg =
            Config::from_json(r#"{"net": {"engine": "threaded", "reactor": true}}"#).unwrap();
        assert_eq!(cfg.net.engine, crate::net::ServerEngine::Threaded);

        // Unknown engines are config errors, and caps clamp to >= 1.
        assert!(Config::from_json(r#"{"net": {"engine": "iocp"}}"#).is_err());
        let cfg = Config::from_json(r#"{"net": {"max_connections": 0, "max_inflight": 0}}"#)
            .unwrap();
        assert_eq!(cfg.net.max_connections, 1);
        assert_eq!(cfg.net.max_inflight, 1);

        // server_options carries the knobs through to the server layer.
        let cfg = Config::from_json(
            r#"{"net": {"engine": "threaded", "max_connections": 9, "max_inflight": 3,
                        "keepalive_idle_secs": 4}}"#,
        )
        .unwrap();
        let opts = cfg.net.server_options();
        assert_eq!(opts.engine, crate::net::ServerEngine::Threaded);
        assert_eq!(opts.max_connections, 9);
        assert_eq!(opts.max_inflight, 3);
        assert_eq!(opts.keepalive_idle, std::time::Duration::from_secs(4));
    }

    #[test]
    fn part_size_parses_with_default() {
        let cfg = Config::from_json("{}").unwrap();
        assert_eq!(cfg.part_size_mb, 8, "default streaming part is 8 MiB");
        assert_eq!(Config::from_json("{\"part_size_mb\": 2}").unwrap().part_size_mb, 2);
        assert_eq!(
            Config::from_json("{\"part_size_mb\": 0}").unwrap().part_size_mb,
            1,
            "part size clamps to at least 1 MiB"
        );
    }

    #[test]
    fn dynamic_policy_config() {
        let cfg = Config::from_json(
            r#"{"policy": {"type": "dynamic", "k": 5, "target_loss": 0.01}}"#,
        )
        .unwrap();
        assert_eq!(cfg.policy, ResiliencePolicy::Dynamic { k: 5, target_loss: 0.01 });
    }

    #[test]
    fn defaults_are_paper_defaults() {
        let cfg = Config::from_json("{}").unwrap();
        assert_eq!(cfg.policy, ResiliencePolicy::Fixed(ErasureConfig::new(10, 7)));
        assert_eq!(cfg.metadata_replicas, 3);
        assert_eq!(cfg.durability_nines, 3.0);
        assert!(cfg.container_tiers.is_empty());
    }

    #[test]
    fn adaptive_policy_and_nines_config() {
        let cfg = Config::from_json(r#"{"policy": {"type": "adaptive"}}"#).unwrap();
        assert_eq!(cfg.policy, ResiliencePolicy::Adaptive { nines: 3.0 });
        // The deployment-wide target feeds the policy default...
        let cfg = Config::from_json(
            r#"{"durability_nines": 4.0, "policy": {"type": "adaptive"}}"#,
        )
        .unwrap();
        assert_eq!(cfg.policy, ResiliencePolicy::Adaptive { nines: 4.0 });
        // ...and the policy block may pin its own.
        let cfg = Config::from_json(
            r#"{"durability_nines": 4.0, "policy": {"type": "adaptive", "nines": 2.5}}"#,
        )
        .unwrap();
        assert_eq!(cfg.policy, ResiliencePolicy::Adaptive { nines: 2.5 });
        assert_eq!(cfg.durability_nines, 4.0);
        assert!(Config::from_json(r#"{"durability_nines": 0}"#).is_err());
        assert!(Config::from_json(r#"{"durability_nines": 99}"#).is_err());
    }

    #[test]
    fn container_tiers_parse_and_apply() {
        let cfg = Config::from_json(
            r#"{"containers": [
                {"name": "hot0", "tier": "mem"},
                {"name": "warm0", "tier": "ssd"},
                {"name": "dc0"},
                {"name": "cold0", "tier": "cold"}
            ],
            "tiering": {"hot_rate": 5.0, "cold_after_secs": 120, "max_moves": 8}}"#,
        )
        .unwrap();
        assert_eq!(
            cfg.container_tiers,
            vec![
                Some(StorageTier::Mem),
                Some(StorageTier::Ssd),
                None,
                Some(StorageTier::Cold)
            ]
        );
        assert_eq!(cfg.tier_cycle.hot_rate, 5.0);
        assert_eq!(cfg.tier_cycle.cold_after_secs, 120);
        assert_eq!(cfg.tier_cycle.max_moves, 8);
        let ds = cfg.build().unwrap();
        assert_eq!(ds.container_tier(0), StorageTier::Mem);
        assert_eq!(ds.container_tier(1), StorageTier::Ssd);
        assert_eq!(ds.container_tier(2), StorageTier::Fs, "untagged = default fs");
        assert_eq!(ds.container_tier(3), StorageTier::Cold);
        // Unknown tier names and tiers on remote entries are rejected.
        assert!(Config::from_json(
            r#"{"containers": [{"name": "x", "tier": "tape"}]}"#
        )
        .is_err());
        assert!(Config::from_json(
            r#"{"containers": [{"endpoint": "h:1", "tier": "mem"}]}"#
        )
        .is_err());
    }
}
