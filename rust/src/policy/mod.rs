//! Control policies (paper §III-B closing + §VI-D): the resilience
//! policy applied per upload, including the *dynamic* algorithm that
//! selects, in real time, how many data and parity chunks to create and
//! where to place them so each data item meets a reliability target
//! (max 0.1 % loss probability per year in the paper's experiment)
//! against heterogeneous per-container failure rates.

use crate::container::ContainerInfo;
use crate::erasure::ErasureConfig;
use crate::sim::FailureModel;
use crate::{Error, Result};

/// Upload-time resilience policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ResiliencePolicy {
    /// "Regular" (paper §VI-C3 baseline): whole object on one container.
    Regular,
    /// Fixed (n, k) IDA for every object (paper Figs. 4-8).
    Fixed(ErasureConfig),
    /// Dynamic per-object (n, k) + placement (paper §VI-D / Table II):
    /// grow parity until the loss probability meets `target_loss`.
    Dynamic { k: usize, target_loss: f64 },
    /// Adaptive per-object (k, n) + placement over the scored fleet
    /// (D-Rex direction, `crate::tiering`): search the whole (k, n)
    /// plane for the cheapest configuration meeting a durability
    /// target of `nines` nines, rating containers by their effective
    /// (observed-blended) failure rates.
    Adaptive { nines: f64 },
}

/// The paper's §VI-D reliability target: 0.1 % per item-year.
pub const PAPER_TARGET_LOSS: f64 = 0.001;

/// Result of the dynamic selection: the chosen configuration and the
/// container ids (one per chunk, reliability-sorted best first).
#[derive(Debug, Clone, PartialEq)]
pub struct DynamicChoice {
    pub config: ErasureConfig,
    pub containers: Vec<u32>,
    /// Predicted one-year loss probability of this placement.
    pub loss_probability: f64,
}

/// Dynamic (n, k) selection (§VI-D): starting from n = k + 1, place
/// chunks on the n most reliable feasible containers and grow n (more
/// parity, more spread) until `loss_probability ≤ target` or the
/// container pool / tile limit is exhausted — then return the best
/// effort with a warning flag via the loss field.
pub fn select_dynamic(
    infos: &[ContainerInfo],
    chunk_size: u64,
    k: usize,
    target_loss: f64,
) -> Result<DynamicChoice> {
    if k == 0 {
        return Err(Error::Erasure("dynamic selection needs k >= 1".into()));
    }
    // Feasible containers, most reliable first (ties by id).
    let mut pool: Vec<&ContainerInfo> = infos
        .iter()
        .filter(|c| c.alive && c.fs_avail >= chunk_size)
        .collect();
    pool.sort_by(|a, b| {
        a.annual_failure_rate
            .partial_cmp(&b.annual_failure_rate)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    if pool.len() < k + 1 {
        return Err(Error::Placement(format!(
            "dynamic selection: need at least {} containers, have {}",
            k + 1,
            pool.len()
        )));
    }
    let max_n = pool.len().min(16);
    let model = FailureModel { afr: pool.iter().map(|c| c.annual_failure_rate).collect() };

    let mut best: Option<DynamicChoice> = None;
    for n in (k + 1)..=max_n {
        let placement: Vec<usize> = (0..n).collect();
        let loss = model.loss_probability(&placement, n - k);
        let choice = DynamicChoice {
            config: ErasureConfig::new(n, k),
            containers: pool[..n].iter().map(|c| c.id).collect(),
            loss_probability: loss,
        };
        let better = best.as_ref().map_or(true, |b| loss < b.loss_probability);
        if better {
            best = Some(choice);
        }
        if loss <= target_loss {
            break;
        }
    }
    best.ok_or_else(|| Error::Placement("dynamic selection found no placement".into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Site;

    fn info(id: u32, afr: f64) -> ContainerInfo {
        ContainerInfo {
            id,
            name: format!("dc{id}"),
            site: Site::ChameleonTacc,
            alive: true,
            mem_total: 1 << 30,
            mem_avail: 1 << 29,
            fs_total: 1 << 40,
            fs_avail: 1 << 39,
            annual_failure_rate: afr,
        }
    }

    fn paper_pool() -> Vec<ContainerInfo> {
        // Ten heterogeneous containers, AFR 1%..25% (§VI-D).
        (0..10)
            .map(|i| info(i, 0.01 + 0.24 * i as f64 / 9.0))
            .collect()
    }

    #[test]
    fn meets_paper_reliability_target() {
        let choice = select_dynamic(&paper_pool(), 1 << 20, 4, PAPER_TARGET_LOSS).unwrap();
        assert!(
            choice.loss_probability <= PAPER_TARGET_LOSS,
            "loss {} > target",
            choice.loss_probability
        );
        assert_eq!(choice.containers.len(), choice.config.n);
        // With 1-25% AFRs the target needs several parity chunks.
        assert!(choice.config.failures_tolerated() >= 3, "{:?}", choice.config);
    }

    #[test]
    fn prefers_reliable_containers() {
        let choice = select_dynamic(&paper_pool(), 1 << 20, 3, PAPER_TARGET_LOSS).unwrap();
        // Pool is sorted by AFR, ids 0.. are the most reliable.
        assert!(choice.containers.starts_with(&[0, 1, 2]));
    }

    #[test]
    fn flakier_pool_needs_more_parity() {
        let reliable: Vec<ContainerInfo> = (0..10).map(|i| info(i, 0.01)).collect();
        let flaky: Vec<ContainerInfo> = (0..10).map(|i| info(i, 0.25)).collect();
        let a = select_dynamic(&reliable, 1024, 4, PAPER_TARGET_LOSS).unwrap();
        let b = select_dynamic(&flaky, 1024, 4, PAPER_TARGET_LOSS).unwrap();
        assert!(
            b.config.failures_tolerated() > a.config.failures_tolerated(),
            "reliable {:?} vs flaky {:?}",
            a.config,
            b.config
        );
    }

    #[test]
    fn dead_containers_excluded() {
        let mut pool = paper_pool();
        for c in pool.iter_mut().take(7) {
            c.alive = false;
        }
        // Only 3 containers left; k=3 needs at least 4.
        assert!(select_dynamic(&pool, 1024, 3, PAPER_TARGET_LOSS).is_err());
    }

    #[test]
    fn best_effort_when_target_unreachable() {
        // Two flaky containers, k=1: target unreachable, still returns
        // the best available (n=2).
        let pool = vec![info(0, 0.25), info(1, 0.25)];
        let choice = select_dynamic(&pool, 1024, 1, 1e-9).unwrap();
        assert_eq!(choice.config, ErasureConfig::new(2, 1));
        assert!(choice.loss_probability > 1e-9);
    }

    #[test]
    fn policy_constants_match_paper() {
        assert_eq!(PAPER_TARGET_LOSS, 0.001);
        let p = ResiliencePolicy::Fixed(ErasureConfig::new(10, 7));
        assert!(matches!(p, ResiliencePolicy::Fixed(c) if c.failures_tolerated() == 3));
    }
}
