//! SWAR and multi-core GF(2^8) backends for the erasure hot path.
//!
//! [`SwarBackend`] runs the fused split-nibble kernel of
//! [`crate::gf256::MatmulPlan`] on the calling thread; it replaces the
//! n×k independent `mul_slice_acc` passes of
//! [`super::PureRustBackend`] with one blocked sweep that keeps each
//! source block L1-hot while accumulating into every output row.
//!
//! [`ParallelBackend`] shards the stripe's columns across a
//! [`ThreadPool`] (the same pool type that backs the HTTP server —
//! generalized with [`ThreadPool::run_scoped`] so jobs may borrow the
//! stripe). Sharding is by column range: every worker owns a disjoint
//! vertical slice of all output rows, so workers never synchronize
//! inside the kernel. Stripes narrower than the small-object threshold
//! stay on the calling thread — thread handoff costs more than the
//! matmul for small objects, which dominate metadata-heavy workloads.

use std::sync::{Arc, Mutex};

use crate::gf256::{MatmulPlan, Matrix, SWAR_BLOCK};
use crate::net::ThreadPool;
use crate::{Error, Result};

use super::codec::GfBackend;

/// Memoizes the most recently compiled [`MatmulPlan`] keyed by the
/// coefficient matrix bytes. Encode reuses one fixed parity matrix per
/// codec, so the common case is a (dims + ≤256-byte memcmp) hit;
/// decode's survivor-dependent inverses simply rebuild on miss.
/// Without this, plan construction ((n-k)·k nibble tables) rivals the
/// matmul itself on minimum-size (64-byte) stripes.
#[derive(Debug, Default)]
struct PlanCache {
    slot: Mutex<Option<(Vec<u8>, Arc<MatmulPlan>)>>,
}

impl PlanCache {
    fn plan_for(&self, a: &Matrix) -> Arc<MatmulPlan> {
        let mut slot = self.slot.lock().unwrap();
        if let Some((key, plan)) = slot.as_ref() {
            if plan.rows() == a.rows()
                && plan.cols() == a.cols()
                && key.as_slice() == a.data()
            {
                return plan.clone();
            }
        }
        let plan = Arc::new(MatmulPlan::new(a));
        *slot = Some((a.data().to_vec(), plan.clone()));
        plan
    }
}

/// Single-threaded fused SWAR backend.
#[derive(Debug, Default)]
pub struct SwarBackend {
    cache: PlanCache,
}

impl SwarBackend {
    pub fn new() -> Self {
        SwarBackend::default()
    }
}

impl GfBackend for SwarBackend {
    fn matmul(&self, a: &Matrix, data: &[&[u8]], out: &mut [&mut [u8]]) -> Result<()> {
        if data.len() != a.cols() || out.len() != a.rows() {
            return Err(Error::Erasure("swar backend shape mismatch".into()));
        }
        self.cache.plan_for(a).run(data, out, 0);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "swar"
    }
}

/// Row lengths below this stay single-threaded: dispatching to the pool
/// costs ~10 µs of handoff + wakeup, which only pays off once per-shard
/// work is comfortably larger (≥ tens of µs of coding per worker).
pub const PARALLEL_THRESHOLD: usize = 256 * 1024;

/// Multi-core SWAR backend: column-sharded fan-out over a worker pool.
///
/// The backend owns a dedicated pool on purpose: `run_scoped` blocks
/// the submitting thread until its shards finish, so sharing a pool
/// with the code that *calls* matmul (e.g. the gateway's HTTP workers)
/// could deadlock once every worker is blocked inside a request
/// handler waiting for shard jobs queued behind those same handlers.
pub struct ParallelBackend {
    pool: Arc<ThreadPool>,
    threshold: usize,
    cache: PlanCache,
}

impl ParallelBackend {
    /// Pool sized to the host's available parallelism.
    pub fn auto() -> Self {
        let threads = std::thread::available_parallelism().map_or(1, |n| n.get());
        ParallelBackend::new(threads)
    }

    pub fn new(threads: usize) -> Self {
        ParallelBackend {
            pool: Arc::new(ThreadPool::new(threads)),
            threshold: PARALLEL_THRESHOLD,
            cache: PlanCache::default(),
        }
    }

    /// Override the small-object threshold (tests set 0 to force
    /// sharding on tiny stripes).
    pub fn with_threshold(mut self, threshold: usize) -> Self {
        self.threshold = threshold;
        self
    }

    pub fn threads(&self) -> usize {
        self.pool.size()
    }
}

impl GfBackend for ParallelBackend {
    fn matmul(&self, a: &Matrix, data: &[&[u8]], out: &mut [&mut [u8]]) -> Result<()> {
        if data.len() != a.cols() || out.len() != a.rows() {
            return Err(Error::Erasure("parallel backend shape mismatch".into()));
        }
        let len = data.first().map_or(0, |d| d.len());
        let plan = self.cache.plan_for(a);
        let workers = self.pool.size();
        if len < self.threshold.max(1) || workers == 1 || a.rows() == 0 {
            plan.run(data, out, 0);
            return Ok(());
        }

        // Column shards: one per worker, widths rounded up to the SWAR
        // block so block boundaries never straddle a shard seam.
        let per = len.div_ceil(workers).div_ceil(SWAR_BLOCK) * SWAR_BLOCK;
        let mut rest: Vec<&mut [u8]> = out.iter_mut().map(|r| &mut **r).collect();
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(workers);
        let mut start = 0usize;
        while start < len {
            let width = per.min(len - start);
            let mut shard: Vec<&mut [u8]> = Vec::with_capacity(rest.len());
            let mut next: Vec<&mut [u8]> = Vec::with_capacity(rest.len());
            for row in rest {
                let (head, tail) = row.split_at_mut(width);
                shard.push(head);
                next.push(tail);
            }
            rest = next;
            let plan_ref = &plan;
            jobs.push(Box::new(move || {
                let mut shard = shard;
                plan_ref.run(data, &mut shard, start);
            }));
            start += width;
        }
        self.pool.run_scoped(jobs)
    }

    fn name(&self) -> &'static str {
        "swar-parallel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::erasure::{Chunk, Codec, ErasureConfig, PureRustBackend};
    use crate::gf256::ida_generator;
    use crate::util::Rng;

    /// Run one backend over (generator, data) and return the output rows.
    fn run(b: &dyn GfBackend, a: &Matrix, refs: &[&[u8]], len: usize) -> Vec<Vec<u8>> {
        let mut out: Vec<Vec<u8>> = (0..a.rows()).map(|_| vec![0x5Au8; len]).collect();
        let mut out_refs: Vec<&mut [u8]> = out.iter_mut().map(|v| v.as_mut_slice()).collect();
        b.matmul(a, refs, &mut out_refs).unwrap();
        out
    }

    #[test]
    fn property_swar_and_parallel_match_scalar_oracle() {
        // The satellite property test: on random stripes (random (n,k),
        // random lengths incl. non-multiples of 8/64/SWAR_BLOCK, random
        // bytes), SWAR and parallel outputs are bit-identical to the
        // scalar PureRustBackend oracle.
        let mut rng = Rng::new(31);
        let parallel = ParallelBackend::new(4).with_threshold(0); // force sharding
        for trial in 0..25u64 {
            let k = 1 + rng.below(8) as usize;
            let n = k + rng.below((16 - k + 1) as u64) as usize;
            let len = 1 + rng.below(40_000) as usize;
            let g = ida_generator(n, k).unwrap();
            let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(len)).collect();
            let refs: Vec<&[u8]> = data.iter().map(|d| d.as_slice()).collect();

            let oracle = run(&PureRustBackend, &g, &refs, len);
            let swar = run(&SwarBackend::new(), &g, &refs, len);
            let par = run(&parallel, &g, &refs, len);
            assert_eq!(swar, oracle, "swar trial={trial} (n,k)=({n},{k}) len={len}");
            assert_eq!(par, oracle, "parallel trial={trial} (n,k)=({n},{k}) len={len}");
        }
    }

    #[test]
    fn parallel_above_threshold_uses_sharding_and_stays_exact() {
        // Big enough to actually cross PARALLEL_THRESHOLD.
        let mut rng = Rng::new(32);
        let len = PARALLEL_THRESHOLD + 12_345; // deliberately unaligned
        let g = ida_generator(10, 7).unwrap();
        let data: Vec<Vec<u8>> = (0..7).map(|_| rng.bytes(len)).collect();
        let refs: Vec<&[u8]> = data.iter().map(|d| d.as_slice()).collect();
        let oracle = run(&PureRustBackend, &g, &refs, len);
        let par = run(&ParallelBackend::new(3), &g, &refs, len);
        assert_eq!(par, oracle);
    }

    #[test]
    fn codec_roundtrips_bit_identical_across_backends() {
        let mut rng = Rng::new(33);
        let object = rng.bytes(200_000);
        let cfg = ErasureConfig::new(10, 7);
        let scalar = Codec::new(cfg).unwrap();
        let swar = Codec::with_backend(cfg, SwarBackend::new()).unwrap();
        let par =
            Codec::with_backend(cfg, ParallelBackend::new(2).with_threshold(0)).unwrap();

        let c_scalar = scalar.encode(&object).unwrap();
        let c_swar = swar.encode(&object).unwrap();
        let c_par = par.encode(&object).unwrap();
        assert_eq!(c_swar, c_scalar, "swar chunks differ from scalar");
        assert_eq!(c_par, c_scalar, "parallel chunks differ from scalar");

        // Cross-backend decode: encode on one engine, decode on another,
        // from a non-contiguous survivor set.
        let survivors: Vec<Chunk> = c_swar[3..].to_vec();
        assert_eq!(scalar.decode(&survivors).unwrap(), object);
        assert_eq!(par.decode(&survivors).unwrap(), object);
        assert_eq!(swar.decode(&c_scalar[..7]).unwrap(), object);
    }

    #[test]
    fn backend_shape_mismatch_rejected() {
        let g = ida_generator(6, 3).unwrap();
        let row = vec![0u8; 64];
        let refs: Vec<&[u8]> = vec![&row; 2]; // wrong: needs 3
        let mut out: Vec<Vec<u8>> = (0..6).map(|_| vec![0u8; 64]).collect();
        let mut out_refs: Vec<&mut [u8]> = out.iter_mut().map(|v| v.as_mut_slice()).collect();
        assert!(SwarBackend::new().matmul(&g, &refs, &mut out_refs).is_err());
        assert!(ParallelBackend::new(2).matmul(&g, &refs, &mut out_refs).is_err());
    }

    #[test]
    fn backend_names() {
        assert_eq!(SwarBackend::new().name(), "swar");
        assert_eq!(ParallelBackend::new(1).name(), "swar-parallel");
        assert!(ParallelBackend::auto().threads() >= 1);
    }
}
