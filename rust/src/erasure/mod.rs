//! Data resilience: the information dispersal algorithm of paper §IV-D.
//!
//! [`encode`](codec::Codec::encode) implements Algorithm 1 — split an
//! object into n chunks (k data + n-k parity via the systematic Cauchy
//! generator), pack the SHA3-256 object hash with every chunk, return the
//! packages to upload. [`decode`](codec::Codec::decode) implements
//! Algorithm 2 — any k chunks reconstruct the object; the hash is
//! recomputed and compared before the object is released.
//!
//! The GF(2^8) byte work is pluggable through [`GfBackend`]:
//!
//! * [`PureRustBackend`] — scalar table codec; always available, and the
//!   correctness oracle every other backend is checked against.
//! * [`SwarBackend`] — fused split-nibble SWAR kernel
//!   ([`crate::gf256::MatmulPlan`]); one blocked sweep instead of n×k
//!   independent passes.
//! * [`ParallelBackend`] — the SWAR kernel column-sharded across a
//!   worker pool, with a small-object threshold.
//! * [`crate::runtime::PjrtGfBackend`] — the PJRT-compiled Pallas
//!   kernel.
//!
//! Deployments pick one via `Config`'s `engine` knob / the coordinator
//! builder (`pure-rust | swar | swar-parallel | pjrt`).

mod backend;
mod chunk;
mod codec;

pub use backend::{ParallelBackend, SwarBackend, PARALLEL_THRESHOLD};
pub use chunk::{Chunk, ChunkHeader, CHUNK_HEADER_LEN};
pub use codec::{Codec, GfBackend, PureRustBackend};

use crate::{Error, Result};

/// Erasure configuration: n total chunks, k needed to reconstruct;
/// tolerates n-k container failures (paper §IV-D).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ErasureConfig {
    pub n: usize,
    pub k: usize,
}

impl ErasureConfig {
    pub const fn new(n: usize, k: usize) -> Self {
        ErasureConfig { n, k }
    }

    /// Paper configurations: DynoStore evaluates n={10,6,3}, k={4,3,2}
    /// (Fig. 4) and n=10, k=7 (Fig. 5-8).
    pub fn validate(&self) -> Result<()> {
        if self.k == 0 {
            return Err(Error::Erasure("k must be >= 1".into()));
        }
        if self.n < self.k {
            return Err(Error::Erasure(format!("n={} < k={}", self.n, self.k)));
        }
        if self.n > 16 {
            // Matches the largest AOT-compiled kernel tile (m=16).
            return Err(Error::Erasure(format!("n={} > 16 unsupported", self.n)));
        }
        Ok(())
    }

    /// Number of container failures this configuration survives.
    pub fn failures_tolerated(&self) -> usize {
        self.n - self.k
    }

    /// Storage overhead ratio, e.g. (10,7) → ~0.43 = 43% extra bytes.
    /// The paper contrasts 20% for DynoStore-style RS vs 300% for HDFS
    /// triple replication (§VII).
    pub fn storage_overhead(&self) -> f64 {
        (self.n as f64 - self.k as f64) / self.k as f64
    }
}

impl std::fmt::Display for ErasureConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "IDA({},{})", self.n, self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configs_validate() {
        for (n, k) in [(3, 2), (6, 3), (10, 4), (10, 7), (12, 8)] {
            let c = ErasureConfig::new(n, k);
            c.validate().unwrap();
            assert_eq!(c.failures_tolerated(), n - k);
        }
    }

    #[test]
    fn bad_configs_rejected() {
        assert!(ErasureConfig::new(2, 3).validate().is_err());
        assert!(ErasureConfig::new(3, 0).validate().is_err());
        assert!(ErasureConfig::new(17, 8).validate().is_err());
    }

    #[test]
    fn overhead_matches_paper_claims() {
        // §VII: "HDFS requiring 300% overhead to tolerate two failures,
        // while DynoStore only requires 20%" — e.g. (12,10)-like configs.
        assert!((ErasureConfig::new(12, 10).storage_overhead() - 0.2).abs() < 1e-9);
        assert!((ErasureConfig::new(10, 7).storage_overhead() - 3.0 / 7.0).abs() < 1e-9);
    }
}
