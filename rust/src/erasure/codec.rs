//! The IDA codec: Algorithms 1 (ENCODE) and 2 (DECODE) of paper §IV-D.
//!
//! Stripe layout: the object is zero-padded to `k * chunk_len` and viewed
//! as a k-row matrix D (row j = bytes `j*chunk_len..(j+1)*chunk_len`).
//! Encode computes `C = G · D` with the systematic generator
//! `[I_k ; Cauchy]`, so chunks 0..k are the data rows verbatim and chunks
//! k..n are parity. Decode selects the surviving generator rows, inverts,
//! and multiplies — then recomputes SHA3-256 and compares with the hash
//! carried in every chunk header.

use crate::crypto::sha3_256;
use crate::gf256::{ida_generator, mul_slice_acc, Matrix};
use crate::{Error, Result};

use super::chunk::{Chunk, ChunkHeader};
use super::ErasureConfig;

/// Pluggable GF(2^8) matmul engine. `a` is the (rows × cols) coefficient
/// matrix, `data` the cols input rows (equal length), `out` the rows
/// output slices (pre-sized to the input row length, overwritten).
///
/// `out` takes borrowed slices rather than owned vectors so callers can
/// point the engine straight at its final destination — chunk wire
/// buffers on encode, the reassembled object buffer on decode — instead
/// of staging rows in temporaries.
pub trait GfBackend: Send + Sync {
    fn matmul(&self, a: &Matrix, data: &[&[u8]], out: &mut [&mut [u8]]) -> Result<()>;
    fn name(&self) -> &'static str;
}

/// Table-driven pure-rust backend: one `mul_slice_acc` per (i, j)
/// coefficient. Always available; the correctness ORACLE the SWAR and
/// PJRT backends are cross-checked against (see `erasure::backend` and
/// `runtime::kernels` tests).
///
/// §Perf iteration 3: the coefficient passes are BLOCKED over 64 KiB
/// column ranges so the src/acc working set of all n x k passes stays
/// L2-resident instead of streaming whole multi-MiB rows n x k times
/// from DRAM (see EXPERIMENTS.md §Perf for measurements). §Perf
/// iteration 4 superseded this path with the fused SWAR kernel
/// ([`crate::erasure::SwarBackend`]); the scalar path is kept as the
/// baseline and oracle.
#[derive(Debug, Default, Clone, Copy)]
pub struct PureRustBackend;

/// Column-block width for the locality blocking (two rows of this size
/// fit comfortably in a 256 KiB-1 MiB L2 alongside the 64 KiB table row).
const L2_BLOCK: usize = 64 * 1024;

impl GfBackend for PureRustBackend {
    fn matmul(&self, a: &Matrix, data: &[&[u8]], out: &mut [&mut [u8]]) -> Result<()> {
        if data.len() != a.cols() || out.len() != a.rows() {
            return Err(Error::Erasure("backend shape mismatch".into()));
        }
        let len = data.first().map_or(0, |d| d.len());
        for out_row in out.iter_mut() {
            out_row.fill(0);
        }
        let mut start = 0usize;
        while start < len {
            let end = (start + L2_BLOCK).min(len);
            for (i, out_row) in out.iter_mut().enumerate() {
                for (j, src) in data.iter().enumerate() {
                    mul_slice_acc(a[(i, j)], &src[start..end], &mut out_row[start..end]);
                }
            }
            start = end;
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "pure-rust"
    }
}

/// Trait-object passthrough so the coordinator can pick the backend at
/// runtime (scalar, SWAR, parallel, PJRT) behind one codec type.
impl GfBackend for std::sync::Arc<dyn GfBackend> {
    fn matmul(&self, a: &Matrix, data: &[&[u8]], out: &mut [&mut [u8]]) -> Result<()> {
        (**self).matmul(a, data, out)
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }
}

/// Stripe alignment: chunk lengths are rounded up so the PJRT kernel's
/// tiled artifacts see aligned rows; 64 keeps padding negligible.
const CHUNK_ALIGN: usize = 64;

/// The erasure codec, parameterized by configuration and GF backend.
pub struct Codec<B: GfBackend = PureRustBackend> {
    config: ErasureConfig,
    generator: Matrix,
    /// Rows k..n of the generator (the Cauchy block). Encode only runs
    /// the backend over these — the first k output chunks are the object
    /// bytes themselves and are emitted by copy, not by matmul.
    parity: Matrix,
    backend: B,
}

impl Codec<PureRustBackend> {
    pub fn new(config: ErasureConfig) -> Result<Self> {
        Codec::with_backend(config, PureRustBackend)
    }
}

impl<B: GfBackend> Codec<B> {
    pub fn with_backend(config: ErasureConfig, backend: B) -> Result<Self> {
        config.validate()?;
        let generator = ida_generator(config.n, config.k)?;
        let parity_rows: Vec<usize> = (config.k..config.n).collect();
        let parity = generator.select_rows(&parity_rows);
        Ok(Codec { config, generator, parity, backend })
    }

    pub fn config(&self) -> ErasureConfig {
        self.config
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Chunk payload length for an object of `len` bytes.
    pub fn chunk_len(&self, len: usize) -> usize {
        let per = len.div_ceil(self.config.k).max(1);
        per.div_ceil(CHUNK_ALIGN) * CHUNK_ALIGN
    }

    /// Algorithm 1: ENCODE(o, n, k) → n packed chunks.
    ///
    /// Zero-copy systematic path: the k data chunks are emitted directly
    /// from the object slice into their pre-sized wire buffers (header +
    /// payload in one allocation, no `padded` staging copy), and the
    /// backend computes only the n-k parity rows — (n-k)·k coefficient
    /// passes instead of the n·k a full `G · D` would cost (for the
    /// paper's IDA(10,7): 21 passes instead of 70).
    pub fn encode(&self, object: &[u8]) -> Result<Vec<Chunk>> {
        let (n, k) = (self.config.n, self.config.k);
        let chunk_len = self.chunk_len(object.len());
        let hash = sha3_256(object); // line 7: h_o = SHA256(o)

        let mut chunks: Vec<Chunk> = (0..n)
            .map(|i| {
                Chunk::new_zeroed(ChunkHeader {
                    n: n as u8,
                    k: k as u8,
                    index: i as u8,
                    object_len: object.len() as u64,
                    chunk_len: chunk_len as u64,
                    object_hash: hash,
                    chunk_hash: [0; 32],
                })
            })
            .collect();

        // line 6: SPLIT(o, n, k) — data rows straight from the object
        // slice into the systematic chunks (tails stay zero-padded).
        for (j, chunk) in chunks.iter_mut().take(k).enumerate() {
            let start = (j * chunk_len).min(object.len());
            let end = ((j + 1) * chunk_len).min(object.len());
            chunk.payload_mut()[..end - start].copy_from_slice(&object[start..end]);
        }

        // Parity rows: P = Cauchy · D through the pluggable backend,
        // written directly into the parity chunks' wire buffers. The
        // systematic payloads ARE the padded data rows, so they double
        // as the matmul input.
        if n > k {
            let (sys, par) = chunks.split_at_mut(k);
            let rows: Vec<&[u8]> = sys.iter().map(|c| c.payload()).collect();
            let mut outs: Vec<&mut [u8]> =
                par.iter_mut().map(|c| c.payload_mut()).collect();
            self.backend.matmul(&self.parity, &rows, &mut outs)?;
        }
        // Payloads are final: stamp each chunk's payload hash so
        // unpack can localize bitrot to the one damaged chunk.
        for chunk in &mut chunks {
            chunk.seal();
        }
        Ok(chunks)
    }

    /// Algorithm 2: DECODE(chunks) → original object.
    ///
    /// Accepts any subset of chunks; needs ≥ k distinct indices. Verifies
    /// the SHA3-256 carried in the headers against the reconstruction and
    /// fails on mismatch (lines 6-9).
    pub fn decode(&self, chunks: &[Chunk]) -> Result<Vec<u8>> {
        let k = self.config.k;
        // Deduplicate by index, validate headers agree.
        let mut seen: Vec<&Chunk> = Vec::new();
        for c in chunks {
            if c.header.n as usize != self.config.n || c.header.k as usize != k {
                return Err(Error::Erasure(format!(
                    "chunk {} config ({},{}) != codec ({},{})",
                    c.header.index, c.header.n, c.header.k, self.config.n, k
                )));
            }
            if !seen.iter().any(|s| s.header.index == c.header.index) {
                seen.push(c);
            }
        }
        if seen.len() < k {
            // Algorithm 2 line 11: not enough chunks.
            return Err(Error::Erasure(format!(
                "not enough chunks: have {} need {}",
                seen.len(),
                k
            )));
        }
        seen.truncate(k);
        seen.sort_by_key(|c| c.header.index);

        let first = seen[0].header.clone();
        let chunk_len = first.chunk_len as usize;
        if chunk_len == 0 {
            return Err(Error::Erasure("zero chunk_len in header".into()));
        }
        for c in &seen {
            if c.header.chunk_len as usize != chunk_len
                || c.header.object_len != first.object_len
                || c.header.object_hash != first.object_hash
            {
                return Err(Error::Erasure("inconsistent chunk headers".into()));
            }
            if c.payload().len() != chunk_len {
                return Err(Error::Erasure("payload length mismatch".into()));
            }
        }

        let indices: Vec<usize> = seen.iter().map(|c| c.header.index as usize).collect();
        let mut object = vec![0u8; k * chunk_len];
        if indices.last().is_some_and(|&last| last < k) {
            // Systematic fast path: k distinct sorted indices all below k
            // means the survivors are exactly the data chunks 0..k — the
            // sub-generator is the identity, so skip inversion and matmul
            // entirely and reassemble by copy.
            for (c, dst) in seen.iter().zip(object.chunks_mut(chunk_len)) {
                dst.copy_from_slice(c.payload());
            }
        } else {
            // Invert the surviving generator rows; multiply straight into
            // the reassembled object buffer (rows are contiguous in it).
            let sub = self.generator.select_rows(&indices);
            let inv = sub.inverse()?;
            let rows: Vec<&[u8]> = seen.iter().map(|c| c.payload()).collect();
            let mut outs: Vec<&mut [u8]> = object.chunks_mut(chunk_len).collect();
            self.backend.matmul(&inv, &rows, &mut outs)?;
        }
        // MERGE is implicit (rows decoded in place); truncate padding.
        object.truncate(first.object_len as usize);

        // lines 6-9: integrity check against the packed hash.
        let recomputed = sha3_256(&object);
        if recomputed != first.object_hash {
            return Err(Error::Integrity(
                "reconstructed object hash mismatch".into(),
            ));
        }
        Ok(object)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn roundtrip(n: usize, k: usize, len: usize, drop: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let object = rng.bytes(len);
        let codec = Codec::new(ErasureConfig::new(n, k)).unwrap();
        let chunks = codec.encode(&object).unwrap();
        assert_eq!(chunks.len(), n);
        let keep = rng.sample_indices(n, n - drop);
        let subset: Vec<Chunk> = keep.iter().map(|&i| chunks[i].clone()).collect();
        let rec = codec.decode(&subset).unwrap();
        assert_eq!(rec, object, "(n,k)=({n},{k}) len={len} drop={drop}");
    }

    #[test]
    fn paper_configs_roundtrip_with_max_failures() {
        for (n, k) in [(3, 2), (6, 3), (10, 4), (10, 7), (12, 8)] {
            roundtrip(n, k, 10_000, n - k, (n * 31 + k) as u64);
        }
    }

    #[test]
    fn roundtrip_no_failures() {
        roundtrip(10, 7, 4096, 0, 1);
    }

    #[test]
    fn tiny_and_empty_objects() {
        for len in [0usize, 1, 63, 64, 65] {
            let codec = Codec::new(ErasureConfig::new(6, 3)).unwrap();
            let object = vec![0xA5u8; len];
            let chunks = codec.encode(&object).unwrap();
            let rec = codec.decode(&chunks[3..]).unwrap(); // drop 3 of 6
            assert_eq!(rec, object, "len={len}");
        }
    }

    #[test]
    fn systematic_prefix_is_raw_data() {
        let codec = Codec::new(ErasureConfig::new(6, 3)).unwrap();
        let object: Vec<u8> = (0..192u32).map(|i| i as u8).collect();
        let chunks = codec.encode(&object).unwrap();
        let cl = codec.chunk_len(object.len());
        for (j, c) in chunks.iter().take(3).enumerate() {
            assert_eq!(&c.payload()[..], &{
                let mut row = vec![0u8; cl];
                let start = j * cl;
                let end = ((j + 1) * cl).min(object.len());
                if start < object.len() {
                    row[..end - start].copy_from_slice(&object[start..end]);
                }
                row
            });
        }
    }

    #[test]
    fn too_few_chunks_fails() {
        let codec = Codec::new(ErasureConfig::new(10, 7)).unwrap();
        let object = vec![1u8; 1000];
        let chunks = codec.encode(&object).unwrap();
        let err = codec.decode(&chunks[..6]).unwrap_err();
        assert!(matches!(err, Error::Erasure(_)), "{err}");
    }

    #[test]
    fn duplicate_indices_do_not_count() {
        let codec = Codec::new(ErasureConfig::new(6, 3)).unwrap();
        let chunks = codec.encode(&[7u8; 500]).unwrap();
        let dup = vec![chunks[0].clone(), chunks[0].clone(), chunks[0].clone()];
        assert!(codec.decode(&dup).is_err());
    }

    #[test]
    fn corrupted_payload_detected_by_hash() {
        let codec = Codec::new(ErasureConfig::new(6, 3)).unwrap();
        let object = vec![9u8; 2000];
        let mut chunks = codec.encode(&object).unwrap();
        // Corrupt one byte in a chunk that WILL be used for decode.
        let off = chunks[1].packed.len() - 1;
        chunks[1].packed[off] ^= 0xFF;
        let err = codec.decode(&chunks[..3]).unwrap_err();
        assert!(matches!(err, Error::Integrity(_)), "{err}");
    }

    #[test]
    fn mismatched_config_rejected() {
        let c63 = Codec::new(ErasureConfig::new(6, 3)).unwrap();
        let c104 = Codec::new(ErasureConfig::new(10, 4)).unwrap();
        let chunks = c63.encode(&[1u8; 100]).unwrap();
        assert!(c104.decode(&chunks).is_err());
    }

    #[test]
    fn decode_from_parity_only_survivors() {
        // Drop ALL k systematic chunks; reconstruct purely from parity.
        // Only configurations with n-k >= k parity chunks can do this.
        for (n, k) in [(4usize, 2usize), (6, 3), (8, 4), (10, 4), (10, 5), (12, 6), (16, 8)] {
            assert!(n - k >= k, "grid entry ({n},{k}) lacks enough parity");
            let mut rng = Rng::new((n * 131 + k) as u64);
            let object = rng.bytes(3_000 + n * 17);
            let codec = Codec::new(ErasureConfig::new(n, k)).unwrap();
            let chunks = codec.encode(&object).unwrap();
            let parity_only: Vec<Chunk> = chunks[k..k + k].to_vec();
            assert!(parity_only.iter().all(|c| (c.header.index as usize) >= k));
            let rec = codec.decode(&parity_only).unwrap();
            assert_eq!(rec, object, "(n,k)=({n},{k}) parity-only");
        }
    }

    #[test]
    fn decode_from_non_contiguous_survivors() {
        // Stride-2 and reversed survivor sets mixing data + parity across
        // the (n,k) grid; exercises the general inverse path with gaps.
        for (n, k) in [(3usize, 2usize), (6, 3), (10, 4), (10, 7), (12, 8), (16, 11)] {
            let mut rng = Rng::new((n * 977 + k) as u64);
            let object = rng.bytes(10_000);
            let codec = Codec::new(ErasureConfig::new(n, k)).unwrap();
            let chunks = codec.encode(&object).unwrap();

            // Every other index (wrapping to fill up to k survivors).
            let mut picks: Vec<usize> = (0..n).step_by(2).collect();
            let mut odd: Vec<usize> = (1..n).step_by(2).collect();
            picks.append(&mut odd);
            picks.truncate(k);
            let subset: Vec<Chunk> = picks.iter().map(|&i| chunks[i].clone()).collect();
            assert_eq!(codec.decode(&subset).unwrap(), object, "stride (n,k)=({n},{k})");

            // Highest k indices in reverse order (order must not matter).
            let rev: Vec<Chunk> = (n - k..n).rev().map(|i| chunks[i].clone()).collect();
            assert_eq!(codec.decode(&rev).unwrap(), object, "reversed (n,k)=({n},{k})");
        }
    }

    #[test]
    fn systematic_fast_path_matches_general_path() {
        // All-data survivors (fast path) and a mixed set must agree.
        let mut rng = Rng::new(404);
        let object = rng.bytes(50_000);
        let codec = Codec::new(ErasureConfig::new(10, 7)).unwrap();
        let chunks = codec.encode(&object).unwrap();
        let fast = codec.decode(&chunks[..7]).unwrap(); // indices 0..7
        let mixed = codec.decode(&chunks[3..]).unwrap(); // indices 3..10
        assert_eq!(fast, object);
        assert_eq!(mixed, object);
    }

    #[test]
    fn random_sweep_any_k_of_n() {
        let mut rng = Rng::new(99);
        for trial in 0..30 {
            let k = 2 + (trial % 9);
            let n = k + 1 + (trial % (16usize - k).max(1)).min(16 - k - 1);
            let len = 1 + rng.below(20_000) as usize;
            roundtrip(n.min(16), k, len, (n.min(16)) - k, trial as u64);
        }
    }
}
