//! Chunk packaging: Algorithm 1 line 9, `p = PACK(h_o, C[i])` — every
//! chunk carries the SHA3-256 hash of the *original object* so decode can
//! verify integrity end to end (Algorithm 2 lines 6-9), plus a
//! per-chunk payload hash so a single rotten chunk is rejected at
//! [`Chunk::unpack`] — the read path hedges to parity and the scrubber
//! heals the damaged copy, instead of the corruption surviving all the
//! way to decode and failing the whole reconstruction.

use crate::crypto::sha3_256;
use crate::{Error, Result};

/// Fixed binary header prepended to every chunk payload.
///
/// Layout (little-endian, 88 bytes):
/// `magic[4] "DYNC" | version u8 | n u8 | k u8 | index u8 |
///  object_len u64 | chunk_len u64 | object_hash [32] | chunk_hash [32]`
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkHeader {
    pub n: u8,
    pub k: u8,
    /// Row index in the generator matrix (0..n).
    pub index: u8,
    /// Original object length in bytes (strips stripe padding on decode).
    pub object_len: u64,
    /// Payload bytes following the header.
    pub chunk_len: u64,
    /// SHA3-256 of the original object.
    pub object_hash: [u8; 32],
    /// SHA3-256 of *this chunk's* coded payload, written by
    /// [`Chunk::seal`] once the payload is final. Verified on unpack:
    /// localizes bitrot to the one damaged chunk.
    pub chunk_hash: [u8; 32],
}

pub const CHUNK_HEADER_LEN: usize = 88;
const MAGIC: &[u8; 4] = b"DYNC";
const VERSION: u8 = 2;

impl ChunkHeader {
    pub fn encode(&self) -> [u8; CHUNK_HEADER_LEN] {
        let mut out = [0u8; CHUNK_HEADER_LEN];
        out[0..4].copy_from_slice(MAGIC);
        out[4] = VERSION;
        out[5] = self.n;
        out[6] = self.k;
        out[7] = self.index;
        out[8..16].copy_from_slice(&self.object_len.to_le_bytes());
        out[16..24].copy_from_slice(&self.chunk_len.to_le_bytes());
        out[24..56].copy_from_slice(&self.object_hash);
        out[56..88].copy_from_slice(&self.chunk_hash);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<ChunkHeader> {
        if buf.len() < CHUNK_HEADER_LEN {
            return Err(Error::Erasure("chunk too short for header".into()));
        }
        if &buf[0..4] != MAGIC {
            return Err(Error::Erasure("bad chunk magic".into()));
        }
        if buf[4] != VERSION {
            return Err(Error::Erasure(format!("unsupported chunk version {}", buf[4])));
        }
        let mut hash = [0u8; 32];
        hash.copy_from_slice(&buf[24..56]);
        let mut chunk_hash = [0u8; 32];
        chunk_hash.copy_from_slice(&buf[56..88]);
        Ok(ChunkHeader {
            n: buf[5],
            k: buf[6],
            index: buf[7],
            object_len: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            chunk_len: u64::from_le_bytes(buf[16..24].try_into().unwrap()),
            object_hash: hash,
            chunk_hash,
        })
    }
}

/// A packed chunk: header + coded payload, ready for upload (the `p` of
/// Algorithm 1).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Chunk {
    pub header: ChunkHeader,
    /// Full wire bytes (header || payload).
    pub packed: Vec<u8>,
}

impl Chunk {
    pub fn pack(header: ChunkHeader, payload: &[u8]) -> Chunk {
        debug_assert_eq!(header.chunk_len as usize, payload.len());
        let mut chunk = Chunk::new_zeroed(header);
        chunk.payload_mut().copy_from_slice(payload);
        chunk.seal();
        chunk
    }

    /// Allocate the chunk's wire buffer in one pre-sized allocation:
    /// header written, payload zeroed. The encoder fills the payload in
    /// place (systematic copy or parity matmul) so coded bytes are
    /// produced directly into the buffer that goes on the wire — no
    /// intermediate payload vector, no second copy.
    pub fn new_zeroed(header: ChunkHeader) -> Chunk {
        let mut packed = vec![0u8; CHUNK_HEADER_LEN + header.chunk_len as usize];
        packed[..CHUNK_HEADER_LEN].copy_from_slice(&header.encode());
        Chunk { header, packed }
    }

    /// Stamp the payload hash into the header (struct and wire bytes).
    /// Must run after the payload is final — the encoder writes coded
    /// bytes in place, so sealing is a separate last step.
    pub fn seal(&mut self) {
        self.header.chunk_hash = sha3_256(self.payload());
        self.packed[..CHUNK_HEADER_LEN].copy_from_slice(&self.header.encode());
    }

    /// Parse wire bytes back into a chunk; validates header/payload
    /// length consistency and the sealed payload hash, so at-rest or
    /// on-the-wire bitrot is caught here — per chunk, not at decode.
    pub fn unpack(bytes: &[u8]) -> Result<Chunk> {
        let header = ChunkHeader::decode(bytes)?;
        let expect = CHUNK_HEADER_LEN + header.chunk_len as usize;
        if bytes.len() != expect {
            return Err(Error::Erasure(format!(
                "chunk length mismatch: wire {} expect {}",
                bytes.len(),
                expect
            )));
        }
        if sha3_256(&bytes[CHUNK_HEADER_LEN..]) != header.chunk_hash {
            return Err(Error::Integrity(format!(
                "chunk {} payload hash mismatch (bitrot)",
                header.index
            )));
        }
        Ok(Chunk { header, packed: bytes.to_vec() })
    }

    pub fn payload(&self) -> &[u8] {
        &self.packed[CHUNK_HEADER_LEN..]
    }

    /// Mutable view of the payload region (the encoder writes coded
    /// bytes straight into the wire buffer).
    pub fn payload_mut(&mut self) -> &mut [u8] {
        &mut self.packed[CHUNK_HEADER_LEN..]
    }

    /// Total wire size (what the containers store and the WAN carries).
    pub fn wire_len(&self) -> usize {
        self.packed.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header() -> ChunkHeader {
        ChunkHeader {
            n: 10,
            k: 7,
            index: 3,
            object_len: 123456,
            chunk_len: 4,
            object_hash: [0xAB; 32],
            chunk_hash: [0; 32],
        }
    }

    #[test]
    fn header_roundtrip() {
        let h = header();
        let enc = h.encode();
        assert_eq!(ChunkHeader::decode(&enc).unwrap(), h);
    }

    #[test]
    fn new_zeroed_then_fill_and_seal_equals_pack() {
        let mut h = header();
        h.chunk_len = 4;
        let mut z = Chunk::new_zeroed(h.clone());
        assert_eq!(z.payload(), &[0, 0, 0, 0]);
        z.payload_mut().copy_from_slice(&[1, 2, 3, 4]);
        z.seal();
        assert_eq!(z, Chunk::pack(h, &[1, 2, 3, 4]));
    }

    #[test]
    fn chunk_roundtrip() {
        let c = Chunk::pack(header(), &[1, 2, 3, 4]);
        let c2 = Chunk::unpack(&c.packed).unwrap();
        assert_eq!(c2, c);
        assert_eq!(c2.payload(), &[1, 2, 3, 4]);
        assert_eq!(c2.wire_len(), CHUNK_HEADER_LEN + 4);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut enc = header().encode().to_vec();
        enc[0] = b'X';
        assert!(ChunkHeader::decode(&enc).is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let mut enc = header().encode().to_vec();
        enc[4] = 99;
        assert!(ChunkHeader::decode(&enc).is_err());
    }

    #[test]
    fn rejects_payload_bitrot() {
        let c = Chunk::pack(header(), &[1, 2, 3, 4]);
        let mut rotten = c.packed.clone();
        let last = rotten.len() - 1;
        rotten[last] ^= 0xA5;
        match Chunk::unpack(&rotten) {
            Err(Error::Integrity(_)) => {}
            other => panic!("expected Integrity error, got {other:?}"),
        }
        // The pristine bytes still unpack.
        assert_eq!(Chunk::unpack(&c.packed).unwrap(), c);
    }

    #[test]
    fn rejects_truncated() {
        let c = Chunk::pack(header(), &[1, 2, 3, 4]);
        assert!(Chunk::unpack(&c.packed[..c.packed.len() - 1]).is_err());
        assert!(ChunkHeader::decode(&[0u8; 10]).is_err());
    }
}
