//! Property-testing mini-framework (proptest is absent from the vendored
//! crate set — DESIGN.md §3).
//!
//! Seeded generation + N-case runner + greedy input shrinking. Used by
//! the coordinator invariants (routing, placement fairness, erasure
//! roundtrips, metadata consistency) in unit and integration tests.
//!
//! The [`agents`] submodule spins up real container agent servers on
//! localhost for transport-plane integration tests.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries miss the xla_extension rpath)
//! use dynostore::testkit::{forall, prop_assert, Gen};
//! forall(100, |g| {
//!     let xs = g.vec_u8(0, 64);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     prop_assert(ys == xs, "double reverse is identity")
//! });
//! ```

pub mod agents;

pub use agents::{spawn_agent, SpawnedAgent};

use std::sync::Arc;

use crate::container::{deploy_containers, AgentSpec, ContainerChannel, LocalChannel};
use crate::coordinator::DynoStore;
use crate::sim::{DeviceKind, FaultChannel, FaultPlan, Site};
use crate::util::Rng;

/// Uniform container fleet for tests and benches: `count` containers
/// named `{prefix}{i}`, all at the same site/device, with the given
/// memory (cache) and filesystem capacities in bytes.
pub fn uniform_specs(prefix: &str, count: usize, mem: u64, fs: u64) -> Vec<AgentSpec> {
    (0..count)
        .map(|i| {
            AgentSpec::new(
                format!("{prefix}{i}"),
                Site::ChameleonTacc,
                DeviceKind::ChameleonLocal,
            )
            .mem(mem)
            .fs(fs)
        })
        .collect()
}

/// A deployment with EVERY container wrapped in a [`FaultChannel`]
/// under one shared, seeded [`FaultPlan`] — the chaos-plane test
/// harness. Channels consult the plan on every operation, so tests
/// script faults mid-run (`plan.set(cid, spec)`), heal them
/// (`plan.clear(cid)`), and open/close partition windows
/// (`plan.advance_epoch()`) without rebuilding anything. With nothing
/// scripted the fleet behaves exactly like a healthy local deployment.
/// Returns `(deployment, plan, UserA's token)`.
pub fn chaos_deployment(
    count: usize,
    seed: u64,
) -> (Arc<DynoStore>, Arc<FaultPlan>, String) {
    let ds = Arc::new(DynoStore::builder().build());
    let plan = FaultPlan::new(seed);
    let specs = uniform_specs("chaos", count, 64 << 20, 1 << 32);
    for c in deploy_containers(&specs, count, 0).containers {
        let inner: Arc<dyn ContainerChannel> = Arc::new(LocalChannel::new(c));
        ds.add_channel(FaultChannel::new(inner, Arc::clone(&plan))).unwrap();
    }
    let token = ds.register_user("UserA").unwrap();
    (ds, plan, token)
}

/// Outcome of a single property case.
pub type PropResult = Result<(), String>;

/// Assertion helper for property bodies.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Equality assertion with debug formatting.
pub fn prop_assert_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T, msg: &str) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{msg}: {a:?} != {b:?}"))
    }
}

/// Generator handle passed to property bodies. Records draw decisions so
/// failures can report the seed; re-running with the same seed replays
/// the exact case.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), seed }
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as u64, hi as u64) as usize
    }

    pub fn u8(&mut self) -> u8 {
        self.rng.below(256) as u8
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Byte vector with length uniform in [min_len, max_len].
    pub fn vec_u8(&mut self, min_len: usize, max_len: usize) -> Vec<u8> {
        let n = self.usize(min_len, max_len);
        self.rng.bytes(n)
    }

    /// `k` distinct indices from `0..n`, sorted.
    pub fn indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        self.rng.sample_indices(n, k)
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// ASCII identifier of length in [1, max_len] (names, paths).
    pub fn ident(&mut self, max_len: usize) -> String {
        let n = self.usize(1, max_len.max(1));
        (0..n)
            .map(|_| {
                let c = self.rng.below(36);
                if c < 26 {
                    (b'a' + c as u8) as char
                } else {
                    (b'0' + (c - 26) as u8) as char
                }
            })
            .collect()
    }
}

/// Run `cases` property cases with seeds derived from `DYNOSTORE_PROP_SEED`
/// (default 0xD1505) — panics with the failing seed so the case can be
/// replayed exactly.
pub fn forall<F>(cases: u64, mut body: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let base = std::env::var("DYNOSTORE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xD1505);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut gen = Gen::new(seed);
        if let Err(msg) = body(&mut gen) {
            panic!(
                "property failed (case {case}, seed {seed}): {msg}\n\
                 replay with DYNOSTORE_PROP_SEED={seed} and cases=1"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_deployment_roundtrips_when_unscripted() {
        let (ds, plan, token) = chaos_deployment(12, 1);
        assert_eq!(plan.epoch(), 0);
        let data = Rng::new(2).bytes(50_000);
        ds.push(&token, "/UserA", "o", &data, Default::default()).unwrap();
        let pull = ds.pull(&token, "/UserA", "o", Default::default()).unwrap();
        assert_eq!(pull.data, data);
        assert!(!pull.degraded, "nothing scripted: clean read");
    }

    #[test]
    fn forall_passes_trivial_property() {
        forall(50, |g| {
            let x = g.u64(0, 1000);
            prop_assert(x <= 1000, "range upper bound")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures_with_seed() {
        forall(10, |g| {
            let x = g.u64(0, 100);
            prop_assert(x < 5, "will fail for most draws")
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        assert_eq!(a.vec_u8(0, 100), b.vec_u8(0, 100));
        assert_eq!(a.ident(10), b.ident(10));
    }

    #[test]
    fn indices_within_bounds() {
        let mut g = Gen::new(3);
        let idx = g.indices(10, 4);
        assert_eq!(idx.len(), 4);
        assert!(idx.iter().all(|&i| i < 10));
    }

    #[test]
    fn ident_is_nonempty_alnum() {
        let mut g = Gen::new(4);
        for _ in 0..100 {
            let s = g.ident(12);
            assert!(!s.is_empty() && s.len() <= 12);
            assert!(s.chars().all(|c| c.is_ascii_alphanumeric()));
        }
    }
}
