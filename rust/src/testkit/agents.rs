//! Test harness for the remote transport: spin up real container agent
//! servers on localhost ephemeral ports and hand back the connected
//! [`RemoteChannel`], so integration tests exercise the exact HTTP path
//! a wide-area deployment uses.

use std::sync::Arc;

use crate::container::{
    deploy_containers, AgentSpec, ContainerServer, DataContainer, RemoteChannel,
};
use crate::Result;

/// A running localhost agent: the HTTP server, the container it fronts,
/// and a channel already connected to it.
pub struct SpawnedAgent {
    pub server: ContainerServer,
    pub container: Arc<DataContainer>,
    pub channel: Arc<RemoteChannel>,
}

impl SpawnedAgent {
    /// `host:port` the agent listens on.
    pub fn endpoint(&self) -> String {
        self.server.addr().to_string()
    }

    /// Simulate an agent crash: stop the HTTP server so channels see
    /// refused connections (the harshest failure mode — no 503, no
    /// answer at all).
    pub fn crash(&mut self) {
        self.server.shutdown();
    }
}

/// Deploy `spec` as container `id`, serve it on an ephemeral localhost
/// port, and connect a [`RemoteChannel`] to it.
pub fn spawn_agent(spec: AgentSpec, id: u32) -> Result<SpawnedAgent> {
    let container = deploy_containers(&[spec], 1, id)
        .containers
        .into_iter()
        .next()
        .expect("one spec yields one container");
    let server = ContainerServer::serve(Arc::clone(&container), "127.0.0.1:0", 2)?;
    let channel = RemoteChannel::connect(&server.addr().to_string())?;
    Ok(SpawnedAgent { server, container, channel })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::ContainerChannel;
    use crate::sim::{DeviceKind, Site};

    #[test]
    fn spawned_agent_roundtrips_through_http() {
        let agent = spawn_agent(
            AgentSpec::new("dc-test", Site::ChameleonUc, DeviceKind::ChameleonLocal),
            7,
        )
        .unwrap();
        assert_eq!(agent.channel.id(), 7);
        assert_eq!(agent.channel.transport(), "http");
        agent.channel.put("k", b"v").unwrap();
        // The bytes really live in the container behind the server.
        assert_eq!(agent.container.get("k").unwrap().data.unwrap(), b"v");
        assert_eq!(agent.channel.get("k").unwrap().data.unwrap(), b"v");
    }

    #[test]
    fn crashed_agent_reads_as_dead() {
        let mut agent = spawn_agent(
            AgentSpec::new("dc-crash", Site::ChameleonUc, DeviceKind::ChameleonLocal),
            8,
        )
        .unwrap();
        assert!(agent.channel.probe());
        agent.crash();
        assert!(!agent.channel.probe());
        assert!(!agent.channel.is_alive());
        assert!(agent.channel.get("k").is_err());
    }
}
