//! Heterogeneity-aware adaptive reliability & storage tiering (the
//! D-Rex plane, PAPERS.md arXiv:2506.02026 + ROADMAP item 3).
//!
//! Three cooperating pieces, threaded through the whole stack:
//!
//! * [`ScoreBoard`] (`score.rs`) — per-container EWMA scorecards fed
//!   by every chunk I/O, probe, and scrub event the coordinator
//!   performs; durably snapshotted through the keyed kv store and the
//!   only telemetry surface `/metrics` + `/health` export.
//! * [`select_adaptive`] (`policy.rs`) — the `policy: "adaptive"`
//!   engine: per-object (k, n) + placement meeting a configured
//!   durability target (`durability_nines`) at minimum storage
//!   overhead over the *effective* (observed-blended) failure rates.
//! * [`StorageTier`] + [`DynoStore::tier_cycle`] (`tiers.rs`) —
//!   mem/ssd/fs/cold container tiers with access-driven promotion and
//!   demotion over the chunk-migration plane.
//!
//! [`DynoStore::tier_cycle`]: crate::coordinator::DynoStore::tier_cycle

pub mod policy;
pub mod score;
pub mod tiers;

pub use policy::{
    nines_to_loss, select_adaptive, AdaptiveChoice, DEFAULT_DURABILITY_NINES,
};
pub use score::{ContainerScore, ScoreBoard, EWMA_ALPHA, PERSIST_EVERY_OBSERVATIONS};
pub use tiers::{AccessStats, StorageTier, TierCycleOpts, TieringReport};

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::{Arc, RwLock};

use crate::container::{ContainerId, ContainerInfo};
use crate::json::{obj, Value};
use crate::placement::PlacementMetric;
use crate::util::unix_secs;
use crate::Result;

/// The per-store tiering state the coordinator owns: scorecards, tier
/// declarations, and per-object access heat. Shared behind an `Arc`
/// with the scrubber and the gateway.
pub struct TieringPlane {
    /// Fleet scorecards (durable when the store has a data dir).
    pub scores: ScoreBoard,
    tiers: RwLock<BTreeMap<ContainerId, StorageTier>>,
    access: RwLock<HashMap<String, AccessStats>>,
}

impl TieringPlane {
    /// In-memory plane: scores and heat vanish on restart.
    pub fn memory() -> TieringPlane {
        TieringPlane {
            scores: ScoreBoard::memory(),
            tiers: RwLock::new(BTreeMap::new()),
            access: RwLock::new(HashMap::new()),
        }
    }

    /// Durable plane rooted at `dir` (conventionally
    /// `data_dir/tiering/`): scorecards recover from the keyed kv
    /// store; tier declarations come from config each boot and access
    /// heat is deliberately volatile.
    pub fn durable(dir: impl Into<PathBuf>) -> Result<TieringPlane> {
        Ok(TieringPlane {
            scores: ScoreBoard::durable(dir)?,
            tiers: RwLock::new(BTreeMap::new()),
            access: RwLock::new(HashMap::new()),
        })
    }

    /// Declare a container's tier.
    pub fn set_tier(&self, id: ContainerId, tier: StorageTier) {
        let mut map = self.tiers.write().unwrap();
        if tier == StorageTier::default() {
            map.remove(&id);
        } else {
            map.insert(id, tier);
        }
    }

    /// A container's declared tier ([`StorageTier::Fs`] by default).
    pub fn tier_of(&self, id: ContainerId) -> StorageTier {
        self.tiers.read().unwrap().get(&id).copied().unwrap_or_default()
    }

    /// True when any container declares a non-default tier.
    pub fn has_tiers(&self) -> bool {
        !self.tiers.read().unwrap().is_empty()
    }

    /// Record one read access against an object (pull paths).
    pub fn record_access(&self, uuid: &str) {
        let now = unix_secs();
        let mut map = self.access.write().unwrap();
        map.entry(uuid.to_string()).or_default().touch(now);
    }

    /// The object's current heat (zeroed stats when never accessed).
    pub fn access_stats(&self, uuid: &str) -> AccessStats {
        self.access.read().unwrap().get(uuid).copied().unwrap_or_default()
    }

    /// Drop heat for an evicted object.
    pub fn forget_access(&self, uuid: &str) {
        self.access.write().unwrap().remove(uuid);
    }

    /// Number of objects with recorded heat.
    pub fn tracked_objects(&self) -> usize {
        self.access.read().unwrap().len()
    }

    /// Per-tier container counts over `infos` — the `/metrics` tier
    /// gauges.
    pub fn tier_counts(&self, infos: &[ContainerInfo]) -> BTreeMap<StorageTier, usize> {
        let mut counts: BTreeMap<StorageTier, usize> = BTreeMap::new();
        for c in infos {
            *counts.entry(self.tier_of(c.id)).or_insert(0) += 1;
        }
        counts
    }

    /// JSON rendering of the tier declarations for `/health`.
    pub fn tiers_json(&self, infos: &[ContainerInfo]) -> Value {
        let entries: Vec<Value> = infos
            .iter()
            .map(|c| {
                obj(vec![
                    ("id", Value::Num(c.id as f64)),
                    ("tier", Value::Str(self.tier_of(c.id).as_str().to_string())),
                ])
            })
            .collect();
        Value::Arr(entries)
    }
}

impl std::fmt::Debug for TieringPlane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TieringPlane")
            .field("scores", &self.scores)
            .field("tiers", &self.tiers.read().unwrap().len())
            .field("tracked_objects", &self.tracked_objects())
            .finish()
    }
}

/// Placement penalty derived from the scorecards: a container's
/// effective AFR (catalog blended with observed errors and observed
/// unavailability) is added straight onto its Eq. 1 occupancy score,
/// so capacity ties break toward reliable containers. Only installed
/// when the adaptive plane is enabled — the default placer stays
/// byte-identical to the static behavior.
pub struct ScorePenalty {
    plane: Arc<TieringPlane>,
}

impl ScorePenalty {
    pub fn new(plane: Arc<TieringPlane>) -> ScorePenalty {
        ScorePenalty { plane }
    }
}

impl PlacementMetric for ScorePenalty {
    fn penalty(&self, info: &ContainerInfo) -> f64 {
        self.plane.scores.effective_afr(info.id, info.annual_failure_rate)
    }

    fn name(&self) -> &'static str {
        "scorecard"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Site;

    fn info(id: u32) -> ContainerInfo {
        ContainerInfo {
            id,
            name: format!("dc{id}"),
            site: Site::ChameleonTacc,
            alive: true,
            mem_total: 1 << 30,
            mem_avail: 1 << 29,
            fs_total: 1 << 40,
            fs_avail: 1 << 39,
            annual_failure_rate: 0.05,
        }
    }

    #[test]
    fn tiers_default_to_fs_and_track_declarations() {
        let p = TieringPlane::memory();
        assert_eq!(p.tier_of(1), StorageTier::Fs);
        assert!(!p.has_tiers());
        p.set_tier(1, StorageTier::Mem);
        p.set_tier(2, StorageTier::Cold);
        assert!(p.has_tiers());
        assert_eq!(p.tier_of(1), StorageTier::Mem);
        let counts = p.tier_counts(&[info(1), info(2), info(3)]);
        assert_eq!(counts.get(&StorageTier::Mem), Some(&1));
        assert_eq!(counts.get(&StorageTier::Cold), Some(&1));
        assert_eq!(counts.get(&StorageTier::Fs), Some(&1));
        // Re-declaring the default drops the entry.
        p.set_tier(1, StorageTier::Fs);
        p.set_tier(2, StorageTier::Fs);
        assert!(!p.has_tiers());
    }

    #[test]
    fn access_heat_tracks_pulls() {
        let p = TieringPlane::memory();
        assert_eq!(p.access_stats("u1").hits, 0);
        p.record_access("u1");
        p.record_access("u1");
        let s = p.access_stats("u1");
        assert_eq!(s.hits, 2);
        assert!(s.rate >= 1.0);
        p.forget_access("u1");
        assert_eq!(p.access_stats("u1").hits, 0);
        assert_eq!(p.tracked_objects(), 0);
    }

    #[test]
    fn score_penalty_prices_observed_failures() {
        let plane = Arc::new(TieringPlane::memory());
        for _ in 0..500 {
            plane.scores.observe_io(1, false, 0, 0.01);
            plane.scores.observe_io(2, true, 1024, 0.01);
        }
        let m = ScorePenalty::new(plane);
        assert!(m.penalty(&info(1)) > m.penalty(&info(2)) + 0.5);
        assert_eq!(m.name(), "scorecard");
    }
}
