//! Container scorecards (D-Rex direction, PAPERS.md arXiv:2506.02026):
//! fold per-chunk I/O outcomes, liveness probes, and scrub events into
//! per-container EWMA statistics — error rate, latency, bandwidth,
//! observed availability — and blend them with the cataloged annual
//! failure rate into an *effective* AFR the adaptive policy engine
//! ([`crate::tiering::select_adaptive`]) solves against.
//!
//! The board is fed from the coordinator's single chunk-I/O choke point
//! (`dispatch_chunk_io_deadline`), the two direct-I/O paths (Regular
//! push, single-copy migration), repair probes, and the scrubber, so
//! every byte the system moves leaves a trace here. Scores persist
//! through the same keyed kv store the sharded metadata plane uses
//! ([`crate::durability::KvStore`]) under `data_dir/tiering/`, one
//! `score:<id>` key per container, flushed every
//! [`PERSIST_EVERY_OBSERVATIONS`] observations and on demand.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

use crate::container::ContainerId;
use crate::durability::KvStore;
use crate::json::{obj, Value};
use crate::util::unix_secs;
use crate::Result;

/// EWMA smoothing factor: one observation moves the estimate 15% of the
/// way to the sample, so ~15 observations dominate the history — quick
/// enough to notice a container going bad mid-benchmark, smooth enough
/// that one hedged timeout does not blacklist a healthy node.
pub const EWMA_ALPHA: f64 = 0.15;

/// Flush dirty scores to the kv store after this many observations.
pub const PERSIST_EVERY_OBSERVATIONS: u64 = 256;

/// Observed-history weight saturates as `ops / (ops + OPS_HALFWAY)`:
/// after 64 chunk ops the observed error rate carries half the weight
/// of the cataloged AFR, after ~600 it carries ~90%.
pub const OPS_HALFWAY: f64 = 64.0;

/// Effective AFR never drops below this fraction of the cataloged rate:
/// a clean observation window is evidence, not proof, of reliability.
pub const AFR_FLOOR_FRACTION: f64 = 0.25;

/// Ceiling for any effective AFR (a container can always limp).
pub const AFR_CEILING: f64 = 0.95;

/// One container's rolling statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct ContainerScore {
    /// Chunk operations observed (success or failure).
    pub ops: u64,
    /// Failed chunk operations.
    pub errors: u64,
    /// Payload bytes successfully moved to/from this container.
    pub bytes_moved: u64,
    /// EWMA of the per-op failure indicator (0 = healthy, 1 = failing).
    pub err_ewma: f64,
    /// EWMA of per-op wall latency, seconds.
    pub lat_ewma_s: f64,
    /// EWMA of observed bandwidth, bytes/second (successful ops only).
    pub bw_ewma: f64,
    /// EWMA of liveness-probe outcomes (1 = alive when probed).
    pub avail_ewma: f64,
    /// Liveness probes observed.
    pub probes: u64,
    /// Scrub verifications that found a corrupt or missing chunk here.
    pub scrub_corrupt: u64,
    /// Unix seconds of the last observation.
    pub last_unix: u64,
}

impl ContainerScore {
    fn new() -> ContainerScore {
        ContainerScore {
            ops: 0,
            errors: 0,
            bytes_moved: 0,
            err_ewma: 0.0,
            lat_ewma_s: 0.0,
            bw_ewma: 0.0,
            avail_ewma: 1.0,
            probes: 0,
            scrub_corrupt: 0,
            last_unix: 0,
        }
    }

    /// Observed per-op error rate over the whole window (not smoothed).
    pub fn error_rate(&self) -> f64 {
        if self.ops == 0 {
            0.0
        } else {
            self.errors as f64 / self.ops as f64
        }
    }

    fn to_json(&self) -> Value {
        obj(vec![
            ("ops", Value::Num(self.ops as f64)),
            ("errors", Value::Num(self.errors as f64)),
            ("bytes_moved", Value::Num(self.bytes_moved as f64)),
            ("err_ewma", Value::Num(self.err_ewma)),
            ("lat_ewma_s", Value::Num(self.lat_ewma_s)),
            ("bw_ewma", Value::Num(self.bw_ewma)),
            ("avail_ewma", Value::Num(self.avail_ewma)),
            ("probes", Value::Num(self.probes as f64)),
            ("scrub_corrupt", Value::Num(self.scrub_corrupt as f64)),
            ("last_unix", Value::Num(self.last_unix as f64)),
        ])
    }

    fn from_json(v: &Value) -> ContainerScore {
        ContainerScore {
            ops: v.opt_u64("ops", 0),
            errors: v.opt_u64("errors", 0),
            bytes_moved: v.opt_u64("bytes_moved", 0),
            err_ewma: v.opt_f64("err_ewma", 0.0),
            lat_ewma_s: v.opt_f64("lat_ewma_s", 0.0),
            bw_ewma: v.opt_f64("bw_ewma", 0.0),
            avail_ewma: v.opt_f64("avail_ewma", 1.0),
            probes: v.opt_u64("probes", 0),
            scrub_corrupt: v.opt_u64("scrub_corrupt", 0),
            last_unix: v.opt_u64("last_unix", 0),
        }
    }
}

fn score_key(id: ContainerId) -> String {
    format!("score:{id}")
}

/// The fleet-wide scorecard: one [`ContainerScore`] per container,
/// optionally persisted through a keyed kv store. All methods take
/// `&self`; the board is shared behind an `Arc` by the coordinator, the
/// scrubber, and the gateway.
pub struct ScoreBoard {
    scores: RwLock<BTreeMap<ContainerId, ContainerScore>>,
    /// Observations since the last flush.
    dirty: AtomicU64,
    /// Monotonic flush sequence (the kv segment watermark).
    flush_seq: AtomicU64,
    kv: Option<Mutex<KvStore>>,
}

impl ScoreBoard {
    /// In-memory board (no `data_dir`): scores vanish on restart.
    pub fn memory() -> ScoreBoard {
        ScoreBoard {
            scores: RwLock::new(BTreeMap::new()),
            dirty: AtomicU64::new(0),
            flush_seq: AtomicU64::new(0),
            kv: None,
        }
    }

    /// Durable board rooted at `dir` (conventionally
    /// `data_dir/tiering/`): recovers any persisted scores, then
    /// appends dirty-score delta segments as observations accumulate.
    pub fn durable(dir: impl Into<PathBuf>) -> Result<ScoreBoard> {
        let (kv, recovery) = KvStore::open(dir)?;
        let mut scores = BTreeMap::new();
        for (key, value) in &recovery.entries {
            if let Some(id) = key.strip_prefix("score:") {
                if let Ok(id) = id.parse::<ContainerId>() {
                    scores.insert(id, ContainerScore::from_json(value));
                }
            }
        }
        Ok(ScoreBoard {
            scores: RwLock::new(scores),
            dirty: AtomicU64::new(0),
            flush_seq: AtomicU64::new(recovery.watermark),
            kv: Some(Mutex::new(kv)),
        })
    }

    /// Record one chunk operation against `id`: outcome, payload bytes
    /// moved, and wall seconds spent.
    pub fn observe_io(&self, id: ContainerId, ok: bool, bytes: u64, wall_s: f64) {
        {
            let mut map = self.scores.write().unwrap();
            let s = map.entry(id).or_insert_with(ContainerScore::new);
            let sample = if ok { 0.0 } else { 1.0 };
            s.err_ewma += EWMA_ALPHA * (sample - s.err_ewma);
            if wall_s.is_finite() && wall_s >= 0.0 {
                if s.ops == 0 {
                    s.lat_ewma_s = wall_s;
                } else {
                    s.lat_ewma_s += EWMA_ALPHA * (wall_s - s.lat_ewma_s);
                }
                if ok && bytes > 0 && wall_s > 0.0 {
                    let inst = bytes as f64 / wall_s;
                    if s.bw_ewma == 0.0 {
                        s.bw_ewma = inst;
                    } else {
                        s.bw_ewma += EWMA_ALPHA * (inst - s.bw_ewma);
                    }
                }
            }
            s.ops += 1;
            if ok {
                s.bytes_moved += bytes;
            } else {
                s.errors += 1;
            }
            s.last_unix = unix_secs();
        }
        self.bump_dirty();
    }

    /// Record a liveness-probe outcome for `id`.
    pub fn observe_probe(&self, id: ContainerId, alive: bool) {
        {
            let mut map = self.scores.write().unwrap();
            let s = map.entry(id).or_insert_with(ContainerScore::new);
            let sample = if alive { 1.0 } else { 0.0 };
            s.avail_ewma += EWMA_ALPHA * (sample - s.avail_ewma);
            s.probes += 1;
            s.last_unix = unix_secs();
        }
        self.bump_dirty();
    }

    /// Record a scrub verification of a chunk held by `id`.
    pub fn observe_scrub(&self, id: ContainerId, healthy: bool) {
        {
            let mut map = self.scores.write().unwrap();
            let s = map.entry(id).or_insert_with(ContainerScore::new);
            // A scrub hit counts as an error observation too: silent
            // corruption is a failure of the stored copy even though
            // the transport op "succeeded".
            let sample = if healthy { 0.0 } else { 1.0 };
            s.err_ewma += EWMA_ALPHA * (sample - s.err_ewma);
            if !healthy {
                s.scrub_corrupt += 1;
            }
            s.last_unix = unix_secs();
        }
        self.bump_dirty();
    }

    /// Snapshot of one container's score.
    pub fn get(&self, id: ContainerId) -> Option<ContainerScore> {
        self.scores.read().unwrap().get(&id).cloned()
    }

    /// Snapshot of every score, id-sorted.
    pub fn all(&self) -> Vec<(ContainerId, ContainerScore)> {
        self.scores
            .read()
            .unwrap()
            .iter()
            .map(|(id, s)| (*id, s.clone()))
            .collect()
    }

    /// Number of containers with any recorded history.
    pub fn len(&self) -> usize {
        self.scores.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Effective annual failure rate for placement decisions: the
    /// cataloged `declared` AFR blended with the observed error EWMA,
    /// the observed side weighted by how much history we actually have
    /// (`ops / (ops + OPS_HALFWAY)`). Unavailability seen by probes is
    /// folded in as additional risk. Clamped to
    /// `[declared * AFR_FLOOR_FRACTION, AFR_CEILING]` so a lucky quiet
    /// window cannot claim a flaky container is perfect, and monotone
    /// in the observed error rate.
    pub fn effective_afr(&self, id: ContainerId, declared: f64) -> f64 {
        let declared = declared.clamp(0.0, AFR_CEILING);
        let map = self.scores.read().unwrap();
        let s = match map.get(&id) {
            Some(s) => s,
            None => return declared,
        };
        let w = s.ops as f64 / (s.ops as f64 + OPS_HALFWAY);
        let unavail = if s.probes > 0 { 1.0 - s.avail_ewma } else { 0.0 };
        let observed = (s.err_ewma + unavail).clamp(0.0, 1.0);
        let blended = declared * (1.0 - w) + observed * w;
        blended.clamp(declared * AFR_FLOOR_FRACTION, AFR_CEILING)
    }

    /// Observations accumulated since the last flush.
    pub fn dirty(&self) -> u64 {
        self.dirty.load(Ordering::Relaxed)
    }

    /// Persist every score as one delta segment (no-op for in-memory
    /// boards). Rotating/folding old segments happens on a background
    /// thread inside the kv store.
    pub fn flush(&self) -> Result<()> {
        let kv = match &self.kv {
            Some(kv) => kv,
            None => {
                self.dirty.store(0, Ordering::Relaxed);
                return Ok(());
            }
        };
        let delta: Vec<(String, Option<Value>)> = self
            .all()
            .into_iter()
            .map(|(id, s)| (score_key(id), Some(s.to_json())))
            .collect();
        let seq = self.flush_seq.fetch_add(1, Ordering::Relaxed) + 1;
        let mut kv = kv.lock().unwrap();
        kv.append_delta(seq, &delta)?;
        kv.maybe_compact()?;
        self.dirty.store(0, Ordering::Relaxed);
        Ok(())
    }

    /// Block until any in-flight background compaction finishes.
    pub fn sync(&self) {
        if let Some(kv) = &self.kv {
            kv.lock().unwrap().sync_compactor();
        }
    }

    fn bump_dirty(&self) {
        let n = self.dirty.fetch_add(1, Ordering::Relaxed) + 1;
        if n >= PERSIST_EVERY_OBSERVATIONS && n % PERSIST_EVERY_OBSERVATIONS == 0 {
            if let Err(e) = self.flush() {
                crate::log_warn!("scorecard flush failed: {e}");
            }
        }
    }

    /// JSON rendering for `/health` and `/metrics`: one object per
    /// container with the aggregated I/O statistics (satellite: the
    /// only telemetry surface for per-chunk outcomes).
    pub fn to_json(&self) -> Value {
        let cards: Vec<Value> = self
            .all()
            .into_iter()
            .map(|(id, s)| {
                obj(vec![
                    ("id", Value::Num(id as f64)),
                    ("ops", Value::Num(s.ops as f64)),
                    ("errors", Value::Num(s.errors as f64)),
                    ("error_rate", Value::Num(s.error_rate())),
                    ("err_ewma", Value::Num(s.err_ewma)),
                    ("lat_ewma_ms", Value::Num(s.lat_ewma_s * 1e3)),
                    ("bw_ewma_bps", Value::Num(s.bw_ewma)),
                    ("avail_ewma", Value::Num(s.avail_ewma)),
                    ("bytes_moved", Value::Num(s.bytes_moved as f64)),
                    ("probes", Value::Num(s.probes as f64)),
                    ("scrub_corrupt", Value::Num(s.scrub_corrupt as f64)),
                    ("last_unix", Value::Num(s.last_unix as f64)),
                ])
            })
            .collect();
        Value::Arr(cards)
    }
}

impl std::fmt::Debug for ScoreBoard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ScoreBoard")
            .field("containers", &self.len())
            .field("dirty", &self.dirty())
            .field("durable", &self.kv.is_some())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges_toward_error_rate() {
        let b = ScoreBoard::memory();
        for _ in 0..200 {
            b.observe_io(1, false, 0, 0.010);
        }
        let s = b.get(1).unwrap();
        assert!(s.err_ewma > 0.99, "err_ewma {}", s.err_ewma);
        assert_eq!(s.ops, 200);
        assert_eq!(s.errors, 200);
        for _ in 0..200 {
            b.observe_io(1, true, 1024, 0.010);
        }
        let s = b.get(1).unwrap();
        assert!(s.err_ewma < 0.01, "err_ewma {}", s.err_ewma);
        assert_eq!(s.bytes_moved, 200 * 1024);
    }

    #[test]
    fn bandwidth_and_latency_track_samples() {
        let b = ScoreBoard::memory();
        // 1 MiB in 10 ms = ~104.8 MB/s.
        for _ in 0..50 {
            b.observe_io(7, true, 1 << 20, 0.010);
        }
        let s = b.get(7).unwrap();
        assert!((s.lat_ewma_s - 0.010).abs() < 1e-9, "lat {}", s.lat_ewma_s);
        let expect = (1u64 << 20) as f64 / 0.010;
        assert!((s.bw_ewma - expect).abs() / expect < 1e-9, "bw {}", s.bw_ewma);
    }

    #[test]
    fn effective_afr_blends_with_history() {
        let b = ScoreBoard::memory();
        // No history: declared rate passes through.
        assert_eq!(b.effective_afr(3, 0.10), 0.10);
        // A long clean history pulls the estimate down, floored at a
        // quarter of the declared rate.
        for _ in 0..10_000 {
            b.observe_io(3, true, 100, 0.001);
        }
        let eff = b.effective_afr(3, 0.10);
        assert!(eff < 0.10 && eff >= 0.025, "eff {eff}");
        // A failing container is pushed far above its catalog rate.
        for _ in 0..10_000 {
            b.observe_io(4, false, 100, 0.001);
        }
        let bad = b.effective_afr(4, 0.02);
        assert!(bad > 0.9, "eff {bad}");
    }

    #[test]
    fn effective_afr_monotone_in_observed_errors() {
        let clean = ScoreBoard::memory();
        let dirty = ScoreBoard::memory();
        for i in 0..500 {
            clean.observe_io(1, true, 10, 0.001);
            dirty.observe_io(1, i % 4 != 0, 10, 0.001); // 25% failures
        }
        assert!(dirty.effective_afr(1, 0.05) > clean.effective_afr(1, 0.05));
    }

    #[test]
    fn probes_fold_into_availability_and_afr() {
        let b = ScoreBoard::memory();
        for _ in 0..100 {
            b.observe_probe(9, false);
        }
        let s = b.get(9).unwrap();
        assert!(s.avail_ewma < 0.01, "avail {}", s.avail_ewma);
        assert!(b.effective_afr(9, 0.01) > 0.3);
    }

    #[test]
    fn scrub_corruption_counts_as_error_evidence() {
        let b = ScoreBoard::memory();
        for _ in 0..50 {
            b.observe_scrub(2, false);
        }
        let s = b.get(2).unwrap();
        assert_eq!(s.scrub_corrupt, 50);
        assert!(s.err_ewma > 0.9);
    }

    #[test]
    fn scores_round_trip_through_kv_store() {
        let dir = std::env::temp_dir().join(format!("dyno-score-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let b = ScoreBoard::durable(&dir).unwrap();
            for i in 0..10 {
                b.observe_io(1, i % 3 != 0, 4096, 0.002);
                b.observe_probe(2, true);
            }
            b.flush().unwrap();
            b.sync();
        }
        let b2 = ScoreBoard::durable(&dir).unwrap();
        let s = b2.get(1).unwrap();
        assert_eq!(s.ops, 10);
        assert_eq!(s.errors, 4);
        assert!(s.bytes_moved > 0);
        let p = b2.get(2).unwrap();
        assert_eq!(p.probes, 10);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn memory_board_flush_is_noop() {
        let b = ScoreBoard::memory();
        b.observe_io(1, true, 1, 0.001);
        assert!(b.dirty() > 0);
        b.flush().unwrap();
        assert_eq!(b.dirty(), 0);
    }

    #[test]
    fn json_surface_has_aggregated_fields() {
        let b = ScoreBoard::memory();
        b.observe_io(5, true, 2048, 0.004);
        b.observe_io(5, false, 0, 0.050);
        let v = b.to_json();
        let cards = v.as_arr().unwrap();
        assert_eq!(cards.len(), 1);
        let c = &cards[0];
        assert_eq!(c.req_u64("id").unwrap(), 5);
        assert_eq!(c.req_u64("ops").unwrap(), 2);
        assert_eq!(c.req_u64("errors").unwrap(), 1);
        assert!(c.opt_f64("error_rate", 0.0) > 0.49);
        assert!(c.opt_f64("lat_ewma_ms", 0.0) > 0.0);
    }
}
