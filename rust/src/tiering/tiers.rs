//! Storage tiers: containers declare a tier (mem/ssd/fs/cold), pulls
//! feed per-object access statistics, and [`DynoStore::tier_cycle`]
//! promotes hot objects' chunks into cache-tier containers / demotes
//! cold ones back out — executed through the PR 3 chunk-migration
//! plane (`migrate_erasure_chunks`), so every cross-tier move keeps
//! the read-during-migration and CAS-commit guarantees the rebalancer
//! already has, and caps per-object moves at n − k per cycle (the
//! stale-reader parity budget).

use std::collections::HashSet;

use crate::coordinator::DynoStore;
use crate::coordinator::lifecycle::ChunkMove;
use crate::metadata::ObjectPlacement;
use crate::util::unix_secs;
use crate::{Error, Result};

/// A container's declared storage tier, hottest first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum StorageTier {
    /// RAM-backed cache container.
    Mem,
    /// Fast local flash.
    Ssd,
    /// General filesystem capacity (the default for every container).
    Fs,
    /// Archival/cold capacity: demotion target, last resort otherwise.
    Cold,
}

impl StorageTier {
    pub fn parse(s: &str) -> Result<StorageTier> {
        match s {
            "mem" => Ok(StorageTier::Mem),
            "ssd" => Ok(StorageTier::Ssd),
            "fs" => Ok(StorageTier::Fs),
            "cold" => Ok(StorageTier::Cold),
            other => Err(Error::Config(format!(
                "unknown tier '{other}' (expected mem|ssd|fs|cold)"
            ))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            StorageTier::Mem => "mem",
            StorageTier::Ssd => "ssd",
            StorageTier::Fs => "fs",
            StorageTier::Cold => "cold",
        }
    }

    /// Cache tiers hold promoted hot objects.
    pub fn is_cache(&self) -> bool {
        matches!(self, StorageTier::Mem | StorageTier::Ssd)
    }
}

impl Default for StorageTier {
    fn default() -> Self {
        StorageTier::Fs
    }
}

impl std::fmt::Display for StorageTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Per-object access history: a time-decayed access rate plus the last
/// touch, fed by `record_access` on every pull.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct AccessStats {
    /// Total accesses observed.
    pub hits: u64,
    /// Exponentially decayed access weight (τ = [`ACCESS_DECAY_TAU_S`]):
    /// each access adds 1, prior weight decays with elapsed time.
    pub rate: f64,
    /// Unix seconds of the last access.
    pub last_unix: u64,
}

/// Decay constant for the access-rate estimate: an object idle for ten
/// minutes has lost ~63% of its accumulated heat.
pub const ACCESS_DECAY_TAU_S: f64 = 600.0;

impl AccessStats {
    pub(crate) fn touch(&mut self, now: u64) {
        let dt = now.saturating_sub(self.last_unix) as f64;
        if self.last_unix > 0 {
            self.rate *= (-dt / ACCESS_DECAY_TAU_S).exp();
        }
        self.rate += 1.0;
        self.hits += 1;
        self.last_unix = now;
    }
}

/// Knobs for one promotion/demotion cycle.
#[derive(Debug, Clone, Copy)]
pub struct TierCycleOpts {
    /// Decayed access rate at or above which an object is hot.
    pub hot_rate: f64,
    /// Seconds without any access after which an object is cold.
    pub cold_after_secs: u64,
    /// Objects examined per cycle (catalog scans stay bounded).
    pub max_objects: usize,
    /// Chunk-move budget across the whole cycle.
    pub max_moves: usize,
}

impl Default for TierCycleOpts {
    fn default() -> Self {
        TierCycleOpts {
            hot_rate: 3.0,
            cold_after_secs: 3600,
            max_objects: 256,
            max_moves: 64,
        }
    }
}

/// What one tier cycle achieved.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TieringReport {
    /// Erasure objects examined.
    pub examined: usize,
    /// Objects that had at least one chunk promoted into a cache tier.
    pub promoted: usize,
    /// Objects that had at least one chunk demoted out of a cache tier.
    pub demoted: usize,
    /// Chunk moves committed.
    pub chunks_moved: usize,
    /// Moves that failed (left on their old tier; retried next cycle).
    pub failed: usize,
    /// Objects skipped: non-erasure placement, no feasible target, or
    /// the move budget ran out.
    pub skipped: usize,
}

impl DynoStore {
    /// Declare `id`'s storage tier (config/CLI and tests).
    pub fn set_container_tier(&self, id: u32, tier: StorageTier) -> Result<()> {
        self.registry.get(id)?;
        self.tiering.set_tier(id, tier);
        Ok(())
    }

    /// The declared tier of `id` (default [`StorageTier::Fs`]).
    pub fn container_tier(&self, id: u32) -> StorageTier {
        self.tiering.tier_of(id)
    }

    /// One promotion/demotion pass over the catalog, driven by the
    /// per-object access stats: hot erasure objects move chunks onto
    /// cache-tier containers, cold ones move chunks off them. A no-op
    /// (and cheap) when no container declares a cache tier — the
    /// default fleet never migrates for temperature.
    pub fn tier_cycle(&self, opts: TierCycleOpts) -> Result<TieringReport> {
        let mut report = TieringReport::default();
        let infos = self.registry.placement_infos();
        let cache_ids: Vec<u32> = infos
            .iter()
            .filter(|c| self.tiering.tier_of(c.id).is_cache())
            .map(|c| c.id)
            .collect();
        if cache_ids.is_empty() {
            return Ok(report);
        }
        let now = unix_secs();
        let mut moves_left = opts.max_moves;

        for meta in self.meta.all_objects()? {
            if report.examined >= opts.max_objects || moves_left == 0 {
                break;
            }
            let (n, k, chunks) = match &meta.placement {
                ObjectPlacement::Erasure { n, k, chunks } => (*n, *k, chunks.clone()),
                _ => continue,
            };
            report.examined += 1;
            let stats = self.tiering.access_stats(&meta.uuid);
            let idle = now.saturating_sub(stats.last_unix);
            let hot = stats.last_unix > 0
                && stats.rate >= opts.hot_rate
                && idle < opts.cold_after_secs;
            let cold = stats.last_unix == 0 || idle >= opts.cold_after_secs;

            // Which chunks sit on the wrong side of the cache boundary?
            let misplaced: Vec<(u8, u32)> = chunks
                .iter()
                .filter(|(_, c)| {
                    let cached = self.tiering.tier_of(*c).is_cache();
                    (hot && !cached) || (cold && cached)
                })
                .cloned()
                .collect();
            if misplaced.is_empty() || (!hot && !cold) {
                continue;
            }

            // Candidate targets on the desired side, most reliable
            // first, excluding containers already holding a chunk of
            // this object (placement distinctness).
            let holders: HashSet<u32> = chunks.iter().map(|&(_, c)| c).collect();
            let chunk_bytes = self.packed_chunk_len(n, k, meta.size)?;
            let mut targets: Vec<&crate::container::ContainerInfo> = infos
                .iter()
                .filter(|c| {
                    let tier = self.tiering.tier_of(c.id);
                    let right_side = if hot { tier.is_cache() } else { !tier.is_cache() };
                    right_side
                        && !holders.contains(&c.id)
                        && c.fs_avail.max(c.mem_avail) >= chunk_bytes
                })
                .collect();
            targets.sort_by(|a, b| {
                let (ta, tb) = (self.tiering.tier_of(a.id), self.tiering.tier_of(b.id));
                // Promotions prefer the hottest tier, demotions the
                // coldest; ties by effective AFR then id.
                let rank = if hot { ta.cmp(&tb) } else { tb.cmp(&ta) };
                rank.then(
                    self.tiering
                        .scores
                        .effective_afr(a.id, a.annual_failure_rate)
                        .partial_cmp(
                            &self.tiering.scores.effective_afr(b.id, b.annual_failure_rate),
                        )
                        .unwrap_or(std::cmp::Ordering::Equal),
                )
                .then(a.id.cmp(&b.id))
            });
            if targets.is_empty() {
                report.skipped += 1;
                continue;
            }

            // Stale-reader parity budget: at most n − k moves per
            // object per cycle, like the rebalancer's batches.
            let budget = misplaced.len().min(n - k).min(moves_left);
            let planned: Vec<ChunkMove> = misplaced
                .iter()
                .take(budget)
                .zip(targets.iter())
                .map(|(&(index, from), t)| ChunkMove { index, from, to: t.id })
                .collect();
            if planned.is_empty() {
                report.skipped += 1;
                continue;
            }
            let out = self.migrate_erasure_chunks(&meta, n, k, &chunks, &planned)?;
            moves_left = moves_left.saturating_sub(planned.len());
            report.chunks_moved += out.moved;
            report.failed += out.failed;
            if out.moved > 0 {
                if hot {
                    report.promoted += 1;
                    self.metrics
                        .tier_promotions
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                } else {
                    report.demoted += 1;
                    self.metrics
                        .tier_demotions
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_parse_round_trip() {
        for t in [StorageTier::Mem, StorageTier::Ssd, StorageTier::Fs, StorageTier::Cold] {
            assert_eq!(StorageTier::parse(t.as_str()).unwrap(), t);
        }
        assert!(StorageTier::parse("tape").is_err());
        assert_eq!(StorageTier::default(), StorageTier::Fs);
        assert!(StorageTier::Mem.is_cache() && StorageTier::Ssd.is_cache());
        assert!(!StorageTier::Fs.is_cache() && !StorageTier::Cold.is_cache());
    }

    #[test]
    fn access_stats_accumulate_and_decay() {
        let mut s = AccessStats::default();
        let t0 = 1_000_000;
        for _ in 0..5 {
            s.touch(t0);
        }
        assert_eq!(s.hits, 5);
        assert!((s.rate - 5.0).abs() < 1e-9);
        // Ten minutes later most of the heat is gone.
        s.touch(t0 + 600);
        assert!(s.rate < 5.0 * 0.37 + 1.0 + 1e-9, "rate {}", s.rate);
        assert_eq!(s.last_unix, t0 + 600);
    }
}
