//! Adaptive per-object redundancy (D-Rex direction, PAPERS.md
//! arXiv:2506.02026): given a target durability expressed in nines,
//! solve for the (k, n) erasure configuration *and* placement over the
//! scored fleet that meets the target at minimum storage overhead —
//! wide stripes across reliable containers, extra parity when the
//! fleet forces flaky ones into the stripe.
//!
//! Contrast with [`crate::policy::select_dynamic`] (paper §VI-D),
//! which fixes k and only grows parity: the adaptive engine searches
//! the whole (k, n) plane and rates containers by their *effective*
//! AFR — catalog rate blended with observed error history from the
//! [`crate::tiering::ScoreBoard`] — so a container that looked fine in
//! the catalog but fails chunks in practice is priced accordingly.

use crate::container::ContainerInfo;
use crate::erasure::ErasureConfig;
use crate::sim::FailureModel;
use crate::tiering::ScoreBoard;
use crate::{Error, Result};

/// Default durability target: three nines = 99.9% per item-year, the
/// paper's §VI-D reliability target (max 0.1% loss probability).
pub const DEFAULT_DURABILITY_NINES: f64 = 3.0;

/// Largest stripe width the erasure kernels support (n ≤ 16).
const MAX_STRIPE: usize = 16;

/// Convert a durability target in nines to a loss-probability bound:
/// 3.0 nines → 1e-3, 4.5 nines → ~3.16e-5.
pub fn nines_to_loss(nines: f64) -> f64 {
    10f64.powf(-nines.max(0.0))
}

/// Result of the adaptive selection.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptiveChoice {
    pub config: ErasureConfig,
    /// Container ids, one per chunk, most reliable first.
    pub containers: Vec<u32>,
    /// Predicted one-year loss probability of this exact placement.
    pub loss_probability: f64,
    /// The loss bound the solver aimed for.
    pub target_loss: f64,
    /// False when no feasible (k, n) met the target and this is the
    /// lowest-risk placement available (best effort).
    pub met_target: bool,
}

impl AdaptiveChoice {
    /// Total bytes stored per logical byte (n/k); 1.0 = no redundancy.
    pub fn stored_ratio(&self) -> f64 {
        self.config.n as f64 / self.config.k as f64
    }
}

#[derive(Clone)]
struct Candidate {
    n: usize,
    k: usize,
    loss: f64,
    containers: Vec<u32>,
}

impl Candidate {
    /// Ordering among target-meeting candidates: least storage
    /// overhead first (n/k, compared exactly in integers), then most
    /// failures tolerated, then the narrower stripe.
    fn preferred_over(&self, other: &Candidate) -> bool {
        let (a, b) = (self.n * other.k, other.n * self.k);
        if a != b {
            return a < b;
        }
        let (ta, tb) = (self.n - self.k, other.n - other.k);
        if ta != tb {
            return ta > tb;
        }
        self.n < other.n
    }

    /// Ordering among best-effort candidates: lowest risk, then least
    /// storage overhead.
    fn lower_risk_than(&self, other: &Candidate) -> bool {
        if self.loss != other.loss {
            return self.loss < other.loss;
        }
        self.n * other.k < other.n * self.k
    }
}

/// Solve for the cheapest (k, n) + placement meeting `target_loss`
/// over the alive, capacity-feasible fleet, rating each container by
/// its effective AFR (catalog blended with scorecard history). Falls
/// back to the lowest-risk feasible placement (flagged via
/// `met_target`) when the target is unreachable — mirroring
/// `select_dynamic`'s best-effort contract.
pub fn select_adaptive(
    infos: &[ContainerInfo],
    scores: &ScoreBoard,
    object_size: u64,
    target_loss: f64,
) -> Result<AdaptiveChoice> {
    // Rate and sort the alive fleet once: effective AFR ascending,
    // ties by id (same determinism contract as select_dynamic).
    let mut rated: Vec<(&ContainerInfo, f64)> = infos
        .iter()
        .filter(|c| c.alive)
        .map(|c| (c, scores.effective_afr(c.id, c.annual_failure_rate)))
        .collect();
    rated.sort_by(|a, b| {
        a.1.partial_cmp(&b.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.id.cmp(&b.0.id))
    });
    if rated.len() < 2 {
        return Err(Error::Placement(format!(
            "adaptive selection: need at least 2 alive containers, have {}",
            rated.len()
        )));
    }

    let mut met: Option<Candidate> = None;
    let mut fallback: Option<Candidate> = None;
    for k in 1..MAX_STRIPE {
        // Same per-chunk sizing the dynamic policy uses for
        // feasibility (ops.rs computes the exact packed length later;
        // the placer re-checks capacity at write time either way).
        let chunk = (object_size / k as u64).max(1);
        let pool: Vec<&(&ContainerInfo, f64)> =
            rated.iter().filter(|(c, _)| c.fs_avail >= chunk).collect();
        let max_n = pool.len().min(MAX_STRIPE);
        if max_n < k + 1 {
            continue;
        }
        let model = FailureModel { afr: pool.iter().map(|(_, afr)| *afr).collect() };
        for n in (k + 1)..=max_n {
            let placement: Vec<usize> = (0..n).collect();
            let loss = model.loss_probability(&placement, n - k);
            let cand = Candidate {
                n,
                k,
                loss,
                containers: pool[..n].iter().map(|(c, _)| c.id).collect(),
            };
            if loss <= target_loss {
                // For fixed k the first qualifying n is the cheapest;
                // wider only adds overhead. Move on to the next k.
                if met.as_ref().map_or(true, |b| cand.preferred_over(b)) {
                    met = Some(cand);
                }
                break;
            }
            if fallback.as_ref().map_or(true, |b| cand.lower_risk_than(b)) {
                fallback = Some(cand);
            }
        }
    }

    let (cand, met_target) = match (met, fallback) {
        (Some(c), _) => (c, true),
        (None, Some(c)) => (c, false),
        (None, None) => {
            return Err(Error::Placement(
                "adaptive selection found no feasible placement".into(),
            ))
        }
    };
    Ok(AdaptiveChoice {
        config: ErasureConfig::new(cand.n, cand.k),
        containers: cand.containers,
        loss_probability: cand.loss,
        target_loss,
        met_target,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::select_dynamic;
    use crate::sim::Site;

    fn info(id: u32, afr: f64) -> ContainerInfo {
        ContainerInfo {
            id,
            name: format!("dc{id}"),
            site: Site::ChameleonTacc,
            alive: true,
            mem_total: 1 << 30,
            mem_avail: 1 << 29,
            fs_total: 1 << 40,
            fs_avail: 1 << 39,
            annual_failure_rate: afr,
        }
    }

    /// Sixteen heterogeneous containers, AFR 1%..25% evenly spread —
    /// the paper's §VI-D scenario widened to a 16-slot fleet.
    fn paper16() -> Vec<ContainerInfo> {
        (0..16)
            .map(|i| info(i, 0.01 + 0.24 * i as f64 / 15.0))
            .collect()
    }

    #[test]
    fn nines_conversion() {
        assert!((nines_to_loss(3.0) - 1e-3).abs() < 1e-15);
        assert!((nines_to_loss(0.0) - 1.0).abs() < 1e-15);
        assert!(nines_to_loss(-1.0) <= 1.0);
    }

    #[test]
    fn meets_target_on_paper_fleet_with_wide_stripe() {
        // Model-verified: the cheapest (k, n) meeting 1e-3 over AFRs
        // 1..25% is (k=5, n=8) — overhead 1.6, loss ≈ 8.3e-4.
        let board = ScoreBoard::memory();
        let c = select_adaptive(&paper16(), &board, 1 << 20, 1e-3).unwrap();
        assert!(c.met_target);
        assert!(c.loss_probability <= 1e-3, "loss {}", c.loss_probability);
        assert_eq!(c.config, ErasureConfig::new(8, 5));
        // Ids equal the reliability order in this fleet.
        assert_eq!(c.containers, vec![0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn strictly_cheaper_than_fixed_k_static_at_same_target() {
        // The deployed static family fixes k and grows parity
        // (select_dynamic). With the paper's default k=7 the cheapest
        // qualifying config on this fleet is (12,7) — stored ratio
        // 12/7 ≈ 1.714. Adaptive finds 8/5 = 1.6: strictly lower
        // total storage at the same durability target.
        let fleet = paper16();
        let board = ScoreBoard::memory();
        let adaptive = select_adaptive(&fleet, &board, 1 << 20, 1e-3).unwrap();
        let chunk = (1u64 << 20) / 7;
        let dynamic = select_dynamic(&fleet, chunk, 7, 1e-3).unwrap();
        assert!(dynamic.loss_probability <= 1e-3);
        assert_eq!(dynamic.config, ErasureConfig::new(12, 7));
        // Exact integer cross-compare of n/k ratios.
        let a = adaptive.config;
        let d = dynamic.config;
        assert!(
            a.n * d.k < d.n * a.k,
            "adaptive {a} not cheaper than static {d}"
        );
    }

    #[test]
    fn wide_stripes_on_reliable_fleet() {
        // Sixteen 1%-AFR containers: the solver stretches to the full
        // stripe width with just two parity chunks — (16,14), stored
        // ratio ≈ 1.14 (model-verified loss ≈ 5.1e-4).
        let fleet: Vec<ContainerInfo> = (0..16).map(|i| info(i, 0.01)).collect();
        let c = select_adaptive(&fleet, &ScoreBoard::memory(), 1 << 20, 1e-3).unwrap();
        assert!(c.met_target);
        assert_eq!(c.config, ErasureConfig::new(16, 14));
    }

    #[test]
    fn extra_parity_on_flaky_fleet() {
        // Ten 25%-AFR containers: seven parity chunks needed — (10,3),
        // model-verified loss ≈ 4.2e-4.
        let fleet: Vec<ContainerInfo> = (0..10).map(|i| info(i, 0.25)).collect();
        let c = select_adaptive(&fleet, &ScoreBoard::memory(), 1 << 20, 1e-3).unwrap();
        assert!(c.met_target);
        assert_eq!(c.config, ErasureConfig::new(10, 3));
        assert_eq!(c.config.failures_tolerated(), 7);
    }

    #[test]
    fn best_effort_when_target_unreachable() {
        let fleet = vec![info(0, 0.25), info(1, 0.25)];
        let c = select_adaptive(&fleet, &ScoreBoard::memory(), 1024, 1e-9).unwrap();
        assert!(!c.met_target);
        assert_eq!(c.config, ErasureConfig::new(2, 1));
        assert!(c.loss_probability > 1e-9);
    }

    #[test]
    fn observed_failures_evict_catalog_favorite() {
        // Container 0 has the best *catalog* AFR but fails every chunk
        // op in practice; the scorecard prices it out of the stripe.
        let fleet = paper16();
        let board = ScoreBoard::memory();
        for _ in 0..1000 {
            board.observe_io(0, false, 0, 0.050);
        }
        let c = select_adaptive(&fleet, &board, 1 << 20, 1e-3).unwrap();
        assert!(c.met_target);
        assert!(!c.containers.contains(&0), "flaky container kept: {:?}", c.containers);
    }

    #[test]
    fn capacity_infeasible_containers_skipped() {
        // Model-verified: on the 8 remaining feasible containers the
        // cheapest qualifying config is (5,4) — loss ≈ 9.8e-4.
        let mut fleet: Vec<ContainerInfo> = (0..16).map(|i| info(i, 0.01)).collect();
        for c in fleet.iter_mut().take(8) {
            c.fs_avail = 1024; // too small for any chunk of a 1 MiB object
        }
        let c = select_adaptive(&fleet, &ScoreBoard::memory(), 1 << 20, 1e-3).unwrap();
        assert!(c.met_target);
        assert!(c.containers.iter().all(|id| *id >= 8), "{:?}", c.containers);
        assert_eq!(c.config, ErasureConfig::new(5, 4));
    }

    #[test]
    fn dead_fleet_is_an_error() {
        let mut fleet = paper16();
        for c in fleet.iter_mut() {
            c.alive = false;
        }
        assert!(select_adaptive(&fleet, &ScoreBoard::memory(), 1024, 1e-3).is_err());
    }
}
