//! REST gateway (paper §III-B): the entry point for client requests.
//! Validates OAuth-style bearer tokens and routes to the coordinator.
//!
//! Routes:
//! * `POST /auth/register`  body `{"user": ...}` → `{"token": ...}`
//!   (409 when the user already exists)
//! * `POST /auth/login`     body `{"user": ...}` → `{"token": ...}`
//! * the versioned **`/v1` object surface** — see [`v1`] for the route
//!   table: `GET/PUT/HEAD/DELETE /v1/objects/...` with `?version=`
//!   pinning, `If-None-Match`/`Range` support and metadata headers,
//!   `GET /v1/collections/...` pagination, `PUT/DELETE /v1/grants/...`
//! * `/objects/<collection...>/<name>` — deprecated alias for
//!   `/v1/objects/...`, same handlers, raw (undecoded) path segments
//!   with no query parsing (legal names may contain `?`), responses
//!   tagged `x-dyno-deprecated`
//! * `GET  /metrics` → counters JSON
//! * `POST /admin/repair`, `POST /admin/gc`
//! * `POST /admin/rebalance` body `{"threshold": .., "max_moves": ..}`
//! * `POST /admin/decommission/<id>` → drain + remove a container
//! * `POST /admin/undrain/<id>` → cancel a stopped drain
//! * `POST /admin/scrub` body `{"sample": n}` → one anti-entropy sweep
//! * `GET  /health` → liveness + container census + imbalance gauge +
//!   per-container circuit-breaker states + retry/shed counters +
//!   streaming gauges (`bytes_in`/`bytes_out`/`streams_active`/
//!   `multipart_open`) + durability state (`wal_len`, `last_snapshot`,
//!   `recovered`)
//!
//! Resilience semantics: requests may carry `x-dyno-deadline-ms`; an
//! exhausted budget answers `504` and an open circuit breaker / missing
//! capacity answers `503`, both with `Retry-After`.
//!
//! Every `/admin/*` route requires a valid bearer token with the
//! `admin` scope (401 without/with a bad token, 403 without the scope;
//! operator tokens come from [`DynoStore::issue_admin_token`]).

mod v1;

use std::sync::Arc;

use crate::coordinator::{DynoStore, RebalanceOpts};
use crate::json::{obj, parse, Value};
use crate::net::{
    client_pool, BodyReader, HttpRequest, HttpResponse, HttpServer, NetStats, ServerEngine,
    ServerOptions,
};
use crate::util::unix_secs;
use crate::{Error, Result};

/// Largest request body the gateway accepts by default: 1 GiB. Object
/// pushes arrive as one body, so this bounds object size; deployments
/// storing bigger objects raise it via [`serve_with_limit`] /
/// `Config::max_body_mb` / `dynostore serve --max-body-mb`.
pub const DEFAULT_GATEWAY_MAX_BODY: usize = 1 << 30;

/// Start the gateway HTTP service on `addr` with `workers` threads and
/// the [`DEFAULT_GATEWAY_MAX_BODY`] request-body cap.
pub fn serve(store: Arc<DynoStore>, addr: &str, workers: usize) -> Result<HttpServer> {
    serve_with_limit(store, addr, workers, DEFAULT_GATEWAY_MAX_BODY)
}

/// [`serve`] with an explicit request-body cap: requests declaring a
/// larger `content-length` get `413 Payload Too Large` without the
/// gateway allocating for them.
pub fn serve_with_limit(
    store: Arc<DynoStore>,
    addr: &str,
    workers: usize,
    max_body: usize,
) -> Result<HttpServer> {
    serve_with_limits(
        store,
        addr,
        workers,
        crate::net::ServerLimits { max_body, ..Default::default() },
    )
}

/// Streaming-ingest part size when the deployment doesn't configure
/// one: 8 MiB. Each part is independently erasure-coded and placed as
/// its bytes arrive, so gateway memory per upload stays around
/// `part_size × pipeline depth (2)` regardless of object size.
pub const DEFAULT_STREAM_PART_SIZE: usize = 8 << 20;

/// [`serve`] with full transport limits: the request-body cap plus the
/// per-connection socket timeout that shields the worker pool from
/// slow/hung clients (`Config::conn_timeout_secs`).
pub fn serve_with_limits(
    store: Arc<DynoStore>,
    addr: &str,
    workers: usize,
    limits: crate::net::ServerLimits,
) -> Result<HttpServer> {
    serve_with_options(store, addr, workers, limits, DEFAULT_STREAM_PART_SIZE)
}

/// [`serve_with_limits`] with an explicit streaming part size
/// (`Config::part_size_mb` / `dynostore serve --part-size-mb`). The
/// gateway runs in the transport's streaming mode: object PUT bodies
/// are erasure-encoded per part as they arrive and striped GETs are
/// written to the socket one part at a time, so peak memory is bounded
/// by the part size, not object size. The body cap still applies to
/// every single request — multipart uploads are how objects larger
/// than the cap get in.
pub fn serve_with_options(
    store: Arc<DynoStore>,
    addr: &str,
    workers: usize,
    limits: crate::net::ServerLimits,
    part_size: usize,
) -> Result<HttpServer> {
    serve_with_net(store, addr, workers, limits, part_size, ServerOptions::default())
}

/// Connection-plane view threaded into the request handlers so
/// `/metrics` and `/health` can report the engine's counters.
#[derive(Clone)]
struct NetView {
    stats: Arc<NetStats>,
    engine: ServerEngine,
}

/// [`serve_with_options`] plus the connection-core knobs
/// (`Config::net` / `dynostore serve --net-engine …`): which engine
/// serves the sockets, the connection/in-flight admission caps, and the
/// keep-alive idle window. The gateway shares the engine's [`NetStats`]
/// so `/metrics` and `/health` expose `conns_open`, `conns_accepted`,
/// `keepalive_reuses`, `admission_shed`, and the reactor lag gauge.
pub fn serve_with_net(
    store: Arc<DynoStore>,
    addr: &str,
    workers: usize,
    limits: crate::net::ServerLimits,
    part_size: usize,
    mut net: ServerOptions,
) -> Result<HttpServer> {
    let stats = net
        .stats
        .get_or_insert_with(|| Arc::new(NetStats::default()))
        .clone();
    let view = NetView { stats, engine: net.engine.resolved() };
    let max_body = limits.max_body;
    let handler = move |req: HttpRequest, body: &mut BodyReader| {
        stream_route(&store, req, body, max_body, part_size, &view)
    };
    HttpServer::serve_stream_with_options(addr, workers, Arc::new(handler), limits, net)
}

/// Streaming-mode entry: plain object PUTs hand the incremental body
/// reader straight to the coordinator's pipelined push; every other
/// route buffers its body under the cap and runs the buffered router
/// unchanged (multipart part PUTs included — one part is one erasure
/// unit and must be whole before it can be encoded).
fn stream_route(
    store: &Arc<DynoStore>,
    req: HttpRequest,
    body: &mut BodyReader,
    max_body: usize,
    part_size: usize,
    net: &NetView,
) -> HttpResponse {
    if v1::is_streaming_put(&req) {
        return match v1::object_put_stream(store, &req, body, part_size) {
            Ok(resp) => resp,
            Err(e) => error_response(store, e),
        };
    }
    match body.read_to_end_cap(max_body) {
        Ok(bytes) => {
            let mut req = req;
            req.body = bytes;
            route(store, req, net)
        }
        Err(e) => error_response(store, e),
    }
}

fn route(store: &Arc<DynoStore>, req: HttpRequest, net: &NetView) -> HttpResponse {
    // Query strings ride on the request target; strip them before
    // matching so `/v1/...?version=2` routes like `/v1/...`. Only `/v1`
    // targets are split: pre-v1 routes never defined query parameters
    // and legal object names may contain `?` — the deprecated alias
    // must keep matching the raw bytes old clients send.
    let (path, query) = if req.path.starts_with("/v1/") {
        v1::split_query(&req.path)
    } else {
        (req.path.as_str(), Vec::new())
    };
    let result = match (req.method.as_str(), path) {
        ("POST", "/auth/register") => auth_register(store, &req),
        ("POST", "/auth/login") => auth_login(store, &req),
        ("GET", "/metrics") => Ok(metrics(store, net)),
        ("GET", "/health") => Ok(health(store, net)),
        ("POST", "/admin/repair") => admin_repair(store, &req),
        ("POST", "/admin/gc") => admin_gc(store, &req),
        ("POST", "/admin/rebalance") => admin_rebalance(store, &req),
        ("POST", path) if path.starts_with("/admin/decommission/") => {
            admin_decommission(store, &req)
        }
        ("POST", path) if path.starts_with("/admin/undrain/") => admin_undrain(store, &req),
        ("POST", "/admin/scrub") => admin_scrub(store, &req),
        ("POST", "/admin/tier-cycle") => admin_tier_cycle(store, &req),
        (method, path) if path.starts_with("/v1/objects/") => {
            v1::object_route(store, method, &req, path, &query, false)
        }
        (method, path) if path.starts_with("/v1/collections/") => {
            v1::collection_route(store, method, &req, path, &query)
        }
        (method, path) if path.starts_with("/v1/grants/") => {
            v1::grant_route(store, method, &req, path)
        }
        // Deprecated alias: the pre-/v1 object routes, served by the
        // same handlers (raw path segments, `x-dyno-deprecated` tag).
        (method, path) if path.starts_with("/objects/") => {
            v1::object_route(store, method, &req, path, &query, true)
        }
        _ => Err(Error::NotFound(format!("{} {}", req.method, req.path))),
    };
    match result {
        Ok(resp) => resp,
        Err(e) => error_response(store, e),
    }
}

fn error_response(store: &Arc<DynoStore>, e: Error) -> HttpResponse {
    // An over-cap body is 413 whichever layer noticed it: the buffered
    // read, or the streaming push mid-body on a chunked upload (sized
    // over-cap bodies are refused by the transport before any handler).
    let status = if crate::net::is_over_cap(&e) {
        413
    } else {
        match &e {
            Error::Auth(_) => 401,
            Error::PermissionDenied(_) => 403,
            Error::NotFound(_) => 404,
            Error::Conflict(_) => 409,
            Error::Invalid(_) | Error::Json(_) | Error::Config(_) => 400,
            Error::Timeout(_) => 504,
            Error::Unavailable(_) | Error::Consensus(_) => 503,
            _ => 500,
        }
    };
    let mut resp =
        HttpResponse::json(status, &obj(vec![("error", e.to_string().as_str().into())]));
    // Load-shed (breaker open, no capacity) and deadline exhaustion are
    // both retryable conditions: tell the client when, count them so
    // operators see shedding in /metrics and /health.
    match status {
        503 => {
            store.metrics.sheds.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            resp.headers.insert("retry-after".into(), "1".into());
        }
        504 => {
            store
                .metrics
                .deadline_timeouts
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            resp.headers.insert("retry-after".into(), "1".into());
        }
        _ => {}
    }
    resp
}

fn parse_user(req: &HttpRequest) -> Result<String> {
    let body = std::str::from_utf8(&req.body)
        .map_err(|_| Error::Invalid("body not utf-8".into()))?;
    Ok(parse(body)?.req_str("user")?.to_string())
}

fn auth_register(store: &Arc<DynoStore>, req: &HttpRequest) -> Result<HttpResponse> {
    let user = parse_user(req)?;
    let token = store.register_user(&user)?;
    Ok(HttpResponse::json(201, &obj(vec![("token", token.as_str().into())])))
}

fn auth_login(store: &Arc<DynoStore>, req: &HttpRequest) -> Result<HttpResponse> {
    let user = parse_user(req)?;
    Ok(HttpResponse::json(
        200,
        &obj(vec![("token", store.login(&user).as_str().into())]),
    ))
}

fn metrics(store: &Arc<DynoStore>, net: &NetView) -> HttpResponse {
    let snap = store.metrics.snapshot();
    let mut fields: Vec<(&str, Value)> =
        snap.iter().map(|(k, v)| (*k, Value::from(*v))).collect();
    // Live gauge rather than a counter: open uploads are replicated
    // metadata, so the value is correct across restarts too.
    fields.push(("multipart_open", store.open_upload_count().into()));
    // Metadata-plane sharding: how many Paxos groups, and how many
    // commands each sequenced since this process started (the skew
    // across shards is the ring-balance signal).
    fields.push(("meta_shards", (store.meta.shard_count() as u64).into()));
    let shard_keys: Vec<String> =
        (0..store.meta.shard_count()).map(|i| format!("meta_commits_shard{i}")).collect();
    for (i, key) in shard_keys.iter().enumerate() {
        fields.push((key.as_str(), store.meta.shard_commits(i).into()));
    }
    // Upload-/uuid-keyed command routing: O(1) index hits vs per-shard
    // scan fallbacks (misses), and how many keys the index tracks.
    let (ri_hits, ri_misses, ri_len) = store.meta.route_index_stats();
    fields.push(("route_index_hits", ri_hits.into()));
    fields.push(("route_index_misses", ri_misses.into()));
    fields.push(("route_index_keys", (ri_len as u64).into()));
    // Storage-tier census: containers per declared tier (gauges; the
    // promotion/demotion counters are in the snapshot above).
    let infos = store.registry.infos();
    let tier_keys: Vec<(String, u64)> = store
        .tiering
        .tier_counts(&infos)
        .into_iter()
        .map(|(t, n)| (format!("tier_{}_containers", t.as_str()), n as u64))
        .collect();
    for (key, n) in &tier_keys {
        fields.push((key.as_str(), (*n).into()));
    }
    // Connection-plane counters from the serving engine (flat keys:
    // conns_open, conns_accepted, keepalive_reuses, admission_shed,
    // reactor_lag_us — gauges and counters per NetStats docs).
    for (k, v) in net.stats.snapshot() {
        fields.push((k, v.into()));
    }
    HttpResponse::json(200, &obj(fields))
}

fn health(store: &Arc<DynoStore>, net: &NetView) -> HttpResponse {
    let infos = store.registry.infos();
    let live = infos.iter().filter(|i| i.alive).count();
    let census: Vec<(&str, Value)> = store
        .registry
        .transport_census()
        .into_iter()
        .map(|(t, n)| (t, Value::from(n)))
        .collect();
    let durability = if store.meta.is_durable() {
        // Backward-compatible aggregates (wal_len summed, last_snapshot
        // the oldest shard, recovered OR-ed) plus the per-shard
        // breakdown: one entry per metadata Paxos group, index == shard
        // id, so an operator can see which shard degraded.
        let shard_reports = store.recovery_shard_reports().unwrap_or(&[]);
        let shards: Vec<Value> = (0..store.meta.shard_count())
            .map(|i| {
                obj(vec![
                    ("shard", (i as u64).into()),
                    ("wal_len", store.meta.shard(i).wal_len().into()),
                    ("last_snapshot", store.meta.shard(i).last_snapshot_unix().into()),
                    ("committed_seq", store.meta.shard(i).committed_seq().into()),
                    ("commits", store.meta.shard_commits(i).into()),
                    (
                        "recovered",
                        shard_reports.get(i).map(|r| r.recovered()).unwrap_or(false).into(),
                    ),
                ])
            })
            .collect();
        obj(vec![
            ("enabled", true.into()),
            ("wal_len", store.meta.wal_len().into()),
            ("last_snapshot", store.meta.last_snapshot_unix().into()),
            (
                "recovered",
                store
                    .recovery_report()
                    .map(|r| r.recovered())
                    .unwrap_or(false)
                    .into(),
            ),
            ("meta_shards", (store.meta.shard_count() as u64).into()),
            ("shards", Value::Arr(shards)),
        ])
    } else {
        obj(vec![("enabled", false.into())])
    };
    // Per-container circuit-breaker view: which agents the gateway is
    // currently shedding traffic from, and why /metrics shows sheds.
    let mut channels = store.registry.all();
    channels.sort_by_key(|c| c.id());
    let breakers: Vec<Value> = channels
        .iter()
        .map(|c| {
            obj(vec![
                ("id", u64::from(c.id()).into()),
                ("name", c.name().into()),
                ("state", c.breaker_state().into()),
            ])
        })
        .collect();
    let snap = store.metrics.snapshot();
    let resilience = obj(vec![
        ("retries", snap["retries"].into()),
        ("sheds", snap["sheds"].into()),
        ("deadline_timeouts", snap["deadline_timeouts"].into()),
        ("scrub_cycles", snap["scrub_cycles"].into()),
        ("scrub_chunks_healed", snap["scrub_chunks_healed"].into()),
    ]);
    // Data-plane streaming view: wire traffic, in-flight streams, and
    // uploads opened but not yet completed/aborted.
    let streaming = obj(vec![
        ("bytes_in", snap["bytes_in"].into()),
        ("bytes_out", snap["bytes_out"].into()),
        ("streams_active", snap["streams_active"].into()),
        ("multipart_open", store.open_upload_count().into()),
    ]);
    // Connection-plane view: which engine serves the sockets, how many
    // connections are open/reused/shed, and the reactor lag gauge.
    let mut net_fields: Vec<(&str, Value)> =
        vec![("engine", net.engine.as_str().into())];
    for (k, v) in net.stats.snapshot() {
        net_fields.push((k, v.into()));
    }
    // Outbound keep-alive pool (coordinator→agent fan-out reuse).
    let pool = client_pool();
    let mut pool_fields: Vec<(&str, Value)> =
        vec![("idle", (pool.idle_count() as u64).into())];
    for (k, v) in pool.stats.snapshot() {
        pool_fields.push((k, v.into()));
    }
    HttpResponse::json(
        200,
        &obj(vec![
            ("status", if live > 0 { "ok" } else { "degraded" }.into()),
            ("containers", infos.len().into()),
            ("live", live.into()),
            ("draining", store.registry.draining_ids().len().into()),
            ("imbalance", store.utilization_spread().into()),
            ("engine", store.engine().as_str().into()),
            ("backend", store.backend_name().into()),
            ("transports", obj(census)),
            ("breakers", Value::Arr(breakers)),
            ("resilience", resilience),
            ("streaming", streaming),
            ("net", obj(net_fields)),
            ("client_pool", obj(pool_fields)),
            ("durability", durability),
            // The D-Rex view: per-container scorecards (observed error/
            // latency/bandwidth/availability EWMAs) and the declared
            // storage tier of every container.
            ("scorecards", store.tiering.scores.to_json()),
            ("tiers", store.tiering.tiers_json(&infos)),
        ]),
    )
}

/// Admin gate (satellite bugfix: these endpoints used to accept
/// unauthenticated requests): a valid bearer token with the `admin`
/// scope is required on every `/admin/*` route. Ordinary
/// `register`/`login` tokens carry only `read`/`write` and get 403;
/// operator tokens come from [`DynoStore::issue_admin_token`] (printed
/// by `dynostore serve` at startup).
fn admin_auth(store: &Arc<DynoStore>, req: &HttpRequest) -> Result<()> {
    let token = req
        .bearer_token()
        .ok_or_else(|| Error::Auth("admin endpoints require a bearer token".into()))?;
    let claims = store.tokens.validate(token).map_err(|e| {
        store
            .metrics
            .auth_failures
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        e
    })?;
    if !claims.has_scope("admin") {
        return Err(Error::PermissionDenied(
            "admin operations require the admin scope".into(),
        ));
    }
    Ok(())
}

fn admin_repair(store: &Arc<DynoStore>, req: &HttpRequest) -> Result<HttpResponse> {
    admin_auth(store, req)?;
    let r = store.repair()?;
    Ok(HttpResponse::json(
        200,
        &obj(vec![
            ("scanned", r.scanned.into()),
            ("repaired", r.repaired.into()),
            ("lost", r.lost.into()),
            ("chunks_moved", r.chunks_moved.into()),
        ]),
    ))
}

fn admin_rebalance(store: &Arc<DynoStore>, req: &HttpRequest) -> Result<HttpResponse> {
    admin_auth(store, req)?;
    let defaults = RebalanceOpts::default();
    let opts = if req.body.is_empty() {
        defaults
    } else {
        let body = std::str::from_utf8(&req.body)
            .map_err(|_| Error::Invalid("body not utf-8".into()))?;
        let v = parse(body)?;
        RebalanceOpts {
            threshold: v.opt_f64("threshold", defaults.threshold),
            max_moves: v.opt_u64("max_moves", defaults.max_moves as u64) as usize,
            batch_moves: v.opt_u64("batch_moves", defaults.batch_moves as u64) as usize,
        }
    };
    let r = store.rebalance(opts)?;
    Ok(HttpResponse::json(
        200,
        &obj(vec![
            ("spread_before", r.spread_before.into()),
            ("spread_after", r.spread_after.into()),
            ("threshold", r.threshold.into()),
            ("batches", r.batches.into()),
            ("chunks_moved", r.chunks_moved.into()),
            ("failed_moves", r.failed_moves.into()),
            ("converged", Value::Bool(r.converged)),
        ]),
    ))
}

fn admin_decommission(store: &Arc<DynoStore>, req: &HttpRequest) -> Result<HttpResponse> {
    admin_auth(store, req)?;
    let id: u32 = req
        .path
        .strip_prefix("/admin/decommission/")
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Invalid(format!("bad decommission path '{}'", req.path)))?;
    let r = store.decommission(id)?;
    Ok(HttpResponse::json(
        200,
        &obj(vec![
            ("container", u64::from(r.container).into()),
            ("objects_scanned", r.objects_scanned.into()),
            ("chunks_moved", r.chunks_moved.into()),
            ("reconstructed", r.reconstructed.into()),
            ("failed_moves", r.failed_moves.into()),
            ("removed", Value::Bool(r.removed)),
        ]),
    ))
}

fn admin_undrain(store: &Arc<DynoStore>, req: &HttpRequest) -> Result<HttpResponse> {
    admin_auth(store, req)?;
    let id: u32 = req
        .path
        .strip_prefix("/admin/undrain/")
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Invalid(format!("bad undrain path '{}'", req.path)))?;
    store.cancel_decommission(id)?;
    Ok(HttpResponse::json(
        200,
        &obj(vec![("container", u64::from(id).into()), ("draining", Value::Bool(false))]),
    ))
}

fn admin_scrub(store: &Arc<DynoStore>, req: &HttpRequest) -> Result<HttpResponse> {
    admin_auth(store, req)?;
    let sample = if req.body.is_empty() {
        crate::coordinator::DEFAULT_SCRUB_SAMPLE
    } else {
        let body = std::str::from_utf8(&req.body)
            .map_err(|_| Error::Invalid("body not utf-8".into()))?;
        parse(body)?.opt_u64("sample", crate::coordinator::DEFAULT_SCRUB_SAMPLE as u64)
            as usize
    };
    let r = store.scrub_cycle(sample)?;
    Ok(HttpResponse::json(
        200,
        &obj(vec![
            ("scanned", r.scanned.into()),
            ("chunks_verified", r.chunks_verified.into()),
            ("corrupt_found", r.corrupt_found.into()),
            ("unreachable", r.unreachable.into()),
            ("chunks_healed", r.chunks_healed.into()),
            ("lost", r.lost.into()),
            ("wrapped", Value::Bool(r.wrapped)),
        ]),
    ))
}

fn admin_tier_cycle(store: &Arc<DynoStore>, req: &HttpRequest) -> Result<HttpResponse> {
    admin_auth(store, req)?;
    let defaults = crate::tiering::TierCycleOpts::default();
    let opts = if req.body.is_empty() {
        defaults
    } else {
        let body = std::str::from_utf8(&req.body)
            .map_err(|_| Error::Invalid("body not utf-8".into()))?;
        let v = parse(body)?;
        crate::tiering::TierCycleOpts {
            hot_rate: v.opt_f64("hot_rate", defaults.hot_rate),
            cold_after_secs: v.opt_u64("cold_after_secs", defaults.cold_after_secs),
            max_objects: v.opt_u64("max_objects", defaults.max_objects as u64) as usize,
            max_moves: v.opt_u64("max_moves", defaults.max_moves as u64) as usize,
        }
    };
    let r = store.tier_cycle(opts)?;
    Ok(HttpResponse::json(
        200,
        &obj(vec![
            ("examined", r.examined.into()),
            ("promoted", r.promoted.into()),
            ("demoted", r.demoted.into()),
            ("chunks_moved", r.chunks_moved.into()),
            ("failed", r.failed.into()),
            ("skipped", r.skipped.into()),
        ]),
    ))
}

fn admin_gc(store: &Arc<DynoStore>, req: &HttpRequest) -> Result<HttpResponse> {
    admin_auth(store, req)?;
    let retention = if req.body.is_empty() {
        crate::metadata::DEFAULT_RETENTION_SECS
    } else {
        let body = std::str::from_utf8(&req.body)
            .map_err(|_| Error::Invalid("body not utf-8".into()))?;
        parse(body)?.opt_u64("retention_secs", crate::metadata::DEFAULT_RETENTION_SECS)
    };
    let collected = store.gc(unix_secs(), retention)?;
    Ok(HttpResponse::json(200, &obj(vec![("collected", collected.into())])))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::deploy_containers;
    use crate::net::HttpClient;
    use crate::testkit::uniform_specs;

    /// (server, client, operator `Authorization` header for /admin/*).
    fn gateway() -> (HttpServer, HttpClient, String) {
        gateway_with_engine(crate::coordinator::GfEngine::PureRust)
    }

    fn gateway_with_engine(
        engine: crate::coordinator::GfEngine,
    ) -> (HttpServer, HttpClient, String) {
        let ds = Arc::new(DynoStore::builder().engine(engine).build());
        for c in deploy_containers(&uniform_specs("dc", 12, 256 << 20, 1 << 40), 12, 0)
            .containers
        {
            ds.add_container(c).unwrap();
        }
        let admin = format!("Bearer {}", ds.issue_admin_token(3600));
        let server = serve(ds, "127.0.0.1:0", 4).unwrap();
        let client = HttpClient::new(&server.addr().to_string());
        (server, client, admin)
    }

    fn register(client: &HttpClient, user: &str) -> String {
        let resp = client
            .post("/auth/register", &[], format!("{{\"user\": \"{user}\"}}").as_bytes())
            .unwrap();
        assert_eq!(resp.status, 201, "{:?}", String::from_utf8_lossy(&resp.body));
        parse(std::str::from_utf8(&resp.body).unwrap())
            .unwrap()
            .req_str("token")
            .unwrap()
            .to_string()
    }

    #[test]
    fn rest_object_lifecycle() {
        let (_server, client, _admin) = gateway();
        let token = register(&client, "UserA");
        let auth = format!("Bearer {token}");
        let payload: Vec<u8> = (0..50_000u32).map(|i| (i % 256) as u8).collect();

        // PUT
        let resp = client
            .put("/objects/UserA/scan1", &[("authorization", &auth)], &payload)
            .unwrap();
        assert_eq!(resp.status, 201);

        // HEAD
        let head =
            client.request("HEAD", "/objects/UserA/scan1", &[("authorization", &auth)], &[]);
        assert_eq!(head.unwrap().status, 200);

        // GET returns the exact bytes.
        let got = client.get("/objects/UserA/scan1", &[("authorization", &auth)]).unwrap();
        assert_eq!(got.status, 200);
        assert_eq!(got.body, payload);

        // DELETE then 404.
        let del =
            client.delete("/objects/UserA/scan1", &[("authorization", &auth)]).unwrap();
        assert_eq!(del.status, 200);
        let gone = client.get("/objects/UserA/scan1", &[("authorization", &auth)]).unwrap();
        assert_eq!(gone.status, 404);
    }

    #[test]
    fn auth_rejected_without_token() {
        let (_server, client, _admin) = gateway();
        let resp = client.get("/objects/UserA/x", &[]).unwrap();
        assert_eq!(resp.status, 401);
        let resp =
            client.get("/objects/UserA/x", &[("authorization", "Bearer junk")]).unwrap();
        assert_eq!(resp.status, 401);
    }

    #[test]
    fn permission_denied_is_403() {
        let (_server, client, _admin) = gateway();
        let token_a = register(&client, "UserA");
        let token_b = register(&client, "UserB");
        let auth_a = format!("Bearer {token_a}");
        let auth_b = format!("Bearer {token_b}");
        client.put("/objects/UserA/secret", &[("authorization", &auth_a)], b"x").unwrap();
        let resp =
            client.get("/objects/UserA/secret", &[("authorization", &auth_b)]).unwrap();
        assert_eq!(resp.status, 403);
    }

    #[test]
    fn metrics_health_admin_endpoints() {
        let (_server, client, admin) = gateway();
        let token = register(&client, "UserA");
        let auth = format!("Bearer {token}");
        client.put("/objects/UserA/o", &[("authorization", &auth)], b"data").unwrap();

        let m = client.get("/metrics", &[]).unwrap();
        assert_eq!(m.status, 200);
        let v = parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        assert_eq!(v.req_u64("pushes").unwrap(), 1);
        // Metadata-plane sharding counters: one group by default, and
        // its commit counter saw the register + push commands.
        assert_eq!(v.req_u64("meta_shards").unwrap(), 1);
        assert!(v.req_u64("meta_commits_shard0").unwrap() >= 2);

        let h = client.get("/health", &[]).unwrap();
        let v = parse(std::str::from_utf8(&h.body).unwrap()).unwrap();
        assert_eq!(v.req_str("status").unwrap(), "ok");
        assert_eq!(v.req_u64("containers").unwrap(), 12);
        assert_eq!(v.req_u64("draining").unwrap(), 0);
        assert!(v.get("imbalance").as_f64().is_some(), "imbalance gauge present");
        assert_eq!(v.req_str("engine").unwrap(), "pure-rust");
        assert_eq!(v.req_str("backend").unwrap(), "pure-rust");
        assert_eq!(v.get("transports").req_u64("local").unwrap(), 12);
        // In-memory gateway: durability reports disabled, nothing else.
        assert_eq!(v.get("durability").get("enabled").as_bool(), Some(false));
        assert_eq!(v.get("durability").get("wal_len"), &Value::Null);

        let r = client.post("/admin/repair", &[("authorization", &admin)], &[]).unwrap();
        assert_eq!(r.status, 200);
        let g = client
            .post("/admin/gc", &[("authorization", &admin)], b"{\"retention_secs\": 0}")
            .unwrap();
        assert_eq!(g.status, 200);
    }

    #[test]
    fn tiering_surfaces_on_gateway() {
        let (_server, client, admin) = gateway();
        let token = register(&client, "UserA");
        let auth = format!("Bearer {token}");
        client.put("/objects/UserA/hot", &[("authorization", &auth)], b"abc").unwrap();
        client.get("/objects/UserA/hot", &[("authorization", &auth)]).unwrap();

        // /metrics carries the route-index counters (single shard: bypassed,
        // so all zero) and per-tier container gauges (all default Fs here).
        let m = client.get("/metrics", &[]).unwrap();
        let v = parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        assert_eq!(v.req_u64("route_index_hits").unwrap(), 0);
        assert_eq!(v.req_u64("route_index_misses").unwrap(), 0);
        assert_eq!(v.req_u64("route_index_keys").unwrap(), 0);
        assert_eq!(v.req_u64("tier_fs_containers").unwrap(), 12);

        // /health exposes the scorecards (fed by the push/pull chunk I/O
        // above) and the per-container tier map.
        let h = client.get("/health", &[]).unwrap();
        let v = parse(std::str::from_utf8(&h.body).unwrap()).unwrap();
        let cards = v.get("scorecards").as_arr().expect("scorecards array");
        assert!(!cards.is_empty(), "chunk I/O fed scorecards");
        assert!(cards.iter().all(|c| c.req_u64("ops").unwrap() >= 1));
        let tiers = v.get("tiers").as_arr().expect("tiers array");
        assert_eq!(tiers.len(), 12);
        assert!(tiers.iter().all(|t| t.req_str("tier").unwrap() == "fs"));

        // Admin tier-cycle runs (and reports a skip: no cache tiers declared).
        let r = client
            .post("/admin/tier-cycle", &[("authorization", &admin)], &[])
            .unwrap();
        assert_eq!(r.status, 200, "{:?}", String::from_utf8_lossy(&r.body));
        let v = parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(v.req_u64("promoted").unwrap(), 0);
        assert_eq!(v.req_u64("chunks_moved").unwrap(), 0);
    }

    #[test]
    fn net_telemetry_in_metrics_and_health() {
        let (server, client, _admin) = gateway();
        // At least this very request was accepted by the engine.
        let m = client.get("/metrics", &[]).unwrap();
        let v = parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        assert!(v.req_u64("conns_accepted").unwrap() >= 1);
        assert!(v.get("conns_open").as_u64().is_some());
        assert!(v.get("keepalive_reuses").as_u64().is_some());
        assert!(v.get("admission_shed").as_u64().is_some());
        assert!(v.get("reactor_lag_us").as_u64().is_some());

        let h = client.get("/health", &[]).unwrap();
        let v = parse(std::str::from_utf8(&h.body).unwrap()).unwrap();
        let net = v.get("net");
        assert_eq!(net.req_str("engine").unwrap(), server.engine().as_str());
        assert!(net.req_u64("conns_accepted").unwrap() >= 1);
        let pool = v.get("client_pool");
        assert!(pool.get("idle").as_u64().is_some());
        assert!(pool.get("reuses").as_u64().is_some());
        assert!(pool.get("stale_retries").as_u64().is_some());
    }

    #[test]
    fn admin_endpoints_require_authentication() {
        let (_server, client, _admin) = gateway();
        // Every /admin/* route rejects missing and invalid tokens.
        for (path, body) in [
            ("/admin/repair", &b""[..]),
            ("/admin/gc", &b""[..]),
            ("/admin/rebalance", &b""[..]),
            ("/admin/decommission/0", &b""[..]),
            ("/admin/undrain/0", &b""[..]),
            ("/admin/scrub", &b""[..]),
            ("/admin/tier-cycle", &b""[..]),
        ] {
            let resp = client.post(path, &[], body).unwrap();
            assert_eq!(resp.status, 401, "unauthenticated {path}");
            let resp =
                client.post(path, &[("authorization", "Bearer junk")], body).unwrap();
            assert_eq!(resp.status, 401, "garbage token {path}");
        }
    }

    #[test]
    fn admin_endpoints_reject_tokens_without_admin_scope() {
        // An ordinary self-registered user's token carries read+write
        // but NOT admin: it must not authorize admin operations.
        let (_server, client, _admin) = gateway();
        let user_token = register(&client, "Ordinary");
        let auth = format!("Bearer {user_token}");
        for path in [
            "/admin/repair",
            "/admin/gc",
            "/admin/rebalance",
            "/admin/decommission/0",
            "/admin/scrub",
            "/admin/tier-cycle",
        ] {
            let resp = client.post(path, &[("authorization", &auth)], &[]).unwrap();
            assert_eq!(resp.status, 403, "user token must not admin {path}");
        }
    }

    #[test]
    fn rest_decommission_and_rebalance() {
        let (_server, client, admin) = gateway();
        let token = register(&client, "UserA");
        let auth = format!("Bearer {token}");
        let payload: Vec<u8> = (0..40_000u32).map(|i| (i % 251) as u8).collect();
        let put = client
            .put("/objects/UserA/obj", &[("authorization", &auth)], &payload)
            .unwrap();
        assert_eq!(put.status, 201);

        // Drain container 0 (12 containers, n = 10: spares exist).
        let resp = client
            .post("/admin/decommission/0", &[("authorization", &admin)], &[])
            .unwrap();
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let v = parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert!(v.get("removed").as_bool().unwrap_or(false), "drain completed");

        let h = client.get("/health", &[]).unwrap();
        let v = parse(std::str::from_utf8(&h.body).unwrap()).unwrap();
        assert_eq!(v.req_u64("containers").unwrap(), 11);

        // Rebalance with a generous threshold converges immediately.
        let resp = client
            .post(
                "/admin/rebalance",
                &[("authorization", &admin)],
                b"{\"threshold\": 0.9}",
            )
            .unwrap();
        assert_eq!(resp.status, 200);
        let v = parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert!(v.get("converged").as_bool().unwrap_or(false));

        // The object survived the drain bit-identically.
        let got = client.get("/objects/UserA/obj", &[("authorization", &auth)]).unwrap();
        assert_eq!(got.status, 200);
        assert_eq!(got.body, payload);

        // Undrain roundtrip: flag a container draining, cancel it.
        let resp =
            client.post("/admin/undrain/1", &[("authorization", &admin)], &[]).unwrap();
        assert_eq!(resp.status, 200);

        // Unknown container id → 404; garbage id → 400.
        let resp = client
            .post("/admin/decommission/77", &[("authorization", &admin)], &[])
            .unwrap();
        assert_eq!(resp.status, 404);
        let resp = client
            .post("/admin/undrain/77", &[("authorization", &admin)], &[])
            .unwrap();
        assert_eq!(resp.status, 404);
        let resp = client
            .post("/admin/decommission/notanid", &[("authorization", &admin)], &[])
            .unwrap();
        assert_eq!(resp.status, 400);
    }

    #[test]
    fn swar_parallel_gateway_serves_objects_end_to_end() {
        let (_server, client, _admin) =
            gateway_with_engine(crate::coordinator::GfEngine::SwarParallel);
        let token = register(&client, "UserA");
        let auth = format!("Bearer {token}");
        let payload: Vec<u8> = (0..300_000u32).map(|i| (i * 31 % 251) as u8).collect();

        let put = client
            .put("/objects/UserA/big", &[("authorization", &auth)], &payload)
            .unwrap();
        assert_eq!(put.status, 201);
        let v = parse(std::str::from_utf8(&put.body).unwrap()).unwrap();
        assert_eq!(v.req_str("backend").unwrap(), "swar-parallel");

        let got = client.get("/objects/UserA/big", &[("authorization", &auth)]).unwrap();
        assert_eq!(got.status, 200);
        assert_eq!(got.body, payload);

        let h = client.get("/health", &[]).unwrap();
        let v = parse(std::str::from_utf8(&h.body).unwrap()).unwrap();
        assert_eq!(v.req_str("engine").unwrap(), "swar-parallel");
    }

    #[test]
    fn scrub_endpoint_and_health_resilience_view() {
        let (_server, client, admin) = gateway();
        let token = register(&client, "UserA");
        let auth = format!("Bearer {token}");
        let payload: Vec<u8> = (0..30_000u32).map(|i| (i % 253) as u8).collect();
        client.put("/objects/UserA/o", &[("authorization", &auth)], &payload).unwrap();

        let resp = client.post("/admin/scrub", &[("authorization", &admin)], &[]).unwrap();
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let v = parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(v.req_u64("scanned").unwrap(), 1);
        assert_eq!(v.req_u64("chunks_verified").unwrap(), 10);
        assert_eq!(v.req_u64("corrupt_found").unwrap(), 0);

        let h = client.get("/health", &[]).unwrap();
        let v = parse(std::str::from_utf8(&h.body).unwrap()).unwrap();
        let breakers = v.get("breakers").as_arr().unwrap();
        assert_eq!(breakers.len(), 12);
        assert!(breakers.iter().all(|b| b.req_str("state").unwrap() == "closed"));
        assert_eq!(v.get("resilience").req_u64("scrub_cycles").unwrap(), 1);
        assert_eq!(v.get("resilience").req_u64("sheds").unwrap(), 0);
    }

    #[test]
    fn exhausted_deadline_is_504_with_retry_after() {
        let (_server, client, _admin) = gateway();
        let token = register(&client, "UserA");
        let auth = format!("Bearer {token}");
        client.put("/objects/UserA/o", &[("authorization", &auth)], b"bytes").unwrap();

        // A zero budget expires before the pull starts: 504, never a hang.
        let resp = client
            .get(
                "/objects/UserA/o",
                &[("authorization", &auth), ("x-dyno-deadline-ms", "0")],
            )
            .unwrap();
        assert_eq!(resp.status, 504, "{}", String::from_utf8_lossy(&resp.body));
        assert_eq!(resp.headers.get("retry-after").map(String::as_str), Some("1"));

        // A generous budget serves normally.
        let resp = client
            .get(
                "/objects/UserA/o",
                &[("authorization", &auth), ("x-dyno-deadline-ms", "60000")],
            )
            .unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"bytes");

        // Garbage header is a client error, and the timeout was counted.
        let resp = client
            .get(
                "/objects/UserA/o",
                &[("authorization", &auth), ("x-dyno-deadline-ms", "soon")],
            )
            .unwrap();
        assert_eq!(resp.status, 400);
        let m = client.get("/metrics", &[]).unwrap();
        let v = parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        assert_eq!(v.req_u64("deadline_timeouts").unwrap(), 1);
    }

    #[test]
    fn duplicate_registration_conflicts() {
        // Satellite bugfix: a duplicate registration is 409 Conflict
        // (it used to surface as a generic 400).
        let (_server, client, _admin) = gateway();
        register(&client, "UserA");
        let resp = client.post("/auth/register", &[], b"{\"user\": \"UserA\"}").unwrap();
        assert_eq!(resp.status, 409);
    }

    #[test]
    fn deprecated_alias_still_serves_and_is_tagged() {
        let (_server, client, _admin) = gateway();
        let token = register(&client, "UserA");
        let auth = format!("Bearer {token}");
        let put = client.put("/objects/UserA/o", &[("authorization", &auth)], b"x").unwrap();
        assert_eq!(put.status, 201);
        assert_eq!(put.headers.get("x-dyno-deprecated").unwrap(), "use /v1/objects");
        // The same object is visible through /v1.
        let got = client.get("/v1/objects/UserA/o", &[("authorization", &auth)]).unwrap();
        assert_eq!(got.status, 200);
        assert_eq!(got.body, b"x");
        assert!(got.headers.get("x-dyno-deprecated").is_none());
    }
}
