//! The versioned `/v1` REST surface (and the deprecated `/objects/`
//! alias, routed through the same handlers).
//!
//! Routes:
//! * `PUT    /v1/objects/<col...>/<name>` body = bytes, optional
//!   `x-dyno-policy: k,n | regular` → 201 + metadata headers. Served
//!   off the streaming ingest path: the body is erasure-encoded one
//!   part at a time as it arrives (bounded gateway memory; bodies at
//!   most one part long take the historical buffered path bit-for-bit)
//! * S3-style multipart, keyed by query string (only on `/v1`):
//!   `POST ?uploads` → `{"upload_id"}`; `PUT ?uploadId=&partNumber=N`
//!   body = part bytes → part JSON + per-part `ETag`;
//!   `GET ?uploadId=` → recorded parts (resume); `POST ?uploadId=` →
//!   complete (201 + metadata headers); `DELETE ?uploadId=` → abort
//!   (chunks of orphan parts garbage-collected)
//! * `GET    /v1/objects/<col...>/<name>[?version=N]` → bytes; honors
//!   `If-None-Match` (→ 304) and single `Range: bytes=` (→ 206 served
//!   by the coordinator's partial-read fast path)
//! * `HEAD   /v1/objects/<col...>/<name>[?version=N]` → metadata
//!   headers, `Content-Length` = object size, no body
//! * `DELETE /v1/objects/<col...>/<name>` → `{"deleted_chunks": n}`
//! * `GET    /v1/collections/<col...>?prefix=&limit=&after=` →
//!   paginated listing (keyset cursor via `next_after`)
//! * `PUT    /v1/grants/<col...>` body `{"user","perm"}` → grant
//! * `DELETE /v1/grants/<col...>` body `{"user","perm"}` → revoke
//!
//! Every object response carries `ETag` (quoted hex SHA3-256 of the
//! content), `x-dyno-version`, `x-dyno-size`, `x-dyno-uuid`,
//! `x-dyno-created`. Path segments are percent-decoded on `/v1` (the
//! alias keeps raw paths for wire compatibility); alias responses add
//! `x-dyno-deprecated` pointing at the replacement.

use std::sync::Arc;

use crate::api::{parse_policy, DEFAULT_LIST_LIMIT, MAX_LIST_LIMIT};
use crate::container::decode_key;
use crate::coordinator::{DynoStore, OpContext, PullOpts, PushOpts};
use crate::json::{obj, parse, Value};
use crate::metadata::{ObjectMeta, Permission};
use crate::net::{BodyReader, HttpRequest, HttpResponse};
use crate::resilience::Deadline;
use crate::util::to_hex;
use crate::{Error, Result};

/// Split a request target into its path and decoded query pairs.
/// Malformed percent escapes in a key/value fall back to the raw text.
pub(super) fn split_query(target: &str) -> (&str, Vec<(String, String)>) {
    match target.split_once('?') {
        None => (target, Vec::new()),
        Some((path, q)) => {
            let pairs = q
                .split('&')
                .filter(|s| !s.is_empty())
                .map(|kv| {
                    let (k, v) = kv.split_once('=').unwrap_or((kv, ""));
                    (
                        decode_key(k).unwrap_or_else(|_| k.to_string()),
                        decode_key(v).unwrap_or_else(|_| v.to_string()),
                    )
                })
                .collect();
            (path, pairs)
        }
    }
}

fn query_get<'a>(query: &'a [(String, String)], key: &str) -> Option<&'a str> {
    query.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

/// `?version=N` (None when absent; 400 on garbage).
fn version_pin(query: &[(String, String)]) -> Result<Option<u64>> {
    match query_get(query, "version") {
        None => Ok(None),
        Some(v) => v
            .parse()
            .map(Some)
            .map_err(|_| Error::Invalid(format!("bad version '{v}'"))),
    }
}

/// Split `<prefix>/<collection...>/<name>` into (collection, name),
/// percent-decoding each segment when `decode` (the `/v1` routes; the
/// deprecated alias keeps raw segments for wire compatibility).
fn object_target(path: &str, prefix: &str, decode: bool) -> Result<(String, String)> {
    let rest = path
        .strip_prefix(prefix)
        .ok_or_else(|| Error::Invalid(format!("bad object path '{path}'")))?;
    let mut segs: Vec<String> = Vec::new();
    for seg in rest.split('/').filter(|s| !s.is_empty()) {
        segs.push(if decode { decode_key(seg)? } else { seg.to_string() });
    }
    if segs.len() < 2 {
        return Err(Error::Invalid(format!(
            "bad object path '{path}' (want /<collection...>/<name>)"
        )));
    }
    let name = segs.pop().expect("len >= 2");
    Ok((format!("/{}", segs.join("/")), name))
}

/// Decode `<prefix>/<collection...>` into a collection path.
fn collection_target(path: &str, prefix: &str) -> Result<String> {
    let rest = path
        .strip_prefix(prefix)
        .ok_or_else(|| Error::Invalid(format!("bad collection path '{path}'")))?;
    let mut segs: Vec<String> = Vec::new();
    for seg in rest.split('/').filter(|s| !s.is_empty()) {
        segs.push(decode_key(seg)?);
    }
    if segs.is_empty() {
        return Err(Error::Invalid(format!("bad collection path '{path}'")));
    }
    Ok(format!("/{}", segs.join("/")))
}

/// Per-request time budget: `x-dyno-deadline-ms: 2500` starts a 2.5 s
/// deadline the moment the gateway parses it; the remaining budget is
/// checked before every expensive coordinator stage and clamped onto
/// every container transport wait. Absent header = no deadline.
fn request_deadline(req: &HttpRequest) -> Result<Deadline> {
    match req.header("x-dyno-deadline-ms") {
        None => Ok(Deadline::none()),
        Some(ms) => {
            let ms: u64 = ms
                .trim()
                .parse()
                .map_err(|_| Error::Invalid(format!("bad x-dyno-deadline-ms '{ms}'")))?;
            Ok(Deadline::in_ms(ms))
        }
    }
}

fn bearer(req: &HttpRequest) -> Result<String> {
    Ok(req
        .bearer_token()
        .ok_or_else(|| Error::Auth("missing bearer token".into()))?
        .to_string())
}

/// The metadata headers every object response carries.
fn object_headers(resp: &mut HttpResponse, meta: &ObjectMeta) {
    resp.headers.insert("etag".into(), format!("\"{}\"", to_hex(&meta.sha3)));
    resp.headers.insert("x-dyno-version".into(), meta.version.to_string());
    resp.headers.insert("x-dyno-size".into(), meta.size.to_string());
    resp.headers.insert("x-dyno-uuid".into(), meta.uuid.clone());
    resp.headers.insert("x-dyno-created".into(), meta.created_at.to_string());
    resp.headers.insert("x-dyno-nonce-epoch".into(), meta.nonce_epoch.to_string());
}

fn mark_deprecated(resp: &mut HttpResponse, alias: bool) {
    if alias {
        resp.headers
            .insert("x-dyno-deprecated".into(), "use /v1/objects".into());
    }
}

/// Does an `If-None-Match` header value match this ETag? Accepts `*`,
/// quoted/unquoted tags, comma-separated lists, and weak prefixes.
fn etag_matches(header: &str, etag_hex: &str) -> bool {
    if header.trim() == "*" {
        return true;
    }
    header.split(',').any(|candidate| {
        candidate
            .trim()
            .trim_start_matches("W/")
            .trim_matches('"')
            .eq_ignore_ascii_case(etag_hex)
    })
}

/// Outcome of parsing a `Range` header against an object of `size`.
enum RangeSpec {
    /// No (or unusable) header: serve the full object. RFC 9110 says
    /// a server MAY ignore an invalid Range header, and multi-range
    /// responses are not supported — both serve the whole object.
    Whole,
    /// Serve `[start, end]` (satisfiable; end already clamped).
    Slice(u64, u64),
    /// `416 Range Not Satisfiable`.
    Unsatisfiable,
}

fn parse_range(header: Option<&str>, size: u64) -> RangeSpec {
    let Some(spec) = header.and_then(|h| h.trim().strip_prefix("bytes=")) else {
        return RangeSpec::Whole;
    };
    if spec.contains(',') {
        return RangeSpec::Whole; // multi-range unsupported: full object
    }
    let Some((a, b)) = spec.split_once('-') else { return RangeSpec::Whole };
    let (a, b) = (a.trim(), b.trim());
    if a.is_empty() {
        // Suffix form: last N bytes.
        let Ok(n) = b.parse::<u64>() else { return RangeSpec::Whole };
        if n == 0 || size == 0 {
            return RangeSpec::Unsatisfiable;
        }
        return RangeSpec::Slice(size.saturating_sub(n), size - 1);
    }
    let Ok(start) = a.parse::<u64>() else { return RangeSpec::Whole };
    if start >= size {
        return RangeSpec::Unsatisfiable;
    }
    let end = if b.is_empty() {
        size - 1
    } else {
        match b.parse::<u64>() {
            Ok(end) if end >= start => end.min(size - 1),
            _ => return RangeSpec::Whole,
        }
    };
    RangeSpec::Slice(start, end)
}

/// `GET/PUT/HEAD/DELETE /v1/objects/...` (and the `/objects/` alias).
pub(super) fn object_route(
    store: &Arc<DynoStore>,
    method: &str,
    req: &HttpRequest,
    path: &str,
    query: &[(String, String)],
    alias: bool,
) -> Result<HttpResponse> {
    let token = bearer(req)?;
    let prefix = if alias { "/objects" } else { "/v1/objects" };
    let (collection, name) = object_target(path, prefix, !alias)?;
    let version = version_pin(query)?;
    let ctx = OpContext::default().with_deadline(request_deadline(req)?);
    // Only reads honor a version pin. Rejecting it elsewhere beats
    // silently ignoring it: DELETE evicts EVERY version, and a client
    // that sent `?version=0` expecting to prune one would lose all of
    // them with a 200.
    if version.is_some() && method != "GET" && method != "HEAD" {
        return Err(Error::Invalid(format!(
            "?version= is only supported on GET/HEAD ({method} affects all versions)"
        )));
    }
    // S3-style multipart rides on query parameters (only reachable via
    // `/v1` — the deprecated alias parses no query string).
    if method == "POST" && query_get(query, "uploads").is_some() {
        let upload_id = store.multipart_init(&token, &collection, &name)?;
        return Ok(HttpResponse::json(
            200,
            &obj(vec![
                ("upload_id", upload_id.as_str().into()),
                ("collection", collection.as_str().into()),
                ("name", name.as_str().into()),
            ]),
        ));
    }
    if let Some(upload_id) = query_get(query, "uploadId") {
        return multipart_route(store, method, req, &token, upload_id, query, ctx);
    }
    let mut resp = match method {
        "PUT" => {
            let policy = match req.header("x-dyno-policy") {
                Some(p) => Some(parse_policy(p)?),
                None => None,
            };
            let report =
                store.push(&token, &collection, &name, &req.body, PushOpts { policy, ctx })?;
            let mut resp = HttpResponse::json(
                201,
                &obj(vec![
                    ("uuid", report.meta.uuid.as_str().into()),
                    ("version", report.meta.version.into()),
                    ("size", report.meta.size.into()),
                    ("etag", to_hex(&report.meta.sha3).into()),
                    ("created_at", report.meta.created_at.into()),
                    ("sim_s", report.sim_s.into()),
                    ("backend", report.backend.into()),
                ]),
            );
            object_headers(&mut resp, &report.meta);
            resp
        }
        "GET" => {
            // Metadata first: conditional GETs and unsatisfiable ranges
            // are answered without touching the data plane. The data
            // path below pins the version this stat saw, so the ETag /
            // Content-Range decisions always describe the bytes served
            // even when a re-push races the request.
            let meta = store.stat(&token, &collection, &name, version)?;
            let version = Some(meta.version);
            let etag_hex = to_hex(&meta.sha3);
            if req
                .header("if-none-match")
                .is_some_and(|inm| etag_matches(inm, &etag_hex))
            {
                let mut resp = HttpResponse::new(304);
                object_headers(&mut resp, &meta);
                mark_deprecated(&mut resp, alias);
                return Ok(resp);
            }
            match parse_range(req.header("range"), meta.size) {
                RangeSpec::Unsatisfiable => {
                    let mut resp = HttpResponse::text(416, "range not satisfiable");
                    resp.headers
                        .insert("content-range".into(), format!("bytes */{}", meta.size));
                    mark_deprecated(&mut resp, alias);
                    return Ok(resp);
                }
                RangeSpec::Slice(start, end) => {
                    let report = store.pull_range(
                        &token,
                        &collection,
                        &name,
                        start,
                        end,
                        PullOpts { version, ctx },
                    )?;
                    let mut resp = HttpResponse::bytes(206, report.data);
                    resp.headers.insert(
                        "content-range".into(),
                        format!("bytes {}-{}/{}", report.start, report.end, meta.size),
                    );
                    resp.headers.insert(
                        "x-dyno-chunks-fetched".into(),
                        report.chunks_fetched.to_string(),
                    );
                    resp.headers
                        .insert("x-dyno-partial".into(), report.partial.to_string());
                    object_headers(&mut resp, &report.meta);
                    resp
                }
                RangeSpec::Whole => {
                    // Striped objects stream to the socket one erasure
                    // part at a time (total length is known from
                    // metadata, so framing stays content-length); other
                    // placements arrive as one pre-pulled block through
                    // the same path.
                    let mut stream = Arc::clone(store).pull_stream(
                        &token,
                        &collection,
                        &name,
                        PullOpts { version, ctx },
                    )?;
                    let total = stream.total_len();
                    let info = stream.meta().clone();
                    let mut resp = HttpResponse::stream(
                        200,
                        Some(total),
                        Box::new(move || stream.next_block()),
                    );
                    object_headers(&mut resp, &info);
                    resp
                }
            }
        }
        "HEAD" => {
            // On `/v1`, size is advertised via content-length with no
            // body (the response writer honors a handler-set
            // content-length, and v1 clients know HEAD is bodiless).
            // The alias keeps the legacy `content-length: 0` framing:
            // pre-v1 client binaries read_exact(content-length) on HEAD
            // and would hang/fail on an advertised size.
            match store.stat(&token, &collection, &name, version) {
                Ok(meta) => {
                    let mut resp = HttpResponse::new(200);
                    resp.headers
                        .insert("content-type".into(), "application/octet-stream".into());
                    if !alias {
                        resp.headers.insert("content-length".into(), meta.size.to_string());
                    }
                    object_headers(&mut resp, &meta);
                    resp
                }
                Err(Error::NotFound(_)) => {
                    // Stamp the persisted eviction generation on the 404
                    // too: an encrypting client re-pushing an evicted
                    // name has nothing to stat, and this header is the
                    // only way it learns the nonce epoch the push will
                    // carry. Best-effort — permission failures keep the
                    // plain 404 (no epoch oracle for unreadable paths).
                    let mut resp = HttpResponse::new(404);
                    if let Ok(epoch) = store.nonce_epoch(&token, &collection, &name) {
                        resp.headers
                            .insert("x-dyno-nonce-epoch".into(), epoch.to_string());
                    }
                    resp
                }
                Err(e) => return Err(e),
            }
        }
        "DELETE" => {
            let deleted = store.evict(&token, &collection, &name)?;
            HttpResponse::json(200, &obj(vec![("deleted_chunks", deleted.into())]))
        }
        other => {
            return Err(Error::Invalid(format!("method {other} not supported on objects")))
        }
    };
    mark_deprecated(&mut resp, alias);
    Ok(resp)
}

/// Multipart sub-routes of `/v1/objects/...`, keyed by `?uploadId=`:
/// `PUT &partNumber=N` records one part, `GET` lists recorded parts
/// (resume support), `POST` completes, `DELETE` aborts.
fn multipart_route(
    store: &Arc<DynoStore>,
    method: &str,
    req: &HttpRequest,
    token: &str,
    upload_id: &str,
    query: &[(String, String)],
    ctx: OpContext,
) -> Result<HttpResponse> {
    match method {
        "PUT" => {
            let number: u32 = query_get(query, "partNumber")
                .ok_or_else(|| {
                    Error::Invalid("part upload requires ?partNumber=".into())
                })?
                .parse()
                .map_err(|_| Error::Invalid("bad partNumber".into()))?;
            let policy = match req.header("x-dyno-policy") {
                Some(p) => Some(parse_policy(p)?),
                None => None,
            };
            let part = store.multipart_put_part(
                token,
                upload_id,
                number,
                &req.body,
                PushOpts { policy, ctx },
            )?;
            let mut resp = HttpResponse::json(
                200,
                &obj(vec![
                    ("number", (part.number as u64).into()),
                    ("size", part.size.into()),
                    ("etag", part.etag().into()),
                ]),
            );
            resp.headers.insert("etag".into(), format!("\"{}\"", part.etag()));
            Ok(resp)
        }
        "GET" => {
            let state = store.multipart_parts(token, upload_id)?;
            let parts: Vec<Value> = state
                .parts
                .values()
                .map(|p| {
                    obj(vec![
                        ("number", (p.number as u64).into()),
                        ("size", p.size.into()),
                        ("etag", p.etag().into()),
                    ])
                })
                .collect();
            Ok(HttpResponse::json(
                200,
                &obj(vec![
                    ("upload_id", upload_id.into()),
                    ("collection", state.collection.as_str().into()),
                    ("name", state.name.as_str().into()),
                    ("created_at", state.created_at.into()),
                    ("parts", Value::Arr(parts)),
                ]),
            ))
        }
        "POST" => {
            let meta = store.multipart_complete(token, upload_id)?;
            let mut resp = HttpResponse::json(
                201,
                &obj(vec![
                    ("uuid", meta.uuid.as_str().into()),
                    ("version", meta.version.into()),
                    ("size", meta.size.into()),
                    ("etag", to_hex(&meta.sha3).into()),
                    ("created_at", meta.created_at.into()),
                ]),
            );
            object_headers(&mut resp, &meta);
            Ok(resp)
        }
        "DELETE" => {
            let aborted = store.multipart_abort(token, upload_id)?;
            Ok(HttpResponse::json(200, &obj(vec![("aborted_parts", aborted.into())])))
        }
        other => Err(Error::Invalid(format!(
            "method {other} not supported on multipart uploads"
        ))),
    }
}

/// Should this request take the streamed-ingest path? Plain object PUTs
/// stream; multipart part PUTs (`?uploadId=`) buffer — a part is one
/// erasure unit and must be whole before it can be encoded.
pub(super) fn is_streaming_put(req: &HttpRequest) -> bool {
    if req.method != "PUT" {
        return false;
    }
    if req.path.starts_with("/v1/objects/") {
        let (_, query) = split_query(&req.path);
        return !query.iter().any(|(k, _)| k == "uploadId");
    }
    // The deprecated alias defines no query parameters, so every alias
    // PUT is a plain object upload.
    req.path.starts_with("/objects/")
}

/// Streamed `PUT /v1/objects/...` (and the `/objects/` alias): the
/// request body is erasure-encoded per part as bytes arrive off the
/// socket, dispatching each part's chunks while the client uploads the
/// next — gateway memory stays O(part × pipeline depth) regardless of
/// body size. Bodies at most one part long take the exact buffered-push
/// path (byte-identical metadata); longer bodies commit as `Striped`.
pub(super) fn object_put_stream(
    store: &Arc<DynoStore>,
    req: &HttpRequest,
    body: &mut BodyReader,
    part_size: usize,
) -> Result<HttpResponse> {
    let alias = !req.path.starts_with("/v1/");
    let (path, query) = if alias {
        (req.path.as_str(), Vec::new())
    } else {
        split_query(&req.path)
    };
    if version_pin(&query)?.is_some() {
        return Err(Error::Invalid(
            "?version= is only supported on GET/HEAD (PUT affects all versions)".into(),
        ));
    }
    let token = bearer(req)?;
    let prefix = if alias { "/objects" } else { "/v1/objects" };
    let (collection, name) = object_target(path, prefix, !alias)?;
    let ctx = OpContext::default().with_deadline(request_deadline(req)?);
    let policy = match req.header("x-dyno-policy") {
        Some(p) => Some(parse_policy(p)?),
        None => None,
    };
    let report = store.push_stream(
        &token,
        &collection,
        &name,
        body,
        part_size,
        PushOpts { policy, ctx },
    )?;
    let mut resp = HttpResponse::json(
        201,
        &obj(vec![
            ("uuid", report.meta.uuid.as_str().into()),
            ("version", report.meta.version.into()),
            ("size", report.meta.size.into()),
            ("etag", to_hex(&report.meta.sha3).into()),
            ("created_at", report.meta.created_at.into()),
            ("sim_s", report.sim_s.into()),
            ("backend", report.backend.into()),
        ]),
    );
    object_headers(&mut resp, &report.meta);
    mark_deprecated(&mut resp, alias);
    Ok(resp)
}

/// `GET /v1/collections/<col...>?prefix=&limit=&after=`.
pub(super) fn collection_route(
    store: &Arc<DynoStore>,
    method: &str,
    req: &HttpRequest,
    path: &str,
    query: &[(String, String)],
) -> Result<HttpResponse> {
    if method != "GET" {
        return Err(Error::Invalid(format!(
            "method {method} not supported on collections"
        )));
    }
    let token = bearer(req)?;
    let collection = collection_target(path, "/v1/collections")?;
    let prefix = query_get(query, "prefix").unwrap_or("");
    let after = query_get(query, "after");
    let limit = match query_get(query, "limit") {
        None => DEFAULT_LIST_LIMIT,
        Some(l) => l
            .parse::<usize>()
            .ok()
            .filter(|&l| l >= 1)
            .ok_or_else(|| Error::Invalid(format!("bad limit '{l}'")))?
            .min(MAX_LIST_LIMIT),
    };
    let page = store.list_page(&token, &collection, prefix, after, limit)?;
    let objects: Vec<Value> = page
        .objects
        .iter()
        .map(|m| {
            obj(vec![
                ("name", m.name.as_str().into()),
                ("uuid", m.uuid.as_str().into()),
                ("version", m.version.into()),
                ("size", m.size.into()),
                ("etag", to_hex(&m.sha3).into()),
                ("created_at", m.created_at.into()),
                ("nonce_epoch", m.nonce_epoch.into()),
            ])
        })
        .collect();
    let next_after = if page.truncated {
        page.objects.last().map(|m| Value::from(m.name.as_str())).unwrap_or(Value::Null)
    } else {
        Value::Null
    };
    Ok(HttpResponse::json(
        200,
        &obj(vec![
            ("collection", collection.as_str().into()),
            ("objects", Value::Arr(objects)),
            ("truncated", page.truncated.into()),
            ("next_after", next_after),
        ]),
    ))
}

/// `PUT/DELETE /v1/grants/<col...>` body `{"user": .., "perm": ..}`.
pub(super) fn grant_route(
    store: &Arc<DynoStore>,
    method: &str,
    req: &HttpRequest,
    path: &str,
) -> Result<HttpResponse> {
    let token = bearer(req)?;
    let collection = collection_target(path, "/v1/grants")?;
    let body = std::str::from_utf8(&req.body)
        .map_err(|_| Error::Invalid("body not utf-8".into()))?;
    let v = parse(body)?;
    let user = v.req_str("user")?.to_string();
    let perm = Permission::parse(v.req_str("perm")?)?;
    let action = match method {
        "PUT" => {
            store.grant(&token, &collection, &user, perm)?;
            "granted"
        }
        "DELETE" => {
            store.revoke(&token, &collection, &user, perm)?;
            "revoked"
        }
        other => {
            return Err(Error::Invalid(format!("method {other} not supported on grants")))
        }
    };
    Ok(HttpResponse::json(
        200,
        &obj(vec![
            (action, true.into()),
            ("collection", collection.as_str().into()),
            ("user", user.as_str().into()),
            ("perm", perm.as_str().into()),
        ]),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_query_cases() {
        let (p, q) = split_query("/v1/objects/UserA/x");
        assert_eq!(p, "/v1/objects/UserA/x");
        assert!(q.is_empty());
        let (p, q) = split_query("/v1/collections/UserA?prefix=ap&limit=2&after=apple");
        assert_eq!(p, "/v1/collections/UserA");
        assert_eq!(
            q,
            vec![
                ("prefix".to_string(), "ap".to_string()),
                ("limit".to_string(), "2".to_string()),
                ("after".to_string(), "apple".to_string()),
            ]
        );
        // Percent-decoded values, flag-style pairs, empty segments.
        let (_, q) = split_query("/x?a=with%20space&flag&&b=");
        assert_eq!(q[0], ("a".to_string(), "with space".to_string()));
        assert_eq!(q[1], ("flag".to_string(), String::new()));
        assert_eq!(q[2], ("b".to_string(), String::new()));
    }

    #[test]
    fn object_target_decoding() {
        assert_eq!(
            object_target("/v1/objects/UserA/Col/name.bin", "/v1/objects", true).unwrap(),
            ("/UserA/Col".to_string(), "name.bin".to_string())
        );
        assert_eq!(
            object_target("/v1/objects/UserA/with%20space", "/v1/objects", true).unwrap(),
            ("/UserA".to_string(), "with space".to_string())
        );
        // Alias keeps raw bytes.
        assert_eq!(
            object_target("/objects/UserA/a%20b", "/objects", false).unwrap(),
            ("/UserA".to_string(), "a%20b".to_string())
        );
        assert!(object_target("/v1/objects/onlyname", "/v1/objects", true).is_err());
        assert!(object_target("/v1/objects/UserA/", "/v1/objects", true).is_err());
    }

    #[test]
    fn range_parsing() {
        let slice = |h: &str, size| match parse_range(Some(h), size) {
            RangeSpec::Slice(a, b) => Some((a, b)),
            _ => None,
        };
        assert_eq!(slice("bytes=0-99", 1000), Some((0, 99)));
        assert_eq!(slice("bytes=10-", 1000), Some((10, 999)));
        assert_eq!(slice("bytes=-100", 1000), Some((900, 999)));
        assert_eq!(slice("bytes=-2000", 1000), Some((0, 999)), "oversize suffix clamps");
        assert_eq!(slice("bytes=500-9999", 1000), Some((500, 999)), "end clamps");
        assert!(matches!(parse_range(Some("bytes=1000-"), 1000), RangeSpec::Unsatisfiable));
        assert!(matches!(parse_range(Some("bytes=-0"), 1000), RangeSpec::Unsatisfiable));
        assert!(matches!(parse_range(Some("bytes=0-"), 0), RangeSpec::Unsatisfiable));
        // Ignored forms serve the whole object.
        assert!(matches!(parse_range(None, 1000), RangeSpec::Whole));
        assert!(matches!(parse_range(Some("bytes=5-2"), 1000), RangeSpec::Whole));
        assert!(matches!(parse_range(Some("bytes=0-1,5-9"), 1000), RangeSpec::Whole));
        assert!(matches!(parse_range(Some("items=0-1"), 1000), RangeSpec::Whole));
        assert!(matches!(parse_range(Some("bytes=x-y"), 1000), RangeSpec::Whole));
    }

    #[test]
    fn etag_matching() {
        assert!(etag_matches("\"abc\"", "abc"));
        assert!(etag_matches("abc", "abc"));
        assert!(etag_matches("*", "anything"));
        assert!(etag_matches("\"zzz\", \"abc\"", "abc"));
        assert!(etag_matches("W/\"abc\"", "abc"));
        assert!(!etag_matches("\"zzz\"", "abc"));
    }
}
