//! DynoStore CLI: deploy/serve a gateway, and push / pull / exists /
//! evict objects against a running gateway (paper §V's command-line
//! client), plus admin operations.
//!
//! ```text
//! dynostore serve  --config cluster.json --addr 127.0.0.1:8080 --data-dir /var/lib/dynostore
//! dynostore agent  --config agent.json   --addr 127.0.0.1:9100
//! dynostore register --addr HOST:PORT --user UserA
//! dynostore push   --addr HOST:PORT --token T /UserA/col/name ./file
//! dynostore pull   --addr HOST:PORT --token T /UserA/col/name ./out
//! dynostore exists --addr HOST:PORT --token T /UserA/col/name
//! dynostore evict  --addr HOST:PORT --token T /UserA/col/name
//! dynostore admin  --addr HOST:PORT [--token T] repair|gc|metrics|health
//! dynostore decommission --addr HOST:PORT --token T ID
//! dynostore rebalance    --addr HOST:PORT --token T [--threshold F] [--max-moves N]
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use dynostore::json::parse;
use dynostore::net::HttpClient;
use dynostore::{gateway, Config};

fn main() {
    dynostore::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

/// Tiny flag parser: `--key value` pairs + positional arguments.
fn parse_args(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut positional = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), String::new());
                i += 1;
            }
        } else {
            positional.push(args[i].clone());
            i += 1;
        }
    }
    (flags, positional)
}

fn run(args: &[String]) -> Result<(), String> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let (flags, pos) = parse_args(&args[1..]);
    match cmd.as_str() {
        "serve" => serve(&flags),
        "agent" => agent(&flags),
        "register" => register(&flags),
        "push" | "pull" | "exists" | "evict" => object_op(cmd, &flags, &pos),
        "admin" => admin(&flags, &pos),
        "decommission" => decommission(&flags, &pos),
        "undrain" => undrain(&flags, &pos),
        "rebalance" => rebalance(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(format!("unknown command '{other}' (try: dynostore help)")),
    }
}

fn print_usage() {
    println!(
        "dynostore — wide-area data distribution over heterogeneous storage\n\
         \n\
         commands:\n\
         \x20 serve    --config FILE [--addr 127.0.0.1:8080] [--workers 8]\n\
         \x20          [--engine pure-rust|swar|swar-parallel|pjrt]\n\
         \x20          [--data-dir DIR] [--snapshot-every N] [--max-body-mb MB]\n\
         \x20          (--data-dir persists the metadata plane: WAL + snapshots;\n\
         \x20           a restarted serve recovers every acknowledged object)\n\
         \x20 agent    --config FILE [--addr 127.0.0.1:9100] [--workers 4]\n\
         \x20          (container agent: serves one data container over HTTP;\n\
         \x20           gateways attach it via an \"endpoint\" container entry)\n\
         \x20 register --addr HOST:PORT --user NAME\n\
         \x20 push     --addr HOST:PORT --token T PATH FILE\n\
         \x20 pull     --addr HOST:PORT --token T PATH [OUT]\n\
         \x20 exists   --addr HOST:PORT --token T PATH\n\
         \x20 evict    --addr HOST:PORT --token T PATH\n\
         \x20 admin    --addr HOST:PORT [--token T] repair|gc|metrics|health\n\
         \x20          (repair/gc need the admin token `serve` prints at startup)\n\
         \x20 decommission --addr HOST:PORT --token T ID\n\
         \x20          (drain container ID: migrate every chunk off, then remove it)\n\
         \x20 undrain  --addr HOST:PORT --token T ID\n\
         \x20          (cancel a stopped drain: container rejoins placement)\n\
         \x20 rebalance    --addr HOST:PORT --token T [--threshold F] [--max-moves N]\n\
         \x20          (move chunks hot\u{2192}cold until utilization spread \u{2264} threshold)\n\
         \n\
         PATH is /User/Collection.../name. See README.md for the config\n\
         file format and examples/ for library usage."
    );
}

fn serve(flags: &HashMap<String, String>) -> Result<(), String> {
    let mut config = match flags.get("config") {
        Some(path) => Config::from_file(path).map_err(|e| e.to_string())?,
        None => {
            dynostore::log_warn!("no --config given; starting an empty default deployment");
            Config::default()
        }
    };
    // CLI override of the config file's GF(2^8) engine knob.
    if let Some(engine) = flags.get("engine") {
        config.engine = dynostore::coordinator::GfEngine::parse(engine).ok_or_else(|| {
            format!("unknown --engine '{engine}' (pure-rust | swar | swar-parallel | pjrt)")
        })?;
    }
    // CLI override of the metadata durability root. Without one (in the
    // config or here) the metadata plane is in-memory and a restart
    // loses it — warn loudly when containers are configured.
    if let Some(dir) = flags.get("data-dir") {
        config.data_dir = Some(dir.clone());
    }
    if let Some(every) = flags.get("snapshot-every") {
        config.snapshot_every = every
            .parse::<u64>()
            .map_err(|_| "--snapshot-every must be a number".to_string())?
            .max(1);
    }
    if let Some(cap) = flags.get("max-body-mb") {
        config.max_body_mb = cap
            .parse::<u64>()
            .map_err(|_| "--max-body-mb must be a number".to_string())?
            .max(1);
    }
    if config.data_dir.is_none() {
        dynostore::log_warn!(
            "no data_dir configured: metadata is in-memory and will NOT survive a restart \
             (pass --data-dir DIR or set \"data_dir\" in the config)"
        );
    }
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:8080".into());
    let workers: usize =
        flags.get("workers").and_then(|w| w.parse().ok()).unwrap_or(8);
    let store = config.build().map_err(|e| e.to_string())?;
    if let Some(rec) = store.recovery_report() {
        if rec.recovered() {
            println!(
                "recovered metadata: snapshot {} ({} commits), {} WAL records replayed{}",
                if rec.snapshot_loaded { "loaded" } else { "absent" },
                rec.snapshot_commits,
                rec.wal_replayed,
                if rec.wal_truncated { ", torn tail truncated" } else { "" }
            );
        }
    }
    // The /admin/* routes require the admin scope; hand the operator a
    // token at startup (mintable only deployment-side).
    let admin_token = store.issue_admin_token(30 * 24 * 3600);
    let max_body = usize::try_from(config.max_body_mb.saturating_mul(1 << 20))
        .unwrap_or(usize::MAX);
    let server = gateway::serve_with_limit(Arc::clone(&store), &addr, workers, max_body)
        .map_err(|e| e.to_string())?;
    dynostore::log_info!(
        "dynostore gateway on {} ({} containers, {} metadata replicas, policy {:?}, engine {})",
        server.addr(),
        store.registry.len(),
        store.meta.replica_count(),
        store.default_policy,
        store.backend_name()
    );
    println!("listening on {}", server.addr());
    println!("admin token (30d, for admin/decommission/undrain/rebalance): {admin_token}");
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Run a standalone container agent: one data container, served over
/// HTTP for remote gateways (paper §III-A's "install the DynoStore
/// agent and provide a configuration file").
fn agent(flags: &HashMap<String, String>) -> Result<(), String> {
    let path = need(flags, "config")?;
    let config = dynostore::config::AgentConfig::from_file(path).map_err(|e| e.to_string())?;
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:9100".into());
    let workers: usize = flags.get("workers").and_then(|w| w.parse().ok()).unwrap_or(4);
    let container = config.build().map_err(|e| e.to_string())?;
    let name = container.name.clone();
    let server = dynostore::container::ContainerServer::serve(container, &addr, workers)
        .map_err(|e| e.to_string())?;
    dynostore::log_info!(
        "dynostore container agent '{}' (id {}) on {} ({:?} backend)",
        name,
        config.id,
        server.addr(),
        config.backend
    );
    println!("agent '{name}' listening on {}", server.addr());
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn need<'a>(flags: &'a HashMap<String, String>, key: &str) -> Result<&'a str, String> {
    flags.get(key).map(|s| s.as_str()).ok_or_else(|| format!("missing --{key}"))
}

fn register(flags: &HashMap<String, String>) -> Result<(), String> {
    let addr = need(flags, "addr")?;
    let user = need(flags, "user")?;
    let client = HttpClient::new(addr);
    let resp = client
        .post("/auth/register", &[], format!("{{\"user\": \"{user}\"}}").as_bytes())
        .map_err(|e| e.to_string())?;
    let body = String::from_utf8_lossy(&resp.body).to_string();
    if resp.status != 201 {
        return Err(format!("register failed ({}): {body}", resp.status));
    }
    let token = parse(&body)
        .map_err(|e| e.to_string())?
        .req_str("token")
        .map_err(|e| e.to_string())?
        .to_string();
    println!("{token}");
    Ok(())
}

fn object_op(
    cmd: &str,
    flags: &HashMap<String, String>,
    pos: &[String],
) -> Result<(), String> {
    let addr = need(flags, "addr")?;
    let token = need(flags, "token")?;
    let path = pos.first().ok_or("missing object PATH")?;
    let auth = format!("Bearer {token}");
    let client = HttpClient::new(addr);
    let url = format!("/objects{path}");
    match cmd {
        "push" => {
            let file = pos.get(1).ok_or("missing FILE to push")?;
            let data = std::fs::read(file).map_err(|e| e.to_string())?;
            let resp = client
                .put(&url, &[("authorization", &auth)], &data)
                .map_err(|e| e.to_string())?;
            println!("{}", String::from_utf8_lossy(&resp.body));
            if resp.status == 201 {
                Ok(())
            } else {
                Err(format!("push failed: {}", resp.status))
            }
        }
        "pull" => {
            let resp = client
                .get(&url, &[("authorization", &auth)])
                .map_err(|e| e.to_string())?;
            if resp.status != 200 {
                return Err(format!(
                    "pull failed ({}): {}",
                    resp.status,
                    String::from_utf8_lossy(&resp.body)
                ));
            }
            match pos.get(1) {
                Some(out) => {
                    std::fs::write(out, &resp.body).map_err(|e| e.to_string())?;
                    println!("wrote {} bytes to {out}", resp.body.len());
                }
                None => {
                    use std::io::Write;
                    std::io::stdout().write_all(&resp.body).map_err(|e| e.to_string())?;
                }
            }
            Ok(())
        }
        "exists" => {
            let resp = client
                .request("HEAD", &url, &[("authorization", &auth)], &[])
                .map_err(|e| e.to_string())?;
            println!("{}", if resp.status == 200 { "true" } else { "false" });
            Ok(())
        }
        "evict" => {
            let resp = client
                .delete(&url, &[("authorization", &auth)])
                .map_err(|e| e.to_string())?;
            println!("{}", String::from_utf8_lossy(&resp.body));
            if resp.status == 200 {
                Ok(())
            } else {
                Err(format!("evict failed: {}", resp.status))
            }
        }
        _ => unreachable!(),
    }
}

/// `Authorization` header for admin-gated endpoints (`--token`).
fn admin_headers(flags: &HashMap<String, String>) -> Result<Vec<(String, String)>, String> {
    let token = need(flags, "token")?;
    Ok(vec![("authorization".to_string(), format!("Bearer {token}"))])
}

fn admin(flags: &HashMap<String, String>, pos: &[String]) -> Result<(), String> {
    let addr = need(flags, "addr")?;
    let action = pos.first().map(|s| s.as_str()).unwrap_or("metrics");
    let client = HttpClient::new(addr);
    let resp = match action {
        // repair/gc mutate the deployment: the gateway requires a token.
        "repair" | "gc" => {
            let headers = admin_headers(flags)?;
            let hdrs: Vec<(&str, &str)> =
                headers.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            client.post(&format!("/admin/{action}"), &hdrs, &[])
        }
        "metrics" => client.get("/metrics", &[]),
        "health" => client.get("/health", &[]),
        other => return Err(format!("unknown admin action '{other}'")),
    }
    .map_err(|e| e.to_string())?;
    println!("{}", String::from_utf8_lossy(&resp.body));
    Ok(())
}

/// Drain a container out of the storage network and remove it.
fn decommission(flags: &HashMap<String, String>, pos: &[String]) -> Result<(), String> {
    let addr = need(flags, "addr")?;
    let id: u32 = pos
        .first()
        .ok_or("missing container ID to decommission")?
        .parse()
        .map_err(|_| "container ID must be a number".to_string())?;
    let headers = admin_headers(flags)?;
    let hdrs: Vec<(&str, &str)> =
        headers.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let client = HttpClient::new(addr);
    let resp = client
        .post(&format!("/admin/decommission/{id}"), &hdrs, &[])
        .map_err(|e| e.to_string())?;
    println!("{}", String::from_utf8_lossy(&resp.body));
    if resp.status == 200 {
        Ok(())
    } else {
        Err(format!("decommission failed: {}", resp.status))
    }
}

/// Cancel a stopped drain: the container rejoins the placement pool.
fn undrain(flags: &HashMap<String, String>, pos: &[String]) -> Result<(), String> {
    let addr = need(flags, "addr")?;
    let id: u32 = pos
        .first()
        .ok_or("missing container ID to undrain")?
        .parse()
        .map_err(|_| "container ID must be a number".to_string())?;
    let headers = admin_headers(flags)?;
    let hdrs: Vec<(&str, &str)> =
        headers.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let client = HttpClient::new(addr);
    let resp = client
        .post(&format!("/admin/undrain/{id}"), &hdrs, &[])
        .map_err(|e| e.to_string())?;
    println!("{}", String::from_utf8_lossy(&resp.body));
    if resp.status == 200 {
        Ok(())
    } else {
        Err(format!("undrain failed: {}", resp.status))
    }
}

/// Rebalance utilization across the storage network.
fn rebalance(flags: &HashMap<String, String>) -> Result<(), String> {
    let addr = need(flags, "addr")?;
    let headers = admin_headers(flags)?;
    let hdrs: Vec<(&str, &str)> =
        headers.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let mut body_fields = Vec::new();
    if let Some(t) = flags.get("threshold") {
        let t: f64 = t.parse().map_err(|_| "--threshold must be a number".to_string())?;
        body_fields.push(format!("\"threshold\": {t}"));
    }
    if let Some(m) = flags.get("max-moves") {
        let m: u64 = m.parse().map_err(|_| "--max-moves must be a number".to_string())?;
        body_fields.push(format!("\"max_moves\": {m}"));
    }
    let body = format!("{{{}}}", body_fields.join(", "));
    let client = HttpClient::new(addr);
    let resp = client
        .post("/admin/rebalance", &hdrs, body.as_bytes())
        .map_err(|e| e.to_string())?;
    println!("{}", String::from_utf8_lossy(&resp.body));
    if resp.status == 200 {
        Ok(())
    } else {
        Err(format!("rebalance failed: {}", resp.status))
    }
}
