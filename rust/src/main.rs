//! DynoStore CLI: deploy/serve a gateway, and push / pull / exists /
//! evict objects against a running gateway (paper §V's command-line
//! client), plus admin operations.
//!
//! ```text
//! dynostore serve  --config cluster.json --addr 127.0.0.1:8080 --data-dir /var/lib/dynostore
//! dynostore agent  --config agent.json   --addr 127.0.0.1:9100
//! dynostore register --url http://HOST:PORT --user UserA
//! dynostore push   --url http://HOST:PORT --token T [--policy k,n] [--multipart] /UserA/col/name ./file
//! dynostore pull   --url http://HOST:PORT --token T [--version N] [--range A-B] /UserA/col/name [./out]
//! dynostore stat   --url http://HOST:PORT --token T /UserA/col/name
//! dynostore exists --url http://HOST:PORT --token T /UserA/col/name
//! dynostore evict  --url http://HOST:PORT --token T /UserA/col/name
//! dynostore list   --url http://HOST:PORT --token T /UserA/col [--prefix P] [--limit N] [--after NAME]
//! dynostore grant  --url http://HOST:PORT --token T /UserA/col USER read|write
//! dynostore revoke --url http://HOST:PORT --token T /UserA/col USER read|write
//! dynostore admin  --url http://HOST:PORT [--token T] repair|gc|metrics|health
//! dynostore scrub  --url http://HOST:PORT --token T [--sample N]
//! dynostore decommission --url http://HOST:PORT --token T ID
//! dynostore rebalance    --url http://HOST:PORT --token T [--threshold F] [--max-moves N]
//! ```
//!
//! `--addr HOST:PORT` is accepted everywhere `--url` is (legacy
//! spelling). Object commands ride the versioned `/v1` REST surface
//! through [`dynostore::RemoteStore`] — the same code path library
//! clients use — and accept `--key-hex <64 hex chars>` for client-side
//! AES-256-CTR encryption.

use std::collections::HashMap;
use std::sync::Arc;

use dynostore::api::{parse_policy, ListOptions};
use dynostore::json::parse;
use dynostore::metadata::Permission;
use dynostore::net::HttpClient;
use dynostore::{gateway, Client, Config};

fn main() {
    dynostore::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

/// Tiny flag parser: `--key value` pairs + positional arguments.
fn parse_args(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut positional = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), String::new());
                i += 1;
            }
        } else {
            positional.push(args[i].clone());
            i += 1;
        }
    }
    (flags, positional)
}

fn run(args: &[String]) -> Result<(), String> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let (flags, pos) = parse_args(&args[1..]);
    match cmd.as_str() {
        "serve" => serve(&flags),
        "agent" => agent(&flags),
        "register" => register(&flags),
        "push" | "pull" | "stat" | "exists" | "evict" => object_op(cmd, &flags, &pos),
        "list" => list(&flags, &pos),
        "grant" | "revoke" => grant_op(cmd, &flags, &pos),
        "admin" => admin(&flags, &pos),
        "scrub" => scrub(&flags),
        "decommission" => decommission(&flags, &pos),
        "undrain" => undrain(&flags, &pos),
        "rebalance" => rebalance(&flags),
        "tier-cycle" => tier_cycle(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(format!("unknown command '{other}' (try: dynostore help)")),
    }
}

fn print_usage() {
    println!(
        "dynostore — wide-area data distribution over heterogeneous storage\n\
         \n\
         commands:\n\
         \x20 serve    --config FILE [--addr 127.0.0.1:8080] [--workers 8]\n\
         \x20          [--engine pure-rust|swar|swar-parallel|pjrt]\n\
         \x20          [--net-engine reactor|threaded]\n\
         \x20          [--data-dir DIR] [--snapshot-every N] [--meta-shards N]\n\
         \x20          [--max-body-mb MB]\n\
         \x20          [--part-size-mb MB]\n\
         \x20          [--policy k,n|regular|adaptive[:NINES]] [--durability-nines N]\n\
         \x20          (--net-engine picks the connection core: epoll reactor\n\
         \x20           with keep-alive, or the portable threaded loop)\n\
         \x20          (--data-dir persists the metadata plane: WAL + snapshots;\n\
         \x20           a restarted serve recovers every acknowledged object)\n\
         \x20          (--meta-shards runs N independent metadata Paxos groups\n\
         \x20           partitioned by namespace; 1 = legacy single group)\n\
         \x20 agent    --config FILE [--addr 127.0.0.1:9100] [--workers 4]\n\
         \x20          (container agent: serves one data container over HTTP;\n\
         \x20           gateways attach it via an \"endpoint\" container entry)\n\
         \x20 register --url http://HOST:PORT --user NAME\n\
         \x20 push     --url http://HOST:PORT --token T\n\
         \x20          [--policy k,n|regular|adaptive[:NINES]]\n\
         \x20          [--key-hex HEX64] [--multipart] [--part-size-mb MB]\n\
         \x20          [--resume UPLOAD_ID] PATH FILE\n\
         \x20          (--multipart splits FILE into independently striped\n\
         \x20           parts — pushes objects larger than the gateway body cap)\n\
         \x20 pull     --url http://HOST:PORT --token T [--version N] [--range A-B]\n\
         \x20          [--key-hex HEX64] PATH [OUT]\n\
         \x20 stat     --url http://HOST:PORT --token T PATH\n\
         \x20 exists   --url http://HOST:PORT --token T PATH\n\
         \x20 evict    --url http://HOST:PORT --token T PATH\n\
         \x20 list     --url http://HOST:PORT --token T COLLECTION\n\
         \x20          [--prefix P] [--limit N] [--after NAME]\n\
         \x20 grant    --url http://HOST:PORT --token T COLLECTION USER read|write\n\
         \x20 revoke   --url http://HOST:PORT --token T COLLECTION USER read|write\n\
         \x20 admin    --url http://HOST:PORT [--token T] repair|gc|metrics|health\n\
         \x20          (repair/gc need the admin token `serve` prints at startup)\n\
         \x20 scrub    --url http://HOST:PORT --token T [--sample N]\n\
         \x20          (one anti-entropy cycle: verify placed chunks, heal rot;\n\
         \x20           needs the admin token)\n\
         \x20 decommission --url http://HOST:PORT --token T ID\n\
         \x20          (drain container ID: migrate every chunk off, then remove it)\n\
         \x20 undrain  --url http://HOST:PORT --token T ID\n\
         \x20          (cancel a stopped drain: container rejoins placement)\n\
         \x20 rebalance    --url http://HOST:PORT --token T [--threshold F] [--max-moves N]\n\
         \x20          (move chunks hot\u{2192}cold until utilization spread \u{2264} threshold)\n\
         \x20 tier-cycle   --url http://HOST:PORT --token T [--hot-rate F]\n\
         \x20          [--cold-after-secs N] [--max-moves N]\n\
         \x20          (one promotion/demotion pass: hot objects into cache-tier\n\
         \x20           containers, cold ones out; needs the admin token)\n\
         \n\
         PATH is /User/Collection.../name; --addr HOST:PORT is accepted\n\
         wherever --url is. Object commands speak the versioned /v1 REST\n\
         surface and accept [--deadline-ms MS] (request time budget, 504\n\
         past it) and [--retries N] (replay transient failures with\n\
         backoff). See README.md \u{a7}API for the route table and\n\
         examples/ for library usage."
    );
}

fn serve(flags: &HashMap<String, String>) -> Result<(), String> {
    let mut config = match flags.get("config") {
        Some(path) => Config::from_file(path).map_err(|e| e.to_string())?,
        None => {
            dynostore::log_warn!("no --config given; starting an empty default deployment");
            Config::default()
        }
    };
    // CLI override of the config file's GF(2^8) engine knob.
    if let Some(engine) = flags.get("engine") {
        config.engine = dynostore::coordinator::GfEngine::parse(engine).ok_or_else(|| {
            format!("unknown --engine '{engine}' (pure-rust | swar | swar-parallel | pjrt)")
        })?;
    }
    // CLI override of the metadata durability root. Without one (in the
    // config or here) the metadata plane is in-memory and a restart
    // loses it — warn loudly when containers are configured.
    if let Some(dir) = flags.get("data-dir") {
        config.data_dir = Some(dir.clone());
    }
    if let Some(every) = flags.get("snapshot-every") {
        config.snapshot_every = every
            .parse::<u64>()
            .map_err(|_| "--snapshot-every must be a number".to_string())?
            .max(1);
    }
    if let Some(shards) = flags.get("meta-shards") {
        config.meta_shards = shards
            .parse::<usize>()
            .map_err(|_| "--meta-shards must be a number".to_string())?
            .max(1);
    }
    if let Some(cap) = flags.get("max-body-mb") {
        config.max_body_mb = cap
            .parse::<u64>()
            .map_err(|_| "--max-body-mb must be a number".to_string())?
            .max(1);
    }
    if let Some(part) = flags.get("part-size-mb") {
        config.part_size_mb = part
            .parse::<u64>()
            .map_err(|_| "--part-size-mb must be a number".to_string())?
            .max(1);
    }
    // CLI override of the connection core (epoll reactor vs threaded).
    if let Some(engine) = flags.get("net-engine") {
        config.net.engine = dynostore::net::ServerEngine::parse(engine)
            .ok_or_else(|| format!("unknown --net-engine '{engine}' (reactor | threaded)"))?;
    }
    // CLI override of the deployment durability target and the default
    // resilience policy (same spellings as the x-dyno-policy header).
    if let Some(nines) = flags.get("durability-nines") {
        let nines: f64 = nines
            .parse()
            .map_err(|_| "--durability-nines must be a number".to_string())?;
        if !nines.is_finite() || nines <= 0.0 || nines > 12.0 {
            return Err("--durability-nines must be in (0, 12]".to_string());
        }
        config.durability_nines = nines;
        if let dynostore::policy::ResiliencePolicy::Adaptive { nines: n } = &mut config.policy
        {
            *n = nines;
        }
    }
    if let Some(policy) = flags.get("policy") {
        config.policy = parse_policy(policy).map_err(|e| e.to_string())?;
        if let dynostore::policy::ResiliencePolicy::Adaptive { nines } = &mut config.policy {
            if !flags.contains_key("durability-nines") && policy.eq_ignore_ascii_case("adaptive")
            {
                *nines = config.durability_nines;
            }
        }
    }
    if config.data_dir.is_none() {
        dynostore::log_warn!(
            "no data_dir configured: metadata is in-memory and will NOT survive a restart \
             (pass --data-dir DIR or set \"data_dir\" in the config)"
        );
    }
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:8080".into());
    let workers: usize =
        flags.get("workers").and_then(|w| w.parse().ok()).unwrap_or(8);
    let store = config.build().map_err(|e| e.to_string())?;
    if let Some(rec) = store.recovery_report() {
        if rec.recovered() {
            println!(
                "recovered metadata: snapshot {} ({} commits), {} WAL records replayed{}",
                if rec.snapshot_loaded { "loaded" } else { "absent" },
                rec.snapshot_commits,
                rec.wal_replayed,
                if rec.wal_truncated { ", torn tail truncated" } else { "" }
            );
        }
    }
    // The /admin/* routes require the admin scope; hand the operator a
    // token at startup (mintable only deployment-side).
    let admin_token = store.issue_admin_token(30 * 24 * 3600);
    let max_body = usize::try_from(config.max_body_mb.saturating_mul(1 << 20))
        .unwrap_or(usize::MAX);
    let limits = dynostore::net::ServerLimits {
        max_body,
        conn_timeout: std::time::Duration::from_secs(config.conn_timeout_secs),
    };
    let part_size = usize::try_from(config.part_size_mb.saturating_mul(1 << 20))
        .unwrap_or(gateway::DEFAULT_STREAM_PART_SIZE);
    let server = gateway::serve_with_net(
        Arc::clone(&store),
        &addr,
        workers,
        limits,
        part_size,
        config.net.server_options(),
    )
    .map_err(|e| e.to_string())?;
    // Background anti-entropy: a paced scrubber sweeps placements and
    // heals silent corruption when the config enables it.
    let _scrubber = if config.scrub_interval_secs > 0 {
        dynostore::log_info!(
            "scrubber on: every {}s, {} objects per cycle",
            config.scrub_interval_secs,
            config.scrub_sample
        );
        Some(dynostore::coordinator::ScrubberHandle::start(
            Arc::clone(&store),
            std::time::Duration::from_secs(config.scrub_interval_secs),
            config.scrub_sample,
        ))
    } else {
        None
    };
    dynostore::log_info!(
        "dynostore gateway on {} ({} containers, {} metadata shards x {} replicas, \
         policy {:?}, engine {}, net {})",
        server.addr(),
        store.registry.len(),
        store.meta.shard_count(),
        store.meta.replica_count(),
        store.default_policy,
        store.backend_name(),
        server.engine().as_str()
    );
    println!("listening on {}", server.addr());
    println!("admin token (30d, for admin/decommission/undrain/rebalance): {admin_token}");
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Run a standalone container agent: one data container, served over
/// HTTP for remote gateways (paper §III-A's "install the DynoStore
/// agent and provide a configuration file").
fn agent(flags: &HashMap<String, String>) -> Result<(), String> {
    let path = need(flags, "config")?;
    let config = dynostore::config::AgentConfig::from_file(path).map_err(|e| e.to_string())?;
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:9100".into());
    let workers: usize = flags.get("workers").and_then(|w| w.parse().ok()).unwrap_or(4);
    let container = config.build().map_err(|e| e.to_string())?;
    let name = container.name.clone();
    let server = dynostore::container::ContainerServer::serve(container, &addr, workers)
        .map_err(|e| e.to_string())?;
    dynostore::log_info!(
        "dynostore container agent '{}' (id {}) on {} ({:?} backend)",
        name,
        config.id,
        server.addr(),
        config.backend
    );
    println!("agent '{name}' listening on {}", server.addr());
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn need<'a>(flags: &'a HashMap<String, String>, key: &str) -> Result<&'a str, String> {
    flags.get(key).map(|s| s.as_str()).ok_or_else(|| format!("missing --{key}"))
}

/// `--url http://HOST:PORT` (preferred) or the legacy `--addr HOST:PORT`.
fn endpoint(flags: &HashMap<String, String>) -> Result<&str, String> {
    flags
        .get("url")
        .or_else(|| flags.get("addr"))
        .map(|s| s.as_str())
        .ok_or_else(|| "missing --url (or --addr)".to_string())
}

/// [`endpoint`] normalized to a bare `HOST:PORT` for raw
/// [`HttpClient`] use (RemoteStore does its own normalization).
fn host(flags: &HashMap<String, String>) -> Result<&str, String> {
    Ok(endpoint(flags)?.trim().trim_start_matches("http://").trim_end_matches('/'))
}

/// A [`Client`] over the gateway's `/v1` surface, honoring `--key-hex`
/// (client-side AES-256-CTR) and `--policy` (per-push resilience).
fn remote_client(flags: &HashMap<String, String>) -> Result<Client, String> {
    let url = endpoint(flags)?;
    let token = need(flags, "token")?;
    let mut client = Client::remote(url, token);
    if let Some(hex) = flags.get("key-hex") {
        let bytes = dynostore::util::from_hex(hex)
            .ok_or_else(|| "--key-hex must be hex".to_string())?;
        let key: [u8; 32] = bytes
            .try_into()
            .map_err(|_| "--key-hex must be 64 hex chars (32 bytes)".to_string())?;
        client = client.with_encryption(key);
    }
    if let Some(policy) = flags.get("policy") {
        client = client.with_policy(parse_policy(policy).map_err(|e| e.to_string())?);
    }
    if let Some(ms) = flags.get("deadline-ms") {
        client = client.with_deadline_ms(
            ms.parse().map_err(|_| "--deadline-ms must be a number".to_string())?,
        );
    }
    if let Some(n) = flags.get("retries") {
        let attempts: u32 =
            n.parse().map_err(|_| "--retries must be a number (total attempts)".to_string())?;
        client = client.with_retries(dynostore::resilience::RetryPolicy {
            max_attempts: attempts.max(1),
            ..dynostore::resilience::RetryPolicy::standard()
        });
    }
    Ok(client)
}

/// Split `/User/Collection.../name` into (collection, name).
fn split_path(path: &str) -> Result<(&str, &str), String> {
    let idx = path.rfind('/').ok_or_else(|| format!("bad PATH '{path}'"))?;
    let (collection, name) = (&path[..idx], &path[idx + 1..]);
    if collection.is_empty() || name.is_empty() {
        return Err(format!("bad PATH '{path}' (want /User/Collection.../name)"));
    }
    Ok((collection, name))
}

fn register(flags: &HashMap<String, String>) -> Result<(), String> {
    let addr = host(flags)?;
    let user = need(flags, "user")?;
    let client = HttpClient::new(addr);
    let body =
        dynostore::json::to_string(&dynostore::json::obj(vec![("user", user.into())]));
    let resp = client
        .post("/auth/register", &[], body.as_bytes())
        .map_err(|e| e.to_string())?;
    let body = String::from_utf8_lossy(&resp.body).to_string();
    if resp.status != 201 {
        return Err(format!("register failed ({}): {body}", resp.status));
    }
    let token = parse(&body)
        .map_err(|e| e.to_string())?
        .req_str("token")
        .map_err(|e| e.to_string())?
        .to_string();
    println!("{token}");
    Ok(())
}

fn object_op(
    cmd: &str,
    flags: &HashMap<String, String>,
    pos: &[String],
) -> Result<(), String> {
    let client = remote_client(flags)?;
    let path = pos.first().ok_or("missing object PATH")?;
    let (collection, name) = split_path(path)?;
    match cmd {
        "push" => {
            let file = pos.get(1).ok_or("missing FILE to push")?;
            let data = std::fs::read(file).map_err(|e| e.to_string())?;
            // `--multipart` splits the payload into independently striped
            // parts (S3-style), so objects larger than the gateway's
            // request-body cap still go through; `--resume UPLOAD_ID`
            // continues an interrupted one, skipping recorded parts.
            if flags.contains_key("multipart") || flags.contains_key("resume") {
                let part_mb: u64 = match flags.get("part-size-mb") {
                    Some(p) => p
                        .parse()
                        .map_err(|_| "--part-size-mb must be a number".to_string())?,
                    None => (gateway::DEFAULT_STREAM_PART_SIZE >> 20) as u64,
                };
                let part_size = usize::try_from(part_mb.max(1).saturating_mul(1 << 20))
                    .unwrap_or(gateway::DEFAULT_STREAM_PART_SIZE);
                let report = match flags.get("resume") {
                    Some(id) => client
                        .resume_multipart(collection, name, id, &data, part_size)
                        .map_err(|e| e.to_string())?,
                    None => client
                        .push_multipart(collection, name, &data, part_size)
                        .map_err(|e| e.to_string())?,
                };
                println!(
                    "pushed {path}: version {} uuid {} etag {} ({} bytes, {} parts, \
                     {} skipped, {:.3}s)",
                    report.info.version,
                    report.info.uuid,
                    report.info.etag,
                    data.len(),
                    report.parts,
                    report.parts_skipped,
                    report.seconds
                );
                return Ok(());
            }
            let (info, seconds) =
                client.push_info(collection, name, &data).map_err(|e| e.to_string())?;
            println!(
                "pushed {path}: version {} uuid {} etag {} ({} bytes, {seconds:.3}s)",
                info.version,
                info.uuid,
                info.etag,
                data.len()
            );
            Ok(())
        }
        "pull" => {
            let version: Option<u64> = match flags.get("version") {
                Some(v) => {
                    Some(v.parse().map_err(|_| "--version must be a number".to_string())?)
                }
                None => None,
            };
            let data = match (flags.get("range"), version) {
                (Some(range), _) => {
                    let (a, b) = range
                        .split_once('-')
                        .ok_or_else(|| "--range must be A-B (bytes, inclusive)".to_string())?;
                    let a: u64 = a.parse().map_err(|_| "bad range start".to_string())?;
                    let b: u64 = b.parse().map_err(|_| "bad range end".to_string())?;
                    if version.is_some() {
                        return Err("--range with --version is not supported yet".into());
                    }
                    client.pull_range(collection, name, a, b).map_err(|e| e.to_string())?.0
                }
                (None, Some(v)) => {
                    client.pull_version(collection, name, v).map_err(|e| e.to_string())?.0
                }
                (None, None) => client.pull(collection, name).map_err(|e| e.to_string())?.0,
            };
            match pos.get(1) {
                Some(out) => {
                    std::fs::write(out, &data).map_err(|e| e.to_string())?;
                    println!("wrote {} bytes to {out}", data.len());
                }
                None => {
                    use std::io::Write;
                    std::io::stdout().write_all(&data).map_err(|e| e.to_string())?;
                }
            }
            Ok(())
        }
        "stat" => {
            let info = client.stat(collection, name).map_err(|e| e.to_string())?;
            println!(
                "{path}: version {} size {} etag {} uuid {} created {}",
                info.version, info.size, info.etag, info.uuid, info.created_at
            );
            Ok(())
        }
        "exists" => {
            let exists = client.exists(collection, name).map_err(|e| e.to_string())?;
            println!("{}", if exists { "true" } else { "false" });
            Ok(())
        }
        "evict" => {
            let deleted = client.evict(collection, name).map_err(|e| e.to_string())?;
            println!("evicted {path} ({deleted} chunks deleted)");
            Ok(())
        }
        _ => unreachable!(),
    }
}

/// Paginated collection listing over `/v1/collections`.
fn list(flags: &HashMap<String, String>, pos: &[String]) -> Result<(), String> {
    let client = remote_client(flags)?;
    let collection = pos.first().ok_or("missing COLLECTION path")?;
    let opts = ListOptions {
        prefix: flags.get("prefix").cloned().unwrap_or_default(),
        after: flags.get("after").cloned(),
        limit: match flags.get("limit") {
            Some(l) => l.parse().map_err(|_| "--limit must be a number".to_string())?,
            None => 0,
        },
    };
    let page = client.list(collection, &opts).map_err(|e| e.to_string())?;
    for o in &page.objects {
        println!("{}\tv{}\t{} bytes\t{}", o.name, o.version, o.size, o.etag);
    }
    if let Some(after) = page.next_after {
        println!("# truncated; continue with --after {after}");
    }
    Ok(())
}

/// Grant / revoke a permission on a collection.
fn grant_op(
    cmd: &str,
    flags: &HashMap<String, String>,
    pos: &[String],
) -> Result<(), String> {
    let client = remote_client(flags)?;
    let collection = pos.first().ok_or("missing COLLECTION path")?;
    let user = pos.get(1).ok_or("missing USER")?;
    let perm = Permission::parse(pos.get(2).ok_or("missing PERM (read|write)")?.as_str())
        .map_err(|e| e.to_string())?;
    if cmd == "grant" {
        client.grant(collection, user, perm).map_err(|e| e.to_string())?;
        println!("granted {} on {collection} to {user}", perm.as_str());
    } else {
        client.revoke(collection, user, perm).map_err(|e| e.to_string())?;
        println!("revoked {} on {collection} from {user}", perm.as_str());
    }
    Ok(())
}

/// `Authorization` header for admin-gated endpoints (`--token`).
fn admin_headers(flags: &HashMap<String, String>) -> Result<Vec<(String, String)>, String> {
    let token = need(flags, "token")?;
    Ok(vec![("authorization".to_string(), format!("Bearer {token}"))])
}

fn admin(flags: &HashMap<String, String>, pos: &[String]) -> Result<(), String> {
    let addr = host(flags)?;
    let action = pos.first().map(|s| s.as_str()).unwrap_or("metrics");
    let client = HttpClient::new(addr);
    let resp = match action {
        // repair/gc mutate the deployment: the gateway requires a token.
        "repair" | "gc" => {
            let headers = admin_headers(flags)?;
            let hdrs: Vec<(&str, &str)> =
                headers.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            client.post(&format!("/admin/{action}"), &hdrs, &[])
        }
        "metrics" => client.get("/metrics", &[]),
        "health" => client.get("/health", &[]),
        other => return Err(format!("unknown admin action '{other}'")),
    }
    .map_err(|e| e.to_string())?;
    println!("{}", String::from_utf8_lossy(&resp.body));
    Ok(())
}

/// Run one scrub cycle on the deployment: sample placements, verify
/// every placed chunk end-to-end, heal what rotted (`POST /admin/scrub`,
/// admin token required).
fn scrub(flags: &HashMap<String, String>) -> Result<(), String> {
    let addr = host(flags)?;
    let headers = admin_headers(flags)?;
    let hdrs: Vec<(&str, &str)> =
        headers.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let body = match flags.get("sample") {
        Some(n) => {
            let n: u64 = n.parse().map_err(|_| "--sample must be a number".to_string())?;
            format!("{{\"sample\": {n}}}")
        }
        None => String::from("{}"),
    };
    let client = HttpClient::new(addr);
    let resp = client
        .post("/admin/scrub", &hdrs, body.as_bytes())
        .map_err(|e| e.to_string())?;
    println!("{}", String::from_utf8_lossy(&resp.body));
    if resp.status == 200 {
        Ok(())
    } else {
        Err(format!("scrub failed: {}", resp.status))
    }
}

/// Drain a container out of the storage network and remove it.
fn decommission(flags: &HashMap<String, String>, pos: &[String]) -> Result<(), String> {
    let addr = host(flags)?;
    let id: u32 = pos
        .first()
        .ok_or("missing container ID to decommission")?
        .parse()
        .map_err(|_| "container ID must be a number".to_string())?;
    let headers = admin_headers(flags)?;
    let hdrs: Vec<(&str, &str)> =
        headers.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let client = HttpClient::new(addr);
    let resp = client
        .post(&format!("/admin/decommission/{id}"), &hdrs, &[])
        .map_err(|e| e.to_string())?;
    println!("{}", String::from_utf8_lossy(&resp.body));
    if resp.status == 200 {
        Ok(())
    } else {
        Err(format!("decommission failed: {}", resp.status))
    }
}

/// Cancel a stopped drain: the container rejoins the placement pool.
fn undrain(flags: &HashMap<String, String>, pos: &[String]) -> Result<(), String> {
    let addr = host(flags)?;
    let id: u32 = pos
        .first()
        .ok_or("missing container ID to undrain")?
        .parse()
        .map_err(|_| "container ID must be a number".to_string())?;
    let headers = admin_headers(flags)?;
    let hdrs: Vec<(&str, &str)> =
        headers.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let client = HttpClient::new(addr);
    let resp = client
        .post(&format!("/admin/undrain/{id}"), &hdrs, &[])
        .map_err(|e| e.to_string())?;
    println!("{}", String::from_utf8_lossy(&resp.body));
    if resp.status == 200 {
        Ok(())
    } else {
        Err(format!("undrain failed: {}", resp.status))
    }
}

/// One storage-tiering pass: promote hot objects into cache-tier
/// containers, demote cold ones out (`POST /admin/tier-cycle`, admin
/// token required).
fn tier_cycle(flags: &HashMap<String, String>) -> Result<(), String> {
    let addr = host(flags)?;
    let headers = admin_headers(flags)?;
    let hdrs: Vec<(&str, &str)> =
        headers.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let mut body_fields = Vec::new();
    if let Some(r) = flags.get("hot-rate") {
        let r: f64 = r.parse().map_err(|_| "--hot-rate must be a number".to_string())?;
        body_fields.push(format!("\"hot_rate\": {r}"));
    }
    if let Some(s) = flags.get("cold-after-secs") {
        let s: u64 =
            s.parse().map_err(|_| "--cold-after-secs must be a number".to_string())?;
        body_fields.push(format!("\"cold_after_secs\": {s}"));
    }
    if let Some(m) = flags.get("max-moves") {
        let m: u64 = m.parse().map_err(|_| "--max-moves must be a number".to_string())?;
        body_fields.push(format!("\"max_moves\": {m}"));
    }
    let body = format!("{{{}}}", body_fields.join(", "));
    let client = HttpClient::new(addr);
    let resp = client
        .post("/admin/tier-cycle", &hdrs, body.as_bytes())
        .map_err(|e| e.to_string())?;
    println!("{}", String::from_utf8_lossy(&resp.body));
    if resp.status == 200 {
        Ok(())
    } else {
        Err(format!("tier-cycle failed: {}", resp.status))
    }
}

/// Rebalance utilization across the storage network.
fn rebalance(flags: &HashMap<String, String>) -> Result<(), String> {
    let addr = host(flags)?;
    let headers = admin_headers(flags)?;
    let hdrs: Vec<(&str, &str)> =
        headers.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    let mut body_fields = Vec::new();
    if let Some(t) = flags.get("threshold") {
        let t: f64 = t.parse().map_err(|_| "--threshold must be a number".to_string())?;
        body_fields.push(format!("\"threshold\": {t}"));
    }
    if let Some(m) = flags.get("max-moves") {
        let m: u64 = m.parse().map_err(|_| "--max-moves must be a number".to_string())?;
        body_fields.push(format!("\"max_moves\": {m}"));
    }
    let body = format!("{{{}}}", body_fields.join(", "));
    let client = HttpClient::new(addr);
    let resp = client
        .post("/admin/rebalance", &hdrs, body.as_bytes())
        .map_err(|e| e.to_string())?;
    println!("{}", String::from_utf8_lossy(&resp.body));
    if resp.status == 200 {
        Ok(())
    } else {
        Err(format!("rebalance failed: {}", resp.status))
    }
}
