//! The DynoStore client (paper §V): push / pull / exists / evict against
//! a deployment, usable as a library (this module) or through the CLI in
//! `main.rs`. Since the PR-5 API redesign the client is
//! **transport-agnostic**: it drives any [`ObjectStore`] backend —
//! in-process ([`LocalStore`], the historical behavior) or a gateway's
//! `/v1` REST surface over HTTP ([`RemoteStore`]) — with identical
//! results. On top of the backend it adds the two client-side features
//! of the paper:
//!
//! * **Parallel channels** (§VI-C4, Fig. 7): workloads of many objects
//!   are spread over T concurrent channels; each channel is a thread
//!   sharing the client's WAN link (the flow-sharing term in
//!   [`crate::sim::Wan`] models the contention for local backends).
//! * **Point-to-point confidentiality** (§IV-E2): optional AES-256-CTR
//!   encryption before upload; the nonce is derived from the object
//!   path, **the version the upload will create**, and the name's
//!   persisted eviction generation (nonce epoch), so re-pushing a name
//!   never reuses a (key, nonce) pair across distinct plaintexts —
//!   even after `evict` resets the version chain.
//!
//! The client also speaks the resilience plane: an optional per-request
//! [`Deadline`] (propagated to the gateway as `x-dyno-deadline-ms`) and
//! an optional [`RetryPolicy`] replaying transient failures with
//! budget-capped backoff.

use std::sync::Arc;

use crate::api::{
    ListOptions, LocalStore, ObjectInfo, ObjectListing, ObjectStore, PullOptions,
    PushOptions, RemoteStore,
};
use crate::coordinator::{
    DecommissionReport, DynoStore, PullOpts, PullReport, PushOpts, PushReport, RangeReport,
    RebalanceOpts, RebalanceReport,
};
use crate::crypto::{sha3_256, AesCtr};
use crate::metadata::Permission;
use crate::policy::ResiliencePolicy;
use crate::resilience::{Deadline, RetryPolicy};
use crate::sim::Site;
use crate::{Error, Result};

/// Client-side encryption configuration.
#[derive(Clone)]
pub struct Encryption {
    key: [u8; 32],
}

impl Encryption {
    pub fn new(key: [u8; 32]) -> Self {
        Encryption { key }
    }

    /// Derive a per-object-version nonce from the logical path, the
    /// version salt, and the name's eviction generation. The salt is
    /// the object's version number (monotonic per name, never reused
    /// across GC), so every re-push of a name gets a fresh keystream
    /// (CTR nonce reuse across distinct plaintexts leaks their XOR).
    ///
    /// The epoch closes the last reuse window: `evict` deletes a name's
    /// whole version chain, so a later push of the *same name* restarts
    /// at version 0 — the server now persists a per-name nonce epoch
    /// (bumped on every evict, surviving GC and snapshots) and stamps it
    /// on each version, and mixing it here keeps the re-push's
    /// keystream disjoint from the evicted generation's. Epoch 0 is
    /// encoded as *absence* (no bytes appended), so every object
    /// written before epochs existed — necessarily generation 0 —
    /// still derives its historical nonce and decrypts unchanged.
    fn nonce_for(
        &self,
        collection: &str,
        name: &str,
        version_salt: u64,
        epoch: u64,
    ) -> [u8; 16] {
        let mut buf = Vec::new();
        buf.extend_from_slice(collection.as_bytes());
        buf.push(0);
        buf.extend_from_slice(name.as_bytes());
        buf.extend_from_slice(&version_salt.to_le_bytes());
        if epoch > 0 {
            buf.extend_from_slice(&epoch.to_le_bytes());
        }
        let h = sha3_256(&buf);
        h[..16].try_into().unwrap()
    }
}

/// Result of a multipart upload driven by the client.
#[derive(Debug, Clone)]
pub struct MultipartReport {
    /// Metadata of the committed object version.
    pub info: ObjectInfo,
    pub upload_id: String,
    /// Total parts the object was assembled from.
    pub parts: usize,
    /// Parts an earlier interrupted attempt had already recorded with a
    /// matching etag — skipped instead of re-uploaded (resume).
    pub parts_skipped: usize,
    /// Wallclock seconds for the whole upload (parts + complete).
    pub seconds: f64,
}

/// Aggregate result of a multi-object client workload.
#[derive(Debug, Clone, Default)]
pub struct BatchReport {
    pub objects: usize,
    pub bytes: u64,
    /// Time for the whole batch. Local backends model Fig. 7's parallel
    /// channels in simulated time (sum over rounds of each round's
    /// slowest request). Remote backends issue requests sequentially on
    /// this thread today, so this is the measured total (sum of request
    /// seconds) — real wire parallelism is future work.
    pub sim_s: f64,
    /// Mean seconds per request.
    pub mean_request_s: f64,
}

/// A client bound to a deployment (through any [`ObjectStore`]
/// backend), a site, and (optionally) a cipher.
pub struct Client {
    store: Arc<dyn ObjectStore>,
    /// Present when the backend is in-process (the same `LocalStore`
    /// `store` points at — one source of truth for deployment and
    /// credentials): unlocks report-level telemetry (`push_report` /
    /// `pull_report`) and admin operations.
    local: Option<Arc<LocalStore>>,
    pub site: Site,
    encryption: Option<Encryption>,
    pub policy: Option<ResiliencePolicy>,
    /// Transient-failure replay policy; [`RetryPolicy::none`] (a single
    /// attempt) by default so historical behavior is unchanged.
    retry: RetryPolicy,
    /// Per-operation time budget in ms; `None` = unbounded. Each
    /// operation starts a fresh [`Deadline`] from this budget.
    deadline_ms: Option<u64>,
}

impl Client {
    /// In-process client (the historical constructor): operations go
    /// straight to the coordinator, with simulated wide-area timing.
    pub fn new(store: Arc<DynoStore>, token: String, site: Site) -> Self {
        let local = Arc::new(LocalStore::new(store, token, site));
        Client {
            store: Arc::clone(&local) as Arc<dyn ObjectStore>,
            local: Some(local),
            site,
            encryption: None,
            policy: None,
            retry: RetryPolicy::none(),
            deadline_ms: None,
        }
    }

    /// Wide-area client: the same operations over a gateway's `/v1`
    /// REST surface. `url` is `http://host:port` (or bare `host:port`),
    /// `token` a gateway bearer token.
    pub fn remote(url: &str, token: &str) -> Self {
        Client {
            store: Arc::new(RemoteStore::connect(url, token)),
            local: None,
            site: Site::Madrid,
            encryption: None,
            policy: None,
            retry: RetryPolicy::none(),
            deadline_ms: None,
        }
    }

    /// [`Client::remote`] with keep-alive connection pooling disabled:
    /// every request dials a fresh connection. The connect-per-request
    /// baseline for differential tests and `benches/net_concurrency.rs`.
    pub fn remote_unpooled(url: &str, token: &str) -> Self {
        Client {
            store: Arc::new(RemoteStore::connect(url, token).without_pool()),
            ..Self::remote(url, token)
        }
    }

    /// A client over any [`ObjectStore`] backend.
    pub fn over(store: Arc<dyn ObjectStore>, site: Site) -> Self {
        Client {
            store,
            local: None,
            site,
            encryption: None,
            policy: None,
            retry: RetryPolicy::none(),
            deadline_ms: None,
        }
    }

    pub fn with_encryption(mut self, key: [u8; 32]) -> Self {
        self.encryption = Some(Encryption::new(key));
        self
    }

    pub fn with_policy(mut self, policy: ResiliencePolicy) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Replay transient failures (`Unavailable` / `Net` / `Io`) under
    /// `policy`'s attempt, sleep-budget, and deadline caps. Pushes are
    /// re-prepared per attempt (the nonce salt is re-derived), so an
    /// attempt that applied server-side before its response was lost
    /// yields a correctly-encrypted duplicate version, never a
    /// nonce-mismatched one.
    pub fn with_retries(mut self, policy: RetryPolicy) -> Self {
        self.retry = policy;
        self
    }

    /// Give every operation a time budget of `ms` milliseconds. Local
    /// backends thread it through the coordinator (which checks it at
    /// every hop); remote backends send it as `x-dyno-deadline-ms` so
    /// the gateway enforces the same cutoff. Expired budgets surface as
    /// [`Error::Timeout`] (HTTP 504).
    pub fn with_deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    /// A fresh per-operation deadline from the configured budget.
    fn op_deadline(&self) -> Deadline {
        self.deadline_ms.map(Deadline::in_ms).unwrap_or_default()
    }

    /// Transport label of the backend (`"local"`, `"http"`).
    pub fn transport(&self) -> &'static str {
        self.store.transport()
    }

    fn local(&self) -> Result<&Arc<DynoStore>> {
        self.local.as_ref().map(|l| l.deployment()).ok_or_else(|| {
            Error::Invalid(
                "this operation needs an in-process deployment (Client::new), \
                 not a remote backend"
                    .into(),
            )
        })
    }

    /// The in-process backend's bearer token (report-level operations
    /// reuse the exact credentials the trait backend holds).
    fn local_token(&self) -> Result<String> {
        self.local.as_ref().map(|l| l.token().to_string()).ok_or_else(|| {
            Error::Invalid("report operations need a local backend".into())
        })
    }

    /// The `(version, nonce_epoch)` salt pair the next push of
    /// `(collection, name)` will create — the encryption nonce inputs.
    /// When the name has live versions, both ride on `stat`; when it
    /// doesn't (first push, or a re-push after `evict`), the persisted
    /// eviction generation is queried on its own. Subject to the usual
    /// read-then-write race under concurrent pushers of the *same
    /// encrypted name*; uploads remain immutable versions either way.
    fn next_nonce_salt(&self, collection: &str, name: &str) -> Result<(u64, u64)> {
        match self.store.stat(collection, name, None) {
            Ok(info) => Ok((info.version + 1, info.nonce_epoch)),
            Err(Error::NotFound(_)) => {
                Ok((0, self.store.nonce_epoch(collection, name)?))
            }
            Err(e) => Err(e),
        }
    }

    /// Encrypt (when configured) the payload for the version this push
    /// will create.
    fn outbound_payload(&self, collection: &str, name: &str, data: &[u8]) -> Result<Vec<u8>> {
        match &self.encryption {
            None => Ok(data.to_vec()),
            Some(enc) => {
                let (salt, epoch) = self.next_nonce_salt(collection, name)?;
                let mut buf = data.to_vec();
                AesCtr::new(&enc.key, &enc.nonce_for(collection, name, salt, epoch))
                    .apply(&mut buf);
                Ok(buf)
            }
        }
    }

    /// Decrypt (when configured) `data` of the given object version,
    /// starting at stream `offset` (non-zero for range reads).
    fn decrypt_inbound(
        &self,
        collection: &str,
        name: &str,
        version: u64,
        epoch: u64,
        offset: u64,
        data: &mut [u8],
    ) {
        if let Some(enc) = &self.encryption {
            AesCtr::new(&enc.key, &enc.nonce_for(collection, name, version, epoch))
                .apply_at(data, offset);
        }
    }

    /// Deterministic per-object retry seed (decorrelated-jitter streams
    /// differ across objects but replay exactly for a given name).
    fn retry_seed(collection: &str, name: &str) -> u64 {
        let mut buf = Vec::with_capacity(collection.len() + name.len() + 1);
        buf.extend_from_slice(collection.as_bytes());
        buf.push(0);
        buf.extend_from_slice(name.as_bytes());
        let h = sha3_256(&buf);
        u64::from_le_bytes(h[..8].try_into().unwrap())
    }

    /// Upload one object. Returns the request seconds (simulated for
    /// local backends, measured for remote).
    pub fn push(&self, collection: &str, name: &str, data: &[u8]) -> Result<f64> {
        self.push_flows(collection, name, data, 1)
    }

    /// Upload one object and return the created version's metadata
    /// (uuid, version, ETag) alongside the request seconds — what the
    /// backend already reports, without a follow-up `stat` that could
    /// observe someone else's concurrent push.
    pub fn push_info(
        &self,
        collection: &str,
        name: &str,
        data: &[u8],
    ) -> Result<(ObjectInfo, f64)> {
        let deadline = self.op_deadline();
        let out = self.retry.run(Self::retry_seed(collection, name), deadline, |_| {
            // Re-prepared per attempt: the nonce salt is re-derived, so
            // a lost-response retry never encrypts under a stale salt.
            let payload = self.outbound_payload(collection, name, data)?;
            self.store.push(
                collection,
                name,
                &payload,
                &PushOptions { policy: self.policy, flows: 1, deadline },
            )
        })?;
        Ok((out.info, out.seconds))
    }

    fn push_flows(&self, collection: &str, name: &str, data: &[u8], flows: u32) -> Result<f64> {
        let deadline = self.op_deadline();
        let out = self.retry.run(Self::retry_seed(collection, name), deadline, |_| {
            let payload = self.outbound_payload(collection, name, data)?;
            self.store.push(
                collection,
                name,
                &payload,
                &PushOptions { policy: self.policy, flows, deadline },
            )
        })?;
        Ok(out.seconds)
    }

    /// Download one object (decrypting if the client has a key).
    pub fn pull(&self, collection: &str, name: &str) -> Result<(Vec<u8>, f64)> {
        self.pull_flows(collection, name, 1)
    }

    fn pull_flows(&self, collection: &str, name: &str, flows: u32) -> Result<(Vec<u8>, f64)> {
        let deadline = self.op_deadline();
        let mut out = self.retry.run(Self::retry_seed(collection, name), deadline, |_| {
            self.store.pull(collection, name, &PullOptions { version: None, flows, deadline })
        })?;
        self.decrypt_inbound(
            collection,
            name,
            out.info.version,
            out.info.nonce_epoch,
            0,
            &mut out.data,
        );
        Ok((out.data, out.seconds))
    }

    /// Download a pinned historical version (paper §IV-B rollback; the
    /// `/v1` `?version=` pin). Decrypts with that version's nonce.
    pub fn pull_version(
        &self,
        collection: &str,
        name: &str,
        version: u64,
    ) -> Result<(Vec<u8>, f64)> {
        let deadline = self.op_deadline();
        let mut out = self.retry.run(Self::retry_seed(collection, name), deadline, |_| {
            self.store.pull(
                collection,
                name,
                &PullOptions { version: Some(version), flows: 1, deadline },
            )
        })?;
        self.decrypt_inbound(
            collection,
            name,
            out.info.version,
            out.info.nonce_epoch,
            0,
            &mut out.data,
        );
        Ok((out.data, out.seconds))
    }

    /// Download exactly `object[start..=end]` (end clamped to the
    /// object size) without transferring the rest — served by the
    /// coordinator's partial-read fast path when the covering
    /// systematic chunks are healthy. CTR keystream seeking decrypts
    /// the slice in place for encrypted clients.
    pub fn pull_range(
        &self,
        collection: &str,
        name: &str,
        start: u64,
        end: u64,
    ) -> Result<(Vec<u8>, f64)> {
        let deadline = self.op_deadline();
        let mut out = self.retry.run(Self::retry_seed(collection, name), deadline, |_| {
            self.store.pull_range(
                collection,
                name,
                start,
                end,
                &PullOptions { version: None, flows: 1, deadline },
            )
        })?;
        self.decrypt_inbound(
            collection,
            name,
            out.info.version,
            out.info.nonce_epoch,
            start,
            &mut out.data,
        );
        Ok((out.data, out.seconds))
    }

    /// Upload one object through an S3-style multipart upload: the
    /// payload is split into `part_size`-byte parts, each independently
    /// striped and placed (and independently retried under the client's
    /// [`RetryPolicy`]), then assembled atomically. This is the path
    /// for objects larger than the gateway's request-body cap — each
    /// part is its own request, so only `part_size` must fit under it.
    ///
    /// Encryption (when configured) is applied to the whole payload
    /// once, exactly as a single-shot push would; parts are contiguous
    /// slices of that ciphertext, so pulls decrypt identically.
    pub fn push_multipart(
        &self,
        collection: &str,
        name: &str,
        data: &[u8],
        part_size: usize,
    ) -> Result<MultipartReport> {
        let deadline = self.op_deadline();
        let payload = self.prepare_multipart(collection, name, data, part_size, deadline)?;
        let upload_id = self
            .retry
            .run(Self::retry_seed(collection, name), deadline, |_| {
                self.store.multipart_init(collection, name)
            })?;
        self.multipart_send(collection, name, &upload_id, &payload, part_size, deadline)
    }

    /// Resume an interrupted multipart upload: parts the server already
    /// recorded with a matching etag are skipped; missing or mismatched
    /// parts are (re-)uploaded; then the upload completes. `data` and
    /// `part_size` must be the ones the upload was started with.
    pub fn resume_multipart(
        &self,
        collection: &str,
        name: &str,
        upload_id: &str,
        data: &[u8],
        part_size: usize,
    ) -> Result<MultipartReport> {
        let deadline = self.op_deadline();
        let payload = self.prepare_multipart(collection, name, data, part_size, deadline)?;
        self.multipart_send(collection, name, upload_id, &payload, part_size, deadline)
    }

    /// Abort an in-progress multipart upload, garbage-collecting the
    /// chunks of every recorded part; returns how many parts were
    /// collected.
    pub fn abort_multipart(
        &self,
        collection: &str,
        name: &str,
        upload_id: &str,
    ) -> Result<usize> {
        self.store.multipart_abort(collection, name, upload_id)
    }

    fn prepare_multipart(
        &self,
        collection: &str,
        name: &str,
        data: &[u8],
        part_size: usize,
        deadline: Deadline,
    ) -> Result<Vec<u8>> {
        deadline.check("multipart push")?;
        if part_size == 0 {
            return Err(Error::Invalid("part size must be positive".into()));
        }
        if data.is_empty() {
            return Err(Error::Invalid(
                "multipart upload needs a non-empty payload (use push for empty objects)"
                    .into(),
            ));
        }
        self.outbound_payload(collection, name, data)
    }

    fn multipart_send(
        &self,
        collection: &str,
        name: &str,
        upload_id: &str,
        payload: &[u8],
        part_size: usize,
        deadline: Deadline,
    ) -> Result<MultipartReport> {
        let t0 = crate::util::now_ns();
        // What the server already holds, for resume: matching etags are
        // skipped, mismatches are replaced.
        let recorded = self.store.multipart_parts(collection, name, upload_id)?;
        let mut have: std::collections::HashMap<u32, String> =
            recorded.parts.iter().map(|p| (p.number, p.etag.clone())).collect();
        let mut skipped = 0usize;
        let mut number = 0u32;
        for part in payload.chunks(part_size) {
            number += 1;
            let etag = crate::util::to_hex(&sha3_256(part));
            if have.remove(&number).is_some_and(|recorded| recorded == etag) {
                skipped += 1;
                continue;
            }
            let opts = PushOptions { policy: self.policy, flows: 1, deadline };
            self.retry.run(
                Self::retry_seed(collection, name) ^ u64::from(number),
                deadline,
                |_| self.store.multipart_put(collection, name, upload_id, number, part, &opts),
            )?;
        }
        // Stale parts past this payload's count would be assembled into
        // the object by complete; refuse rather than commit corruption
        // (a changed part size between attempts gets here).
        if !have.is_empty() {
            return Err(Error::Invalid(format!(
                "upload {upload_id} holds {} recorded part(s) beyond this payload \
                 (different part size?); abort it and push again",
                have.len()
            )));
        }
        let info = self.retry.run(Self::retry_seed(collection, name), deadline, |_| {
            self.store.multipart_complete(collection, name, upload_id)
        })?;
        Ok(MultipartReport {
            info,
            upload_id: upload_id.to_string(),
            parts: number as usize,
            parts_skipped: skipped,
            seconds: (crate::util::now_ns() - t0) as f64 / 1e9,
        })
    }

    /// Object metadata without data-plane traffic (size, version, ETag).
    pub fn stat(&self, collection: &str, name: &str) -> Result<ObjectInfo> {
        self.store.stat(collection, name, None)
    }

    pub fn exists(&self, collection: &str, name: &str) -> Result<bool> {
        self.store.exists(collection, name)
    }

    pub fn evict(&self, collection: &str, name: &str) -> Result<usize> {
        self.store.delete(collection, name)
    }

    /// Paginated listing of a collection.
    pub fn list(&self, collection: &str, opts: &ListOptions) -> Result<ObjectListing> {
        self.store.list(collection, opts)
    }

    /// Grant `perm` on a collection to another user (owner-only).
    pub fn grant(&self, collection: &str, user: &str, perm: Permission) -> Result<()> {
        self.store.grant(collection, user, perm)
    }

    /// Revoke a direct grant.
    pub fn revoke(&self, collection: &str, user: &str, perm: Permission) -> Result<()> {
        self.store.revoke(collection, user, perm)
    }

    /// Upload one object and return the coordinator's full report —
    /// per-chunk transport labels and timings included. Requires an
    /// in-process backend (reports don't travel over the wire).
    pub fn push_report(&self, collection: &str, name: &str, data: &[u8]) -> Result<PushReport> {
        let payload = self.outbound_payload(collection, name, data)?;
        let token = self.local_token()?;
        self.local()?.push(
            &token,
            collection,
            name,
            &payload,
            PushOpts {
                ctx: crate::coordinator::OpContext::at(self.site),
                policy: self.policy,
            },
        )
    }

    /// Download one object and return the coordinator's full report
    /// (data decrypted in place when the client has a key). Requires an
    /// in-process backend.
    pub fn pull_report(&self, collection: &str, name: &str) -> Result<PullReport> {
        let token = self.local_token()?;
        let mut report = self.local()?.pull(
            &token,
            collection,
            name,
            PullOpts { ctx: crate::coordinator::OpContext::at(self.site), version: None },
        )?;
        let (version, epoch) = (report.meta.version, report.meta.nonce_epoch);
        self.decrypt_inbound(collection, name, version, epoch, 0, &mut report.data);
        Ok(report)
    }

    /// Range read with the coordinator's full report (fast-path flag,
    /// per-chunk I/O). Requires an in-process backend.
    pub fn pull_range_report(
        &self,
        collection: &str,
        name: &str,
        start: u64,
        end: u64,
    ) -> Result<RangeReport> {
        let token = self.local_token()?;
        let mut report = self.local()?.pull_range(
            &token,
            collection,
            name,
            start,
            end,
            PullOpts { ctx: crate::coordinator::OpContext::at(self.site), version: None },
        )?;
        let (version, epoch) = (report.meta.version, report.meta.nonce_epoch);
        self.decrypt_inbound(collection, name, version, epoch, report.start, &mut report.data);
        Ok(report)
    }

    /// Name of the GF(2^8) backend serving this client's deployment
    /// (`pure-rust | swar | swar-parallel | pjrt-pallas`) — reported by
    /// the deployment for in-process backends, `"remote"` otherwise
    /// (remote clients read it from `/health`).
    pub fn engine_name(&self) -> &'static str {
        self.local.as_ref().map(|l| l.deployment().backend_name()).unwrap_or("remote")
    }

    /// Drain container `id` out of the deployment (admin operation —
    /// the elastic-lifecycle counterpart of `add_container`): every
    /// chunk it holds migrates to live targets before it is removed.
    /// In-process backends only (the REST path is `POST
    /// /admin/decommission/<id>` with an operator token).
    pub fn decommission(&self, id: u32) -> Result<DecommissionReport> {
        self.local()?.decommission(id)
    }

    /// Equalize utilization across the deployment's containers (admin
    /// operation): hot→cold chunk moves until the weighted-occupancy
    /// spread is at or under `opts.threshold`.
    pub fn rebalance(&self, opts: RebalanceOpts) -> Result<RebalanceReport> {
        self.local()?.rebalance(opts)
    }

    /// Cancel a drain that stopped short: the container rejoins the
    /// placement pool.
    pub fn cancel_decommission(&self, id: u32) -> Result<()> {
        self.local()?.cancel_decommission(id)
    }

    /// Current imbalance (max − min weighted occupancy) of the fleet.
    pub fn utilization_spread(&self) -> Result<f64> {
        Ok(self.local()?.utilization_spread())
    }

    /// Upload a batch of objects over `threads` parallel channels
    /// (Fig. 7). Items are processed in rounds of `threads`; every
    /// channel active in a round shares the WAN link with exactly the
    /// other channels of that round (the final partial round uses fewer
    /// flows, so tail items go faster).
    pub fn push_batch(
        &self,
        items: &[(String, String, Vec<u8>)],
        threads: usize,
    ) -> Result<BatchReport> {
        self.batch(items.len(), threads, |i, flows| {
            let (col, name, data) = &items[i];
            self.push_flows(col, name, data, flows).map(|s| (s, data.len() as u64))
        })
    }

    /// Download a batch over parallel channels.
    pub fn pull_batch(
        &self,
        items: &[(String, String)],
        threads: usize,
    ) -> Result<BatchReport> {
        self.batch(items.len(), threads, |i, flows| {
            let (col, name) = &items[i];
            self.pull_flows(col, name, flows).map(|(data, s)| (s, data.len() as u64))
        })
    }

    /// Shared batch engine: round r runs items r*T..(r+1)*T with flows =
    /// that round's active channel count. On a local (simulated-time)
    /// backend the round's requests are modeled as concurrent, so the
    /// round costs its slowest request; on any other transport they
    /// actually execute sequentially on this thread, so the round costs
    /// their sum — the report must not claim parallelism that never
    /// happened on the wire.
    fn batch(
        &self,
        count: usize,
        threads: usize,
        op: impl Fn(usize, u32) -> Result<(f64, u64)>,
    ) -> Result<BatchReport> {
        if threads == 0 {
            return Err(Error::Invalid("threads must be >= 1".into()));
        }
        let modeled_parallel = self.store.transport() == "local";
        let mut sim_s = 0.0f64;
        let mut total_bytes = 0u64;
        let mut total_req = 0.0f64;
        let mut i = 0usize;
        while i < count {
            let active = threads.min(count - i) as u32;
            let mut round_max = 0.0f64;
            let mut round_sum = 0.0f64;
            for j in 0..active as usize {
                let (req_s, bytes) = op(i + j, active)?;
                round_max = round_max.max(req_s);
                round_sum += req_s;
                total_bytes += bytes;
                total_req += req_s;
            }
            sim_s += if modeled_parallel { round_max } else { round_sum };
            i += active as usize;
        }
        Ok(BatchReport {
            objects: count,
            bytes: total_bytes,
            sim_s,
            mean_request_s: if count > 0 { total_req / count as f64 } else { 0.0 },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{deploy_containers, AgentSpec};
    use crate::sim::DeviceKind;

    fn deployment() -> (Arc<DynoStore>, String) {
        let ds = Arc::new(DynoStore::builder().build());
        let specs: Vec<AgentSpec> = (0..12)
            .map(|i| {
                AgentSpec::new(
                    format!("dc{i}"),
                    Site::ChameleonTacc,
                    DeviceKind::ChameleonLocal,
                )
            })
            .collect();
        for c in deploy_containers(&specs, 12, 0).containers {
            ds.add_container(c).unwrap();
        }
        let token = ds.register_user("UserA").unwrap();
        (ds, token)
    }

    #[test]
    fn client_roundtrip() {
        let (ds, token) = deployment();
        let client = Client::new(ds, token, Site::Madrid);
        assert_eq!(client.engine_name(), "pure-rust");
        assert_eq!(client.transport(), "local");
        let data = crate::util::Rng::new(1).bytes(10_000);
        client.push("/UserA", "obj", &data).unwrap();
        assert!(client.exists("/UserA", "obj").unwrap());
        let info = client.stat("/UserA", "obj").unwrap();
        assert_eq!(info.size, 10_000);
        assert_eq!(info.version, 0);
        assert_eq!(info.etag, crate::util::to_hex(&crate::crypto::sha3_256(&data)));
        let (got, _) = client.pull("/UserA", "obj").unwrap();
        assert_eq!(got, data);
        client.evict("/UserA", "obj").unwrap();
        assert!(!client.exists("/UserA", "obj").unwrap());
    }

    #[test]
    fn encryption_roundtrip_and_ciphertext_at_rest() {
        let (ds, token) = deployment();
        let key = [9u8; 32];
        let client = Client::new(ds.clone(), token, Site::Madrid).with_encryption(key);
        let secret = b"extremely sensitive medical scan".to_vec();
        client.push("/UserA", "scan", &secret).unwrap();
        // Plaintext client sees ciphertext, encrypted client sees plaintext.
        let (got, _) = client.pull("/UserA", "scan").unwrap();
        assert_eq!(got, secret);
        let plain_client = Client::new(ds.clone(), ds.login("UserA"), Site::Madrid);
        let (raw, _) = plain_client.pull("/UserA", "scan").unwrap();
        assert_ne!(raw, secret, "data at rest is encrypted");
    }

    #[test]
    fn versioned_nonce_repush_decrypts_every_version() {
        // Satellite bugfix: re-pushing a name used to reuse the nonce
        // (version_salt hardcoded 0), so a version-pinned pull of a
        // re-pushed name decrypted with a colliding keystream. The salt
        // is now the version number.
        let (ds, token) = deployment();
        let key = [3u8; 32];
        let client = Client::new(ds, token, Site::Madrid).with_encryption(key);
        let v0 = b"version zero plaintext".to_vec();
        let v1 = b"version ONE plaintext!".to_vec();
        client.push("/UserA", "obj", &v0).unwrap();
        client.push("/UserA", "obj", &v1).unwrap();
        let (latest, _) = client.pull("/UserA", "obj").unwrap();
        assert_eq!(latest, v1);
        let (pinned, _) = client.pull_version("/UserA", "obj", 0).unwrap();
        assert_eq!(pinned, v0, "pinned pull decrypts with the version's own nonce");
        let (pinned1, _) = client.pull_version("/UserA", "obj", 1).unwrap();
        assert_eq!(pinned1, v1);
    }

    #[test]
    fn evict_then_repush_gets_a_fresh_nonce_epoch() {
        // Satellite bugfix (PR-5 residual): evicting a name deleted its
        // whole version chain, so re-pushing it restarted at version 0
        // and reused the version-0 nonce — identical plaintexts
        // encrypted to identical ciphertext across the evict (and
        // distinct plaintexts leaked their XOR). The metadata plane now
        // persists a per-name eviction generation that the nonce mixes
        // in.
        let (ds, token) = deployment();
        let key = [7u8; 32];
        let client = Client::new(ds.clone(), token, Site::Madrid).with_encryption(key);
        let secret = b"same plaintext, pushed twice across an evict".to_vec();
        client.push("/UserA", "obj", &secret).unwrap();
        let plain = Client::new(ds.clone(), ds.login("UserA"), Site::Madrid);
        let (at_rest_gen0, _) = plain.pull("/UserA", "obj").unwrap();
        client.evict("/UserA", "obj").unwrap();
        client.push("/UserA", "obj", &secret).unwrap();
        let info = client.stat("/UserA", "obj").unwrap();
        assert_eq!((info.version, info.nonce_epoch), (0, 1), "fresh chain, bumped epoch");
        let (at_rest_gen1, _) = plain.pull("/UserA", "obj").unwrap();
        assert_ne!(
            at_rest_gen0, at_rest_gen1,
            "identical plaintext must not repeat its ciphertext across an evict"
        );
        // And the epoch-salted ciphertext still decrypts.
        let (got, _) = client.pull("/UserA", "obj").unwrap();
        assert_eq!(got, secret);
        // Second evict → epoch 2 (monotonic, not flag-like).
        client.evict("/UserA", "obj").unwrap();
        client.push("/UserA", "obj", &secret).unwrap();
        assert_eq!(client.stat("/UserA", "obj").unwrap().nonce_epoch, 2);
        let (got, _) = client.pull("/UserA", "obj").unwrap();
        assert_eq!(got, secret);
    }

    #[test]
    fn client_deadline_short_circuits_with_timeout() {
        let (ds, token) = deployment();
        let client = Client::new(ds, token, Site::Madrid).with_deadline_ms(0);
        assert!(matches!(client.push("/UserA", "o", b"x"), Err(Error::Timeout(_))));
        assert!(matches!(client.pull("/UserA", "o"), Err(Error::Timeout(_))));
        assert!(matches!(client.pull_range("/UserA", "o", 0, 9), Err(Error::Timeout(_))));
    }

    #[test]
    fn encrypted_range_read_decrypts_slice() {
        let (ds, token) = deployment();
        let key = [5u8; 32];
        let client = Client::new(ds, token, Site::Madrid).with_encryption(key);
        let data = crate::util::Rng::new(77).bytes(60_000);
        client.push("/UserA", "obj", &data).unwrap();
        let (slice, _) = client.pull_range("/UserA", "obj", 1000, 2999).unwrap();
        assert_eq!(slice, &data[1000..=2999], "CTR seek decrypts mid-stream");
    }

    #[test]
    fn detailed_reports_expose_dispatch_plane() {
        let (ds, token) = deployment();
        let client = Client::new(ds, token, Site::Madrid);
        let data = crate::util::Rng::new(5).bytes(50_000);
        let push = client.push_report("/UserA", "obj", &data).unwrap();
        assert_eq!(push.chunk_io.len(), 10);
        assert!(push.chunk_io.iter().all(|c| c.transport == "local" && c.ok));
        let pull = client.pull_report("/UserA", "obj").unwrap();
        assert_eq!(pull.data, data);
        assert_eq!(pull.chunk_io.len(), 7);
        let range = client.pull_range_report("/UserA", "obj", 0, 99).unwrap();
        assert!(range.partial, "healthy read uses the fast path");
        assert_eq!(range.data, &data[0..=99]);
        assert_eq!(range.chunks_fetched, 1);
    }

    #[test]
    fn parallel_channels_reduce_batch_time() {
        // Fig. 7 shape: more channels → lower total time for a fixed
        // workload, with diminishing returns.
        let (ds, token) = deployment();
        let client = Client::new(ds, token, Site::Madrid);
        let items: Vec<(String, String, Vec<u8>)> = (0..32)
            .map(|i| ("/UserA".to_string(), format!("o{i}"), vec![7u8; 200_000]))
            .collect();
        let t1 = client.push_batch(&items, 1).unwrap().sim_s;
        let t8 = client.push_batch(&items, 8).unwrap().sim_s;
        let t32 = client.push_batch(&items, 32).unwrap().sim_s;
        assert!(t8 < t1, "8 threads {t8} < 1 thread {t1}");
        assert!(t32 <= t8);
        let reduction = (t1 - t32) / t1;
        assert!(reduction > 0.2, "expected sizeable reduction, got {reduction}");
    }

    #[test]
    fn lifecycle_ops_via_client() {
        let (ds, token) = deployment();
        let client = Client::new(ds.clone(), token, Site::Madrid);
        let data = crate::util::Rng::new(9).bytes(30_000);
        client.push("/UserA", "obj", &data).unwrap();
        assert!(client.utilization_spread().unwrap() >= 0.0);
        // 12 containers under (10,7): draining one always has a spare.
        let victim = ds
            .meta
            .read(|s| s.get_latest("UserA", "/UserA", "obj"))
            .unwrap()
            .placement
            .containers()[0];
        let report = client.decommission(victim).unwrap();
        assert!(report.removed);
        let rebalance = client
            .rebalance(RebalanceOpts { threshold: 0.9, ..Default::default() })
            .unwrap();
        assert!(rebalance.converged);
        let (got, _) = client.pull("/UserA", "obj").unwrap();
        assert_eq!(got, data);
    }

    #[test]
    fn listing_and_grants_via_client() {
        let (ds, token) = deployment();
        let client = Client::new(ds.clone(), token, Site::Madrid);
        for name in ["a", "b", "c"] {
            client.push("/UserA", name, b"x").unwrap();
        }
        let page = client
            .list("/UserA", &ListOptions { limit: 2, ..Default::default() })
            .unwrap();
        assert_eq!(page.objects.len(), 2);
        assert!(page.truncated);
        assert_eq!(page.next_after.as_deref(), Some("b"));
        // Grant read to UserB; they can pull through their own client.
        let token_b = ds.register_user("UserB").unwrap();
        let client_b = Client::new(ds, token_b, Site::Madrid);
        assert!(client_b.pull("/UserA", "a").is_err());
        client.grant("/UserA", "UserB", Permission::Read).unwrap();
        assert!(client_b.pull("/UserA", "a").is_ok());
        client.revoke("/UserA", "UserB", Permission::Read).unwrap();
        assert!(client_b.pull("/UserA", "a").is_err());
    }

    #[test]
    fn batch_zero_threads_rejected() {
        let (ds, token) = deployment();
        let client = Client::new(ds, token, Site::Madrid);
        assert!(client.push_batch(&[], 0).is_err());
    }
}
