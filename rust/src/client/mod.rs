//! The DynoStore client (paper §V): push / pull / exists / evict against
//! a deployment, usable as a library (this module) or through the CLI in
//! `main.rs`. Adds the two client-side features of the paper:
//!
//! * **Parallel channels** (§VI-C4, Fig. 7): workloads of many objects
//!   are spread over T concurrent channels; each channel is a thread
//!   sharing the client's WAN link (the flow-sharing term in
//!   [`crate::sim::Wan`] models the contention).
//! * **Point-to-point confidentiality** (§IV-E2): optional AES-256-CTR
//!   encryption before upload; the nonce is derived from the object name
//!   so pulls are self-contained.

use std::sync::Arc;

use crate::coordinator::{
    DecommissionReport, DynoStore, OpContext, PullOpts, PullReport, PushOpts, PushReport,
    RebalanceOpts, RebalanceReport,
};
use crate::crypto::{sha3_256, AesCtr};
use crate::policy::ResiliencePolicy;
use crate::sim::Site;
use crate::{Error, Result};

/// Client-side encryption configuration.
#[derive(Clone)]
pub struct Encryption {
    key: [u8; 32],
}

impl Encryption {
    pub fn new(key: [u8; 32]) -> Self {
        Encryption { key }
    }

    /// Derive a per-object nonce from the logical path (deterministic,
    /// distinct per object; versions of the same name share a nonce only
    /// if contents differ — acceptable for CTR because the key is per
    /// deployment and uploads are immutable versions).
    fn nonce_for(&self, collection: &str, name: &str, version_salt: u64) -> [u8; 16] {
        let mut buf = Vec::new();
        buf.extend_from_slice(collection.as_bytes());
        buf.push(0);
        buf.extend_from_slice(name.as_bytes());
        buf.extend_from_slice(&version_salt.to_le_bytes());
        let h = sha3_256(&buf);
        h[..16].try_into().unwrap()
    }
}

/// Aggregate result of a multi-object client workload.
#[derive(Debug, Clone, Default)]
pub struct BatchReport {
    pub objects: usize,
    pub bytes: u64,
    /// Simulated wall time for the whole batch (parallel channels).
    pub sim_s: f64,
    /// Mean simulated seconds per request.
    pub mean_request_s: f64,
}

/// A client bound to a deployment, a site, and (optionally) a cipher.
pub struct Client {
    store: Arc<DynoStore>,
    token: String,
    pub site: Site,
    encryption: Option<Encryption>,
    pub policy: Option<ResiliencePolicy>,
}

impl Client {
    pub fn new(store: Arc<DynoStore>, token: String, site: Site) -> Self {
        Client { store, token, site, encryption: None, policy: None }
    }

    pub fn with_encryption(mut self, key: [u8; 32]) -> Self {
        self.encryption = Some(Encryption::new(key));
        self
    }

    pub fn with_policy(mut self, policy: ResiliencePolicy) -> Self {
        self.policy = Some(policy);
        self
    }

    fn ctx(&self, flows: u32) -> OpContext {
        OpContext::at(self.site).with_flows(flows)
    }

    /// Upload one object. Returns the simulated request seconds.
    pub fn push(&self, collection: &str, name: &str, data: &[u8]) -> Result<f64> {
        self.push_flows(collection, name, data, 1)
    }

    /// Upload one object and return the coordinator's full report —
    /// per-chunk transport labels and timings included.
    pub fn push_report(&self, collection: &str, name: &str, data: &[u8]) -> Result<PushReport> {
        self.push_report_flows(collection, name, data, 1)
    }

    fn push_flows(&self, collection: &str, name: &str, data: &[u8], flows: u32) -> Result<f64> {
        Ok(self.push_report_flows(collection, name, data, flows)?.sim_s)
    }

    fn push_report_flows(
        &self,
        collection: &str,
        name: &str,
        data: &[u8],
        flows: u32,
    ) -> Result<PushReport> {
        let payload = match &self.encryption {
            Some(enc) => {
                let mut buf = data.to_vec();
                AesCtr::new(&enc.key, &enc.nonce_for(collection, name, 0)).apply(&mut buf);
                buf
            }
            None => data.to_vec(),
        };
        self.store.push(
            &self.token,
            collection,
            name,
            &payload,
            PushOpts { ctx: self.ctx(flows), policy: self.policy },
        )
    }

    /// Download one object (decrypting if the client has a key).
    pub fn pull(&self, collection: &str, name: &str) -> Result<(Vec<u8>, f64)> {
        self.pull_flows(collection, name, 1)
    }

    /// Download one object and return the coordinator's full report
    /// (data decrypted in place when the client has a key).
    pub fn pull_report(&self, collection: &str, name: &str) -> Result<PullReport> {
        self.pull_report_flows(collection, name, 1)
    }

    fn pull_flows(&self, collection: &str, name: &str, flows: u32) -> Result<(Vec<u8>, f64)> {
        let report = self.pull_report_flows(collection, name, flows)?;
        Ok((report.data, report.sim_s))
    }

    fn pull_report_flows(
        &self,
        collection: &str,
        name: &str,
        flows: u32,
    ) -> Result<PullReport> {
        let mut report = self.store.pull(
            &self.token,
            collection,
            name,
            PullOpts { ctx: self.ctx(flows), version: None },
        )?;
        if let Some(enc) = &self.encryption {
            AesCtr::new(&enc.key, &enc.nonce_for(collection, name, 0)).apply(&mut report.data);
        }
        Ok(report)
    }

    pub fn exists(&self, collection: &str, name: &str) -> Result<bool> {
        self.store.exists(&self.token, collection, name)
    }

    /// Name of the GF(2^8) backend serving this client's deployment
    /// (`pure-rust | swar | swar-parallel | pjrt-pallas`) — the knob is
    /// set deployment-side via `Config`'s `engine` field; clients
    /// observe it here and in every push/pull report.
    pub fn engine_name(&self) -> &'static str {
        self.store.backend_name()
    }

    pub fn evict(&self, collection: &str, name: &str) -> Result<usize> {
        self.store.evict(&self.token, collection, name)
    }

    /// Drain container `id` out of the deployment (admin operation —
    /// the elastic-lifecycle counterpart of `add_container`): every
    /// chunk it holds migrates to live targets before it is removed.
    pub fn decommission(&self, id: u32) -> Result<DecommissionReport> {
        self.store.decommission(id)
    }

    /// Equalize utilization across the deployment's containers (admin
    /// operation): hot→cold chunk moves until the weighted-occupancy
    /// spread is at or under `opts.threshold`.
    pub fn rebalance(&self, opts: RebalanceOpts) -> Result<RebalanceReport> {
        self.store.rebalance(opts)
    }

    /// Cancel a drain that stopped short: the container rejoins the
    /// placement pool.
    pub fn cancel_decommission(&self, id: u32) -> Result<()> {
        self.store.cancel_decommission(id)
    }

    /// Current imbalance (max − min weighted occupancy) of the fleet.
    pub fn utilization_spread(&self) -> f64 {
        self.store.utilization_spread()
    }

    /// Upload a batch of objects over `threads` parallel channels
    /// (Fig. 7). Items are processed in rounds of `threads`; every
    /// channel active in a round shares the WAN link with exactly the
    /// other channels of that round (the final partial round uses fewer
    /// flows, so tail items go faster).
    pub fn push_batch(
        &self,
        items: &[(String, String, Vec<u8>)],
        threads: usize,
    ) -> Result<BatchReport> {
        self.batch(items.len(), threads, |i, flows| {
            let (col, name, data) = &items[i];
            self.push_flows(col, name, data, flows).map(|s| (s, data.len() as u64))
        })
    }

    /// Download a batch over parallel channels.
    pub fn pull_batch(
        &self,
        items: &[(String, String)],
        threads: usize,
    ) -> Result<BatchReport> {
        self.batch(items.len(), threads, |i, flows| {
            let (col, name) = &items[i];
            self.pull_flows(col, name, flows).map(|(data, s)| (s, data.len() as u64))
        })
    }

    /// Shared batch engine: round r runs items r*T..(r+1)*T concurrently
    /// with flows = that round's active channel count; batch time = sum
    /// over rounds of the round's slowest request.
    fn batch(
        &self,
        count: usize,
        threads: usize,
        op: impl Fn(usize, u32) -> Result<(f64, u64)>,
    ) -> Result<BatchReport> {
        if threads == 0 {
            return Err(Error::Invalid("threads must be >= 1".into()));
        }
        let mut sim_s = 0.0f64;
        let mut total_bytes = 0u64;
        let mut total_req = 0.0f64;
        let mut i = 0usize;
        while i < count {
            let active = threads.min(count - i) as u32;
            let mut round_max = 0.0f64;
            for j in 0..active as usize {
                let (req_s, bytes) = op(i + j, active)?;
                round_max = round_max.max(req_s);
                total_bytes += bytes;
                total_req += req_s;
            }
            sim_s += round_max;
            i += active as usize;
        }
        Ok(BatchReport {
            objects: count,
            bytes: total_bytes,
            sim_s,
            mean_request_s: if count > 0 { total_req / count as f64 } else { 0.0 },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{deploy_containers, AgentSpec};
    use crate::sim::DeviceKind;

    fn deployment() -> (Arc<DynoStore>, String) {
        let ds = Arc::new(DynoStore::builder().build());
        let specs: Vec<AgentSpec> = (0..12)
            .map(|i| {
                AgentSpec::new(
                    format!("dc{i}"),
                    Site::ChameleonTacc,
                    DeviceKind::ChameleonLocal,
                )
            })
            .collect();
        for c in deploy_containers(&specs, 12, 0).containers {
            ds.add_container(c).unwrap();
        }
        let token = ds.register_user("UserA").unwrap();
        (ds, token)
    }

    #[test]
    fn client_roundtrip() {
        let (ds, token) = deployment();
        let client = Client::new(ds, token, Site::Madrid);
        assert_eq!(client.engine_name(), "pure-rust");
        let data = crate::util::Rng::new(1).bytes(10_000);
        client.push("/UserA", "obj", &data).unwrap();
        assert!(client.exists("/UserA", "obj").unwrap());
        let (got, _) = client.pull("/UserA", "obj").unwrap();
        assert_eq!(got, data);
        client.evict("/UserA", "obj").unwrap();
        assert!(!client.exists("/UserA", "obj").unwrap());
    }

    #[test]
    fn encryption_roundtrip_and_ciphertext_at_rest() {
        let (ds, token) = deployment();
        let key = [9u8; 32];
        let client = Client::new(ds.clone(), token, Site::Madrid).with_encryption(key);
        let secret = b"extremely sensitive medical scan".to_vec();
        client.push("/UserA", "scan", &secret).unwrap();
        // Plaintext client sees ciphertext, encrypted client sees plaintext.
        let (got, _) = client.pull("/UserA", "scan").unwrap();
        assert_eq!(got, secret);
        let plain_client =
            Client::new(ds, client.store_token_for_tests(), Site::Madrid);
        let (raw, _) = plain_client.pull("/UserA", "scan").unwrap();
        assert_ne!(raw, secret, "data at rest is encrypted");
    }

    #[test]
    fn detailed_reports_expose_dispatch_plane() {
        let (ds, token) = deployment();
        let client = Client::new(ds, token, Site::Madrid);
        let data = crate::util::Rng::new(5).bytes(50_000);
        let push = client.push_report("/UserA", "obj", &data).unwrap();
        assert_eq!(push.chunk_io.len(), 10);
        assert!(push.chunk_io.iter().all(|c| c.transport == "local" && c.ok));
        let pull = client.pull_report("/UserA", "obj").unwrap();
        assert_eq!(pull.data, data);
        assert_eq!(pull.chunk_io.len(), 7);
    }

    #[test]
    fn parallel_channels_reduce_batch_time() {
        // Fig. 7 shape: more channels → lower total time for a fixed
        // workload, with diminishing returns.
        let (ds, token) = deployment();
        let client = Client::new(ds, token, Site::Madrid);
        let items: Vec<(String, String, Vec<u8>)> = (0..32)
            .map(|i| ("/UserA".to_string(), format!("o{i}"), vec![7u8; 200_000]))
            .collect();
        let t1 = client.push_batch(&items, 1).unwrap().sim_s;
        let t8 = client.push_batch(&items, 8).unwrap().sim_s;
        let t32 = client.push_batch(&items, 32).unwrap().sim_s;
        assert!(t8 < t1, "8 threads {t8} < 1 thread {t1}");
        assert!(t32 <= t8);
        let reduction = (t1 - t32) / t1;
        assert!(reduction > 0.2, "expected sizeable reduction, got {reduction}");
    }

    #[test]
    fn lifecycle_ops_via_client() {
        let (ds, token) = deployment();
        let client = Client::new(ds.clone(), token, Site::Madrid);
        let data = crate::util::Rng::new(9).bytes(30_000);
        client.push("/UserA", "obj", &data).unwrap();
        assert!(client.utilization_spread() >= 0.0);
        // 12 containers under (10,7): draining one always has a spare.
        let victim = ds
            .meta
            .read(|s| s.get_latest("UserA", "/UserA", "obj"))
            .unwrap()
            .placement
            .containers()[0];
        let report = client.decommission(victim).unwrap();
        assert!(report.removed);
        let rebalance = client
            .rebalance(RebalanceOpts { threshold: 0.9, ..Default::default() })
            .unwrap();
        assert!(rebalance.converged);
        let (got, _) = client.pull("/UserA", "obj").unwrap();
        assert_eq!(got, data);
    }

    #[test]
    fn batch_zero_threads_rejected() {
        let (ds, token) = deployment();
        let client = Client::new(ds, token, Site::Madrid);
        assert!(client.push_batch(&[], 0).is_err());
    }

    impl Client {
        /// Test helper: reissue a token for the same subject.
        fn store_token_for_tests(&self) -> String {
            self.store.login("UserA")
        }
    }
}
