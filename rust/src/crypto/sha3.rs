//! SHA3-256 from scratch (FIPS 202 Keccak-f[1600], rate 1088, capacity
//! 512, domain suffix 0x06).
//!
//! The paper's integrity scheme (§IV-D Algorithms 1-2, §IV-E) names
//! SHA3-256 specifically; the vendored crate set only ships SHA-2, so we
//! implement Keccak here and validate against the NIST/known-answer
//! vectors in the unit tests.

const ROUNDS: usize = 24;

const RC: [u64; ROUNDS] = [
    0x0000000000000001, 0x0000000000008082, 0x800000000000808a, 0x8000000080008000,
    0x000000000000808b, 0x0000000080000001, 0x8000000080008081, 0x8000000000008009,
    0x000000000000008a, 0x0000000000000088, 0x0000000080008009, 0x000000008000000a,
    0x000000008000808b, 0x800000000000008b, 0x8000000000008089, 0x8000000000008003,
    0x8000000000008002, 0x8000000000000080, 0x000000000000800a, 0x800000008000000a,
    0x8000000080008081, 0x8000000000008080, 0x0000000080000001, 0x8000000080008008,
];

// Rotation offsets r[x][y] laid out as state index 5*y + x.
const RHO: [u32; 25] = [
    0, 1, 62, 28, 27, 36, 44, 6, 55, 20, 3, 10, 43, 25, 39, 41, 45, 15, 21, 8, 18, 2, 61, 56, 14,
];

fn keccak_f(state: &mut [u64; 25]) {
    for rc in RC.iter().take(ROUNDS) {
        // θ
        let mut c = [0u64; 5];
        for x in 0..5 {
            c[x] = state[x] ^ state[x + 5] ^ state[x + 10] ^ state[x + 15] ^ state[x + 20];
        }
        for x in 0..5 {
            let d = c[(x + 4) % 5] ^ c[(x + 1) % 5].rotate_left(1);
            for y in 0..5 {
                state[5 * y + x] ^= d;
            }
        }
        // ρ and π
        let mut b = [0u64; 25];
        for x in 0..5 {
            for y in 0..5 {
                let idx = 5 * y + x;
                // π: B[y, 2x+3y] = rot(A[x, y])
                let nx = y;
                let ny = (2 * x + 3 * y) % 5;
                b[5 * ny + nx] = state[idx].rotate_left(RHO[idx]);
            }
        }
        // χ
        for y in 0..5 {
            for x in 0..5 {
                state[5 * y + x] = b[5 * y + x] ^ ((!b[5 * y + (x + 1) % 5]) & b[5 * y + (x + 2) % 5]);
            }
        }
        // ι
        state[0] ^= rc;
    }
}

const RATE: usize = 136; // 1088 bits for SHA3-256

/// Streaming SHA3-256.
#[derive(Clone)]
pub struct Sha3_256 {
    state: [u64; 25],
    buf: [u8; RATE],
    buf_len: usize,
}

impl Default for Sha3_256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha3_256 {
    pub fn new() -> Self {
        Sha3_256 { state: [0u64; 25], buf: [0u8; RATE], buf_len: 0 }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        // Fill the partial block first.
        if self.buf_len > 0 {
            let take = (RATE - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == RATE {
                let block = self.buf;
                self.absorb(&block);
                self.buf_len = 0;
            }
        }
        // Whole blocks straight from the input.
        while data.len() >= RATE {
            let (block, rest) = data.split_at(RATE);
            let mut tmp = [0u8; RATE];
            tmp.copy_from_slice(block);
            self.absorb(&tmp);
            data = rest;
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    fn absorb(&mut self, block: &[u8; RATE]) {
        for (i, lane) in block.chunks_exact(8).enumerate() {
            self.state[i] ^= u64::from_le_bytes(lane.try_into().unwrap());
        }
        keccak_f(&mut self.state);
    }

    pub fn finalize(mut self) -> [u8; 32] {
        // Pad: SHA-3 domain suffix 0b01 then pad10*1 → 0x06 ... 0x80.
        let mut block = [0u8; RATE];
        block[..self.buf_len].copy_from_slice(&self.buf[..self.buf_len]);
        block[self.buf_len] = 0x06;
        block[RATE - 1] |= 0x80;
        self.absorb(&block);
        let mut out = [0u8; 32];
        for (i, chunk) in out.chunks_exact_mut(8).enumerate() {
            chunk.copy_from_slice(&self.state[i].to_le_bytes());
        }
        out
    }
}

/// One-shot SHA3-256.
pub fn sha3_256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha3_256::new();
    h.update(data);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::to_hex;

    #[test]
    fn nist_empty_vector() {
        // FIPS 202 known-answer: SHA3-256("")
        assert_eq!(
            to_hex(&sha3_256(b"")),
            "a7ffc6f8bf1ed76651c14756a061d662f580ff4de43b49fa82d80a4b80f8434a"
        );
    }

    #[test]
    fn known_answer_abc() {
        assert_eq!(
            to_hex(&sha3_256(b"abc")),
            "3a985da74fe225b2045c172d6bd390bd855f086e3e9d525b46bfe24511431532"
        );
    }

    #[test]
    fn known_answer_448_bits() {
        let msg = b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq";
        assert_eq!(
            to_hex(&sha3_256(msg)),
            "41c0dba2a9d6240849100376a8235e2c82e1b9998a999e21db32dd97496d3376"
        );
    }

    #[test]
    fn known_answer_million_a() {
        let msg = vec![b'a'; 1_000_000];
        assert_eq!(
            to_hex(&sha3_256(&msg)),
            "5c8875ae474a3634ba4fd55ec85bffd661f32aca75c6d699d0cdcb6c115891c1"
        );
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let oneshot = sha3_256(&data);
        // Feed in awkward chunk sizes crossing block boundaries.
        for chunk in [1usize, 7, 135, 136, 137, 1000] {
            let mut h = Sha3_256::new();
            for c in data.chunks(chunk) {
                h.update(c);
            }
            assert_eq!(h.finalize(), oneshot, "chunk size {chunk}");
        }
    }

    #[test]
    fn distinct_inputs_distinct_digests() {
        assert_ne!(sha3_256(b"a"), sha3_256(b"b"));
        assert_ne!(sha3_256(b""), sha3_256(b"\x00"));
    }
}
