//! AES-256 in CTR mode — the paper's client-side point-to-point
//! confidentiality (§IV-E2: "DynoStore's client implements an AES-256
//! encryption to safeguard sensitive objects during transport").
//!
//! Both halves are implemented in-crate (the crate builds with zero
//! external dependencies): the AES-256 block cipher below is a direct
//! FIPS-197 transcription (S-box substitution, 14 rounds, 8-word key
//! schedule), verified against the FIPS-197 C.3 block vector and the
//! NIST SP 800-38A F.5.5 CTR stream vector. CTR mode is a big-endian
//! 128-bit counter starting from the nonce, encrypt-counter-and-XOR;
//! CTR is symmetric, so `apply` both encrypts and decrypts, and the
//! keystream is seekable (`apply_at`) so range reads can decrypt a
//! middle slice without the prefix.

/// The AES S-box (FIPS-197 Fig. 7).
#[rustfmt::skip]
const SBOX: [u8; 256] = [
    0x63, 0x7c, 0x77, 0x7b, 0xf2, 0x6b, 0x6f, 0xc5, 0x30, 0x01, 0x67, 0x2b, 0xfe, 0xd7, 0xab, 0x76,
    0xca, 0x82, 0xc9, 0x7d, 0xfa, 0x59, 0x47, 0xf0, 0xad, 0xd4, 0xa2, 0xaf, 0x9c, 0xa4, 0x72, 0xc0,
    0xb7, 0xfd, 0x93, 0x26, 0x36, 0x3f, 0xf7, 0xcc, 0x34, 0xa5, 0xe5, 0xf1, 0x71, 0xd8, 0x31, 0x15,
    0x04, 0xc7, 0x23, 0xc3, 0x18, 0x96, 0x05, 0x9a, 0x07, 0x12, 0x80, 0xe2, 0xeb, 0x27, 0xb2, 0x75,
    0x09, 0x83, 0x2c, 0x1a, 0x1b, 0x6e, 0x5a, 0xa0, 0x52, 0x3b, 0xd6, 0xb3, 0x29, 0xe3, 0x2f, 0x84,
    0x53, 0xd1, 0x00, 0xed, 0x20, 0xfc, 0xb1, 0x5b, 0x6a, 0xcb, 0xbe, 0x39, 0x4a, 0x4c, 0x58, 0xcf,
    0xd0, 0xef, 0xaa, 0xfb, 0x43, 0x4d, 0x33, 0x85, 0x45, 0xf9, 0x02, 0x7f, 0x50, 0x3c, 0x9f, 0xa8,
    0x51, 0xa3, 0x40, 0x8f, 0x92, 0x9d, 0x38, 0xf5, 0xbc, 0xb6, 0xda, 0x21, 0x10, 0xff, 0xf3, 0xd2,
    0xcd, 0x0c, 0x13, 0xec, 0x5f, 0x97, 0x44, 0x17, 0xc4, 0xa7, 0x7e, 0x3d, 0x64, 0x5d, 0x19, 0x73,
    0x60, 0x81, 0x4f, 0xdc, 0x22, 0x2a, 0x90, 0x88, 0x46, 0xee, 0xb8, 0x14, 0xde, 0x5e, 0x0b, 0xdb,
    0xe0, 0x32, 0x3a, 0x0a, 0x49, 0x06, 0x24, 0x5c, 0xc2, 0xd3, 0xac, 0x62, 0x91, 0x95, 0xe4, 0x79,
    0xe7, 0xc8, 0x37, 0x6d, 0x8d, 0xd5, 0x4e, 0xa9, 0x6c, 0x56, 0xf4, 0xea, 0x65, 0x7a, 0xae, 0x08,
    0xba, 0x78, 0x25, 0x2e, 0x1c, 0xa6, 0xb4, 0xc6, 0xe8, 0xdd, 0x74, 0x1f, 0x4b, 0xbd, 0x8b, 0x8a,
    0x70, 0x3e, 0xb5, 0x66, 0x48, 0x03, 0xf6, 0x0e, 0x61, 0x35, 0x57, 0xb9, 0x86, 0xc1, 0x1d, 0x9e,
    0xe1, 0xf8, 0x98, 0x11, 0x69, 0xd9, 0x8e, 0x94, 0x9b, 0x1e, 0x87, 0xe9, 0xce, 0x55, 0x28, 0xdf,
    0x8c, 0xa1, 0x89, 0x0d, 0xbf, 0xe6, 0x42, 0x68, 0x41, 0x99, 0x2d, 0x0f, 0xb0, 0x54, 0xbb, 0x16,
];

/// Round constants for the key schedule (`rcon[i] = x^(i-1)` in GF(2^8);
/// AES-256 consumes indices 1..=7).
const RCON: [u8; 8] = [0x00, 0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40];

/// Multiply by x (i.e. {02}) in GF(2^8) mod x^8 + x^4 + x^3 + x + 1.
#[inline]
fn xtime(b: u8) -> u8 {
    (b << 1) ^ (((b >> 7) & 1) * 0x1b)
}

/// AES-256 block cipher, encrypt-only (CTR never needs the inverse
/// cipher). State layout follows FIPS-197: `block[4c + r] = s[r][c]`.
struct Aes256 {
    /// 15 round keys of 16 bytes each (Nr = 14).
    round_keys: [[u8; 16]; 15],
}

impl Aes256 {
    fn new(key: &[u8; 32]) -> Self {
        // Key expansion (FIPS-197 §5.2, Nk = 8, Nb = 4, Nr = 14).
        let mut w = [[0u8; 4]; 60];
        for (i, word) in w.iter_mut().take(8).enumerate() {
            word.copy_from_slice(&key[4 * i..4 * i + 4]);
        }
        for i in 8..60 {
            let mut temp = w[i - 1];
            if i % 8 == 0 {
                // RotWord then SubWord then Rcon.
                temp = [
                    SBOX[temp[1] as usize],
                    SBOX[temp[2] as usize],
                    SBOX[temp[3] as usize],
                    SBOX[temp[0] as usize],
                ];
                temp[0] ^= RCON[i / 8];
            } else if i % 8 == 4 {
                // AES-256 extra SubWord at Nk/2.
                for b in temp.iter_mut() {
                    *b = SBOX[*b as usize];
                }
            }
            for b in 0..4 {
                w[i][b] = w[i - 8][b] ^ temp[b];
            }
        }
        let mut round_keys = [[0u8; 16]; 15];
        for (r, rk) in round_keys.iter_mut().enumerate() {
            for c in 0..4 {
                rk[4 * c..4 * c + 4].copy_from_slice(&w[4 * r + c]);
            }
        }
        Aes256 { round_keys }
    }

    fn encrypt_block(&self, block: &mut [u8; 16]) {
        add_round_key(block, &self.round_keys[0]);
        for round in 1..14 {
            sub_bytes(block);
            shift_rows(block);
            mix_columns(block);
            add_round_key(block, &self.round_keys[round]);
        }
        sub_bytes(block);
        shift_rows(block);
        add_round_key(block, &self.round_keys[14]);
    }
}

#[inline]
fn add_round_key(state: &mut [u8; 16], rk: &[u8; 16]) {
    for i in 0..16 {
        state[i] ^= rk[i];
    }
}

#[inline]
fn sub_bytes(state: &mut [u8; 16]) {
    for b in state.iter_mut() {
        *b = SBOX[*b as usize];
    }
}

/// Row r of the state rotates left by r: `s'[r][c] = s[r][(c + r) % 4]`.
#[inline]
fn shift_rows(state: &mut [u8; 16]) {
    let old = *state;
    for r in 1..4 {
        for c in 0..4 {
            state[4 * c + r] = old[4 * ((c + r) % 4) + r];
        }
    }
}

/// Per-column multiply by the fixed polynomial {03}x^3+{01}x^2+{01}x+{02}.
#[inline]
fn mix_columns(state: &mut [u8; 16]) {
    for c in 0..4 {
        let col = [state[4 * c], state[4 * c + 1], state[4 * c + 2], state[4 * c + 3]];
        let all = col[0] ^ col[1] ^ col[2] ^ col[3];
        for r in 0..4 {
            state[4 * c + r] = col[r] ^ all ^ xtime(col[r] ^ col[(r + 1) % 4]);
        }
    }
}

/// AES-256-CTR stream cipher.
pub struct AesCtr {
    cipher: Aes256,
    nonce: [u8; 16],
}

impl AesCtr {
    /// `key` is the 32-byte AES-256 key, `nonce` the 16-byte initial
    /// counter block (callers derive it per object *version*; never
    /// reuse a (key, nonce) pair across distinct plaintexts).
    pub fn new(key: &[u8; 32], nonce: &[u8; 16]) -> Self {
        AesCtr { cipher: Aes256::new(key), nonce: *nonce }
    }

    /// Encrypt or decrypt `data` in place starting at stream offset 0.
    pub fn apply(&self, data: &mut [u8]) {
        self.apply_at(data, 0);
    }

    /// Encrypt or decrypt starting at byte offset `offset` in the stream
    /// (supports chunked/parallel processing of one logical object, and
    /// decryption of HTTP range reads without fetching the prefix).
    pub fn apply_at(&self, data: &mut [u8], offset: u64) {
        let mut block_index = offset / 16;
        let mut skip = (offset % 16) as usize;
        let mut pos = 0usize;
        while pos < data.len() {
            let mut ctr_block = counter_block(&self.nonce, block_index);
            self.cipher.encrypt_block(&mut ctr_block);
            let take = (16 - skip).min(data.len() - pos);
            for i in 0..take {
                data[pos + i] ^= ctr_block[skip + i];
            }
            pos += take;
            skip = 0;
            block_index += 1;
        }
    }
}

/// nonce + big-endian 128-bit block counter (standard CTR increment).
fn counter_block(nonce: &[u8; 16], index: u64) -> [u8; 16] {
    let mut block = *nonce;
    let mut carry = index;
    for byte in block.iter_mut().rev() {
        if carry == 0 {
            break;
        }
        let sum = *byte as u64 + (carry & 0xff);
        *byte = sum as u8;
        carry = (carry >> 8) + (sum >> 8);
    }
    block
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{from_hex, to_hex};

    /// FIPS-197 Appendix C.3: AES-256 single-block known answer.
    #[test]
    fn fips197_c3_block_vector() {
        let key: [u8; 32] = from_hex(
            "000102030405060708090a0b0c0d0e0f101112131415161718191a1b1c1d1e1f",
        )
        .unwrap()
        .try_into()
        .unwrap();
        let mut block: [u8; 16] =
            from_hex("00112233445566778899aabbccddeeff").unwrap().try_into().unwrap();
        Aes256::new(&key).encrypt_block(&mut block);
        assert_eq!(to_hex(&block), "8ea2b7ca516745bfeafc49904b496089");
    }

    /// NIST SP 800-38A F.5.5 CTR-AES256.Encrypt test vector.
    #[test]
    fn nist_sp800_38a_ctr_aes256() {
        let key: [u8; 32] = from_hex(
            "603deb1015ca71be2b73aef0857d77811f352c073b6108d72d9810a30914dff4",
        )
        .unwrap()
        .try_into()
        .unwrap();
        let nonce: [u8; 16] =
            from_hex("f0f1f2f3f4f5f6f7f8f9fafbfcfdfeff").unwrap().try_into().unwrap();
        let mut data = from_hex(
            "6bc1bee22e409f96e93d7e117393172a\
             ae2d8a571e03ac9c9eb76fac45af8e51\
             30c81c46a35ce411e5fbc1191a0a52ef\
             f69f2445df4f9b17ad2b417be66c3710",
        )
        .unwrap();
        AesCtr::new(&key, &nonce).apply(&mut data);
        assert_eq!(
            to_hex(&data),
            "601ec313775789a5b7a7f504bbf3d228\
             f443e3ca4d62b59aca84e990cacaf5c5\
             2b0930daa23de94ce87017ba2d84988d\
             dfc9c58db67aada613c2dd08457941a6"
                .replace(' ', "")
        );
    }

    #[test]
    fn roundtrip() {
        let key = [7u8; 32];
        let nonce = [3u8; 16];
        let mut data: Vec<u8> = (0..10_000u32).map(|i| (i % 256) as u8).collect();
        let orig = data.clone();
        let c = AesCtr::new(&key, &nonce);
        c.apply(&mut data);
        assert_ne!(data, orig, "ciphertext differs from plaintext");
        c.apply(&mut data);
        assert_eq!(data, orig, "decrypt restores plaintext");
    }

    #[test]
    fn offset_apply_matches_full_stream() {
        let key = [1u8; 32];
        let nonce = [9u8; 16];
        let c = AesCtr::new(&key, &nonce);
        let mut whole: Vec<u8> = (0..1000u32).map(|i| (i * 7 % 256) as u8).collect();
        let orig = whole.clone();
        c.apply(&mut whole);
        // Re-encrypt the same plaintext in misaligned pieces.
        for split in [1usize, 15, 16, 17, 333] {
            let mut pieces = orig.clone();
            let (a, b) = pieces.split_at_mut(split);
            c.apply_at(a, 0);
            c.apply_at(b, split as u64);
            assert_eq!(pieces, whole, "split at {split}");
        }
    }

    /// Range-read decryption: a middle slice of ciphertext decrypts with
    /// `apply_at(start)` to exactly the plaintext slice.
    #[test]
    fn middle_slice_decrypts_with_offset() {
        let key = [0x42u8; 32];
        let nonce = [0x17u8; 16];
        let c = AesCtr::new(&key, &nonce);
        let plain: Vec<u8> = (0..5000u32).map(|i| (i * 13 % 256) as u8).collect();
        let mut cipher = plain.clone();
        c.apply(&mut cipher);
        for (start, end) in [(0usize, 4999usize), (100, 100), (7, 40), (4090, 4200)] {
            let mut slice = cipher[start..=end].to_vec();
            c.apply_at(&mut slice, start as u64);
            assert_eq!(slice, &plain[start..=end], "range {start}..={end}");
        }
    }

    #[test]
    fn counter_block_carry_propagates() {
        let nonce = [0xffu8; 16];
        let b = counter_block(&nonce, 1);
        assert_eq!(b, [0u8; 16], "all-ones nonce + 1 wraps to zero");
    }

    #[test]
    fn different_nonce_different_keystream() {
        let key = [5u8; 32];
        let mut a = vec![0u8; 64];
        let mut b = vec![0u8; 64];
        AesCtr::new(&key, &[0u8; 16]).apply(&mut a);
        AesCtr::new(&key, &[1u8; 16]).apply(&mut b);
        assert_ne!(a, b);
    }
}
