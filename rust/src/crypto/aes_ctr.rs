//! AES-256 in CTR mode — the paper's client-side point-to-point
//! confidentiality (§IV-E2: "DynoStore's client implements an AES-256
//! encryption to safeguard sensitive objects during transport").
//!
//! The vendored `aes` crate supplies the block cipher; CTR mode (the
//! `ctr` crate is absent) is implemented here: big-endian 128-bit counter
//! starting from the nonce, encrypt-counter-and-XOR. CTR is symmetric, so
//! `apply` both encrypts and decrypts.

use aes::cipher::{BlockEncrypt, KeyInit};
use aes::Aes256;

/// AES-256-CTR stream cipher.
pub struct AesCtr {
    cipher: Aes256,
    nonce: [u8; 16],
}

impl AesCtr {
    /// `key` is the 32-byte AES-256 key, `nonce` the 16-byte initial
    /// counter block (callers derive it per object; never reuse a
    /// (key, nonce) pair across distinct plaintexts).
    pub fn new(key: &[u8; 32], nonce: &[u8; 16]) -> Self {
        AesCtr { cipher: Aes256::new(key.into()), nonce: *nonce }
    }

    /// Encrypt or decrypt `data` in place starting at stream offset 0.
    pub fn apply(&self, data: &mut [u8]) {
        self.apply_at(data, 0);
    }

    /// Encrypt or decrypt starting at byte offset `offset` in the stream
    /// (supports chunked/parallel processing of one logical object).
    pub fn apply_at(&self, data: &mut [u8], offset: u64) {
        let mut block_index = offset / 16;
        let mut skip = (offset % 16) as usize;
        let mut pos = 0usize;
        while pos < data.len() {
            let mut ctr_block = counter_block(&self.nonce, block_index);
            self.cipher.encrypt_block((&mut ctr_block).into());
            let take = (16 - skip).min(data.len() - pos);
            for i in 0..take {
                data[pos + i] ^= ctr_block[skip + i];
            }
            pos += take;
            skip = 0;
            block_index += 1;
        }
    }
}

/// nonce + big-endian 128-bit block counter (standard CTR increment).
fn counter_block(nonce: &[u8; 16], index: u64) -> [u8; 16] {
    let mut block = *nonce;
    let mut carry = index;
    for byte in block.iter_mut().rev() {
        if carry == 0 {
            break;
        }
        let sum = *byte as u64 + (carry & 0xff);
        *byte = sum as u8;
        carry = (carry >> 8) + (sum >> 8);
    }
    block
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{from_hex, to_hex};

    /// NIST SP 800-38A F.5.5 CTR-AES256.Encrypt test vector.
    #[test]
    fn nist_sp800_38a_ctr_aes256() {
        let key: [u8; 32] = from_hex(
            "603deb1015ca71be2b73aef0857d77811f352c073b6108d72d9810a30914dff4",
        )
        .unwrap()
        .try_into()
        .unwrap();
        let nonce: [u8; 16] =
            from_hex("f0f1f2f3f4f5f6f7f8f9fafbfcfdfeff").unwrap().try_into().unwrap();
        let mut data = from_hex(
            "6bc1bee22e409f96e93d7e117393172a\
             ae2d8a571e03ac9c9eb76fac45af8e51\
             30c81c46a35ce411e5fbc1191a0a52ef\
             f69f2445df4f9b17ad2b417be66c3710",
        )
        .unwrap();
        AesCtr::new(&key, &nonce).apply(&mut data);
        assert_eq!(
            to_hex(&data),
            "601ec313775789a5b7a7f504bbf3d228\
             f443e3ca4d62b59aca84e990cacaf5c5\
             2b0930daa23de94ce87017ba2d84988d\
             dfc9c58db67aada613c2dd08457941a6"
                .replace(' ', "")
        );
    }

    #[test]
    fn roundtrip() {
        let key = [7u8; 32];
        let nonce = [3u8; 16];
        let mut data: Vec<u8> = (0..10_000u32).map(|i| (i % 256) as u8).collect();
        let orig = data.clone();
        let c = AesCtr::new(&key, &nonce);
        c.apply(&mut data);
        assert_ne!(data, orig, "ciphertext differs from plaintext");
        c.apply(&mut data);
        assert_eq!(data, orig, "decrypt restores plaintext");
    }

    #[test]
    fn offset_apply_matches_full_stream() {
        let key = [1u8; 32];
        let nonce = [9u8; 16];
        let c = AesCtr::new(&key, &nonce);
        let mut whole: Vec<u8> = (0..1000u32).map(|i| (i * 7 % 256) as u8).collect();
        let orig = whole.clone();
        c.apply(&mut whole);
        // Re-encrypt the same plaintext in misaligned pieces.
        for split in [1usize, 15, 16, 17, 333] {
            let mut pieces = orig.clone();
            let (a, b) = pieces.split_at_mut(split);
            c.apply_at(a, 0);
            c.apply_at(b, split as u64);
            assert_eq!(pieces, whole, "split at {split}");
        }
    }

    #[test]
    fn counter_block_carry_propagates() {
        let nonce = [0xffu8; 16];
        let b = counter_block(&nonce, 1);
        assert_eq!(b, [0u8; 16], "all-ones nonce + 1 wraps to zero");
    }

    #[test]
    fn different_nonce_different_keystream() {
        let key = [5u8; 32];
        let mut a = vec![0u8; 64];
        let mut b = vec![0u8; 64];
        AesCtr::new(&key, &[0u8; 16]).apply(&mut a);
        AesCtr::new(&key, &[1u8; 16]).apply(&mut b);
        assert_ne!(a, b);
    }
}
