//! Security substrate (paper §IV-E): SHA3-256 integrity hashing
//! (Algorithms 1-2 pack the object hash with every chunk), AES-256-CTR
//! point-to-point confidentiality for the client, and HMAC-SHA256 OAuth
//! style bearer tokens validated at the gateway.

pub mod aes_ctr;
pub mod sha3;
pub mod token;

pub use aes_ctr::AesCtr;
pub use sha3::{sha3_256, Sha3_256};
pub use token::{Claims, TokenService};
