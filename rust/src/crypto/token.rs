//! OAuth-style bearer tokens (paper §IV-E1): the authentication service
//! issues a token encapsulating user identity and scopes; the gateway
//! validates it on every request.
//!
//! Wire format: `base64url-ish(payload_json) . hex(hmac_sha256(payload))`
//! — self-contained claims + signature, the usual bearer-token shape,
//! signed with the service's secret (HMAC-SHA256 from the vendored
//! `hmac`/`sha2` crates).

use hmac::{Hmac, Mac};
use sha2::Sha256;

use crate::json::{obj, parse, to_string, Value};
use crate::util::{from_hex, to_hex, unix_secs};
use crate::{Error, Result};

type HmacSha256 = Hmac<Sha256>;

/// Token claims: subject (user), scopes, expiry.
#[derive(Debug, Clone, PartialEq)]
pub struct Claims {
    pub subject: String,
    pub scopes: Vec<String>,
    /// Unix seconds after which the token is invalid.
    pub expires_at: u64,
}

impl Claims {
    pub fn has_scope(&self, scope: &str) -> bool {
        self.scopes.iter().any(|s| s == scope || s == "*")
    }
}

/// Issues and validates bearer tokens.
pub struct TokenService {
    secret: Vec<u8>,
}

impl TokenService {
    pub fn new(secret: &[u8]) -> Self {
        TokenService { secret: secret.to_vec() }
    }

    /// Issue a token for `subject` with `scopes`, valid `ttl_secs`.
    pub fn issue(&self, subject: &str, scopes: &[&str], ttl_secs: u64) -> String {
        self.issue_at(subject, scopes, unix_secs() + ttl_secs)
    }

    /// Issue with an explicit expiry timestamp (tests, clock injection).
    pub fn issue_at(&self, subject: &str, scopes: &[&str], expires_at: u64) -> String {
        let payload = to_string(&obj(vec![
            ("sub", subject.into()),
            (
                "scopes",
                Value::Arr(scopes.iter().map(|s| Value::from(*s)).collect()),
            ),
            ("exp", expires_at.into()),
        ]));
        let sig = self.sign(payload.as_bytes());
        format!("{}.{}", to_hex(payload.as_bytes()), to_hex(&sig))
    }

    /// Validate signature + expiry; returns the claims.
    pub fn validate(&self, token: &str) -> Result<Claims> {
        self.validate_at(token, unix_secs())
    }

    /// Validate against an explicit "now" (tests, simulated clock).
    pub fn validate_at(&self, token: &str, now: u64) -> Result<Claims> {
        let (payload_hex, sig_hex) = token
            .split_once('.')
            .ok_or_else(|| Error::Auth("malformed token".into()))?;
        let payload =
            from_hex(payload_hex).ok_or_else(|| Error::Auth("bad payload encoding".into()))?;
        let sig = from_hex(sig_hex).ok_or_else(|| Error::Auth("bad signature encoding".into()))?;
        let expect = self.sign(&payload);
        // Constant-time comparison via HMAC verify.
        let mut mac = HmacSha256::new_from_slice(&self.secret).expect("hmac key");
        mac.update(&payload);
        mac.verify_slice(&sig)
            .map_err(|_| Error::Auth("signature mismatch".into()))?;
        let _ = expect;
        let text =
            String::from_utf8(payload).map_err(|_| Error::Auth("payload not utf-8".into()))?;
        let v = parse(&text).map_err(|_| Error::Auth("payload not json".into()))?;
        let claims = Claims {
            subject: v.req_str("sub").map_err(|_| Error::Auth("no sub".into()))?.to_string(),
            scopes: v
                .get("scopes")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|s| s.as_str().map(str::to_string))
                .collect(),
            expires_at: v.req_u64("exp").map_err(|_| Error::Auth("no exp".into()))?,
        };
        if now >= claims.expires_at {
            return Err(Error::Auth("token expired".into()));
        }
        Ok(claims)
    }

    fn sign(&self, data: &[u8]) -> Vec<u8> {
        let mut mac = HmacSha256::new_from_slice(&self.secret).expect("hmac key");
        mac.update(data);
        mac.finalize().into_bytes().to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svc() -> TokenService {
        TokenService::new(b"test-secret-please-rotate")
    }

    #[test]
    fn issue_validate_roundtrip() {
        let s = svc();
        let tok = s.issue_at("userA", &["read", "write"], 1_000);
        let claims = s.validate_at(&tok, 500).unwrap();
        assert_eq!(claims.subject, "userA");
        assert!(claims.has_scope("read"));
        assert!(claims.has_scope("write"));
        assert!(!claims.has_scope("admin"));
    }

    #[test]
    fn wildcard_scope() {
        let s = svc();
        let tok = s.issue_at("admin", &["*"], 1_000);
        let claims = s.validate_at(&tok, 1).unwrap();
        assert!(claims.has_scope("anything"));
    }

    #[test]
    fn expired_token_rejected() {
        let s = svc();
        let tok = s.issue_at("userA", &["read"], 100);
        assert!(matches!(s.validate_at(&tok, 100), Err(Error::Auth(_))));
        assert!(matches!(s.validate_at(&tok, 101), Err(Error::Auth(_))));
        assert!(s.validate_at(&tok, 99).is_ok());
    }

    #[test]
    fn tampered_payload_rejected() {
        let s = svc();
        let tok = s.issue_at("userA", &["read"], 1_000);
        // Flip a nibble in the payload hex.
        let mut chars: Vec<char> = tok.chars().collect();
        chars[4] = if chars[4] == '0' { '1' } else { '0' };
        let forged: String = chars.into_iter().collect();
        assert!(matches!(s.validate_at(&forged, 1), Err(Error::Auth(_))));
    }

    #[test]
    fn wrong_secret_rejected() {
        let s = svc();
        let other = TokenService::new(b"different-secret");
        let tok = s.issue_at("userA", &["read"], 1_000);
        assert!(matches!(other.validate_at(&tok, 1), Err(Error::Auth(_))));
    }

    #[test]
    fn garbage_tokens_rejected() {
        let s = svc();
        for bad in ["", "no-dot", "zz.zz", "abcd.", ".abcd"] {
            assert!(s.validate_at(bad, 1).is_err(), "{bad:?}");
        }
    }
}
