//! FaaS case-study substrate (paper §VI-E/F): a Globus-Compute-style
//! function executor plus a ProxyStore-style proxy layer, over a
//! pluggable data fabric (DynoStore or one of the baselines).
//!
//! The paper's two case studies run image-processing functions across
//! distributed workers; each function pulls its input through the data
//! fabric, computes, and pushes its output back. The executor models the
//! worker pool (16/32/64 workers in Fig. 11) and accounts simulated
//! time as the makespan over workers.

use std::sync::Arc;

use crate::sim::Site;
use crate::{Error, Result};

/// The data-plane interface the case studies program against — the role
/// ProxyStore's connector plays in the paper (§V). DynoStore and every
/// baseline implement this.
pub trait DataFabric: Send + Sync {
    /// Store bytes under a key; returns simulated seconds.
    fn put(&self, key: &str, data: &[u8]) -> Result<f64>;
    /// Fetch bytes; returns (data, simulated seconds).
    fn get(&self, key: &str) -> Result<(Vec<u8>, f64)>;
    fn exists(&self, key: &str) -> bool;
    fn fabric_name(&self) -> &'static str;
}

/// A ProxyStore-style proxy: a lightweight reference to an object living
/// in the fabric; `resolve` materializes it (paper §V: "a Python program
/// can consume this reference as a native object, but it is stored in a
/// remote location").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Proxy {
    pub key: String,
    pub size: u64,
}

/// Proxy layer over a fabric.
pub struct ProxyStore {
    fabric: Arc<dyn DataFabric>,
}

impl ProxyStore {
    pub fn new(fabric: Arc<dyn DataFabric>) -> Self {
        ProxyStore { fabric }
    }

    /// Store `data` and hand back a proxy (accumulates sim time).
    pub fn proxy(&self, key: &str, data: &[u8]) -> Result<(Proxy, f64)> {
        let sim_s = self.fabric.put(key, data)?;
        Ok((Proxy { key: key.to_string(), size: data.len() as u64 }, sim_s))
    }

    /// Materialize a proxy.
    pub fn resolve(&self, p: &Proxy) -> Result<(Vec<u8>, f64)> {
        self.fabric.get(&p.key)
    }

    pub fn fabric(&self) -> &Arc<dyn DataFabric> {
        &self.fabric
    }
}

/// One FaaS task: pull input proxy, compute for `compute_s` simulated
/// seconds (the image-processing function body), push output.
#[derive(Debug, Clone)]
pub struct Task {
    pub input: Proxy,
    pub output_key: String,
    /// Simulated compute seconds (calibrated per case study).
    pub compute_s: f64,
    /// Output size as a fraction of input (e.g. segmentation mask ≈ 0.2).
    pub output_ratio: f64,
}

/// Executor report: the numbers Figs. 10-11 plot.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub tasks: usize,
    pub workers: usize,
    /// Simulated makespan (what the paper's y-axes show).
    pub sim_s: f64,
    /// Total bytes moved through the fabric.
    pub bytes_moved: u64,
    pub failures: usize,
}

/// Globus-Compute-style executor: `workers` parallel workers at a site
/// drain the task queue; per-task time = input fetch + compute + output
/// store; makespan = max over workers of their serial share.
pub struct Executor {
    pub workers: usize,
    pub site: Site,
    /// Serial per-task scheduling overhead at the coordinator (Globus
    /// Compute submission + result routing, ~50 ms measured in the
    /// paper's stack). This is the Amdahl term behind Fig. 11's 28-30%
    /// (not 4x) improvement from 16 -> 64 workers.
    pub dispatch_s: f64,
}

impl Executor {
    pub fn new(workers: usize, site: Site) -> Self {
        Executor { workers: workers.max(1), site, dispatch_s: 0.0 }
    }

    pub fn with_dispatch(mut self, dispatch_s: f64) -> Self {
        self.dispatch_s = dispatch_s;
        self
    }

    pub fn run(&self, store: &ProxyStore, tasks: &[Task]) -> Result<RunReport> {
        let mut worker_time = vec![0.0f64; self.workers];
        let mut report = RunReport {
            tasks: tasks.len(),
            workers: self.workers,
            ..Default::default()
        };
        for (i, task) in tasks.iter().enumerate() {
            let w = i % self.workers;
            let (input, fetch_s) = match store.resolve(&task.input) {
                Ok(x) => x,
                Err(Error::Unavailable(_)) | Err(Error::NotFound(_)) => {
                    report.failures += 1;
                    continue;
                }
                Err(e) => return Err(e),
            };
            let out_len = ((input.len() as f64) * task.output_ratio).ceil() as usize;
            let output = produce_output(&input, out_len);
            let store_s = store.fabric.put(&task.output_key, &output)?;
            worker_time[w] += fetch_s + task.compute_s + store_s;
            report.bytes_moved += (input.len() + output.len()) as u64;
        }
        let serial = self.dispatch_s * tasks.len() as f64;
        report.sim_s = serial + worker_time.iter().cloned().fold(0.0, f64::max);
        Ok(report)
    }
}

/// Deterministic "processing" so outputs depend on inputs (keeps the
/// data plane honest — a wrong fetch corrupts downstream hashes).
fn produce_output(input: &[u8], out_len: usize) -> Vec<u8> {
    let mut out = vec![0u8; out_len];
    let mut acc: u8 = 0x5A;
    for (i, o) in out.iter_mut().enumerate() {
        acc = acc.wrapping_add(input[i % input.len().max(1)]).rotate_left(3);
        *o = acc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;
    use std::sync::Mutex;

    /// Trivial in-memory fabric with fixed per-op cost for unit tests.
    struct TestFabric {
        map: Mutex<HashMap<String, Vec<u8>>>,
        op_cost: f64,
    }

    impl DataFabric for TestFabric {
        fn put(&self, key: &str, data: &[u8]) -> Result<f64> {
            self.map.lock().unwrap().insert(key.into(), data.to_vec());
            Ok(self.op_cost)
        }

        fn get(&self, key: &str) -> Result<(Vec<u8>, f64)> {
            self.map
                .lock()
                .unwrap()
                .get(key)
                .cloned()
                .map(|d| (d, self.op_cost))
                .ok_or_else(|| Error::NotFound(key.into()))
        }

        fn exists(&self, key: &str) -> bool {
            self.map.lock().unwrap().contains_key(key)
        }

        fn fabric_name(&self) -> &'static str {
            "test"
        }
    }

    fn setup(op_cost: f64) -> (ProxyStore, Vec<Task>) {
        let fabric = Arc::new(TestFabric { map: Mutex::new(HashMap::new()), op_cost });
        let store = ProxyStore::new(fabric);
        let tasks: Vec<Task> = (0..40)
            .map(|i| {
                let (proxy, _) =
                    store.proxy(&format!("in/{i}"), &vec![i as u8; 1000]).unwrap();
                Task {
                    input: proxy,
                    output_key: format!("out/{i}"),
                    compute_s: 0.5,
                    output_ratio: 0.25,
                }
            })
            .collect();
        (store, tasks)
    }

    #[test]
    fn workers_reduce_makespan() {
        // Fig. 11 shape: 16 → 64 workers cuts response time ~28-30%.
        let (store, tasks) = setup(0.1);
        let t1 = Executor::new(1, Site::ChameleonTacc).run(&store, &tasks).unwrap();
        let t4 = Executor::new(4, Site::ChameleonTacc).run(&store, &tasks).unwrap();
        let t8 = Executor::new(8, Site::ChameleonTacc).run(&store, &tasks).unwrap();
        assert!(t4.sim_s < t1.sim_s / 3.0);
        assert!(t8.sim_s < t4.sim_s);
        assert_eq!(t8.failures, 0);
    }

    #[test]
    fn outputs_are_stored() {
        let (store, tasks) = setup(0.01);
        Executor::new(4, Site::ChameleonTacc).run(&store, &tasks).unwrap();
        for t in &tasks {
            assert!(store.fabric().exists(&t.output_key), "{}", t.output_key);
        }
    }

    #[test]
    fn missing_inputs_counted_as_failures() {
        let (store, mut tasks) = setup(0.01);
        tasks[3].input.key = "in/ghost".into();
        tasks[7].input.key = "in/ghost2".into();
        let report = Executor::new(2, Site::ChameleonTacc).run(&store, &tasks).unwrap();
        assert_eq!(report.failures, 2);
        assert!(!store.fabric().exists(&tasks[3].output_key));
    }

    #[test]
    fn proxy_roundtrip() {
        let fabric =
            Arc::new(TestFabric { map: Mutex::new(HashMap::new()), op_cost: 0.0 });
        let store = ProxyStore::new(fabric);
        let (p, _) = store.proxy("k", b"hello").unwrap();
        assert_eq!(p.size, 5);
        assert_eq!(store.resolve(&p).unwrap().0, b"hello");
    }

    #[test]
    fn produce_output_depends_on_input() {
        let a = produce_output(b"aaaa", 16);
        let b = produce_output(b"aaab", 16);
        assert_ne!(a, b);
        assert_eq!(a.len(), 16);
    }
}
