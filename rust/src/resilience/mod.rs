//! The unified resilience layer: retry budgets, request deadlines, and
//! per-container circuit breakers.
//!
//! The paper's robustness claim (§VI-D) is that DynoStore "withstands
//! more failures than traditional systems". Before this module the
//! crate's failure handling was scattered ad-hoc mechanisms — hedged
//! parity waves, a 500 ms liveness TTL in `RemoteChannel`, one
//! fail-fast HTTP timeout. This module centralizes the three policies
//! every hop now shares:
//!
//! * [`RetryPolicy`] — exponential backoff with *decorrelated jitter*
//!   (each sleep is drawn uniformly from `[base, 3×previous]`, capped),
//!   bounded both by an attempt count and a total sleep budget so a
//!   retry storm can never exceed a known worst-case latency.
//! * [`Deadline`] — a per-request time budget created at the edge
//!   (client `--deadline-ms`, gateway `x-dyno-deadline-ms` header) and
//!   propagated gateway → coordinator → channel → `HttpClient`. Expired
//!   deadlines short-circuit with [`Error::Timeout`] (HTTP 504) instead
//!   of queueing doomed work.
//! * [`CircuitBreaker`] — per-container closed → open → half-open state
//!   machine with single-probe admission, replacing `RemoteChannel`'s
//!   dead-mark + info-TTL liveness. While open, every request is shed
//!   locally (no connect, no timeout wait); after a cooldown exactly one
//!   probe is admitted and its outcome decides between closing the
//!   breaker and re-opening it.
//!
//! All three are deterministic given their inputs: the retry jitter is
//! seeded, and the breaker takes an explicit `now_ms` so property tests
//! (and the chaos suite) can drive it on a logical clock.

use std::sync::Mutex;
use std::time::Duration;

use crate::util::{now_ns, Rng};
use crate::{Error, Result};

/// Monotonic milliseconds since an arbitrary process-local epoch
/// (wraps `util::now_ns`; used by deadlines and breaker cooldowns).
pub fn mono_ms() -> u64 {
    now_ns() / 1_000_000
}

// ---------------------------------------------------------------------
// Deadline
// ---------------------------------------------------------------------

/// A per-request time budget. `Deadline::none()` (the `Default`) never
/// expires; `Deadline::in_ms(b)` expires `b` milliseconds after
/// creation. Copyable so it rides inside `OpContext` through every
/// coordinator hop.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Deadline {
    /// Monotonic ms at which the budget runs out (`None` = unbounded).
    expires_at_ms: Option<u64>,
}

impl Deadline {
    /// No deadline: every remaining-budget query reports unbounded.
    pub fn none() -> Deadline {
        Deadline { expires_at_ms: None }
    }

    /// Expires `budget_ms` from now (a budget of 0 is already expired —
    /// the short-circuit path, exercised by gateway tests).
    pub fn in_ms(budget_ms: u64) -> Deadline {
        Deadline { expires_at_ms: Some(mono_ms().saturating_add(budget_ms)) }
    }

    pub fn is_none(&self) -> bool {
        self.expires_at_ms.is_none()
    }

    /// Remaining budget in ms; `None` when unbounded, `Some(0)` when
    /// expired.
    pub fn remaining_ms(&self) -> Option<u64> {
        self.expires_at_ms.map(|at| at.saturating_sub(mono_ms()))
    }

    pub fn expired(&self) -> bool {
        self.remaining_ms() == Some(0)
    }

    /// `Err(Error::Timeout)` when the budget is gone — the uniform
    /// short-circuit every hop calls before starting (more) work.
    pub fn check(&self, what: &str) -> Result<()> {
        if self.expired() {
            Err(Error::Timeout(format!("deadline exceeded before {what}")))
        } else {
            Ok(())
        }
    }

    /// Clamp a transport timeout to the remaining budget: a hop must
    /// never wait longer than the request has left to live. `None` when
    /// already expired (callers short-circuit via [`Deadline::check`]).
    pub fn clamp_timeout(&self, timeout: Duration) -> Option<Duration> {
        match self.remaining_ms() {
            None => Some(timeout),
            Some(0) => None,
            Some(ms) => Some(timeout.min(Duration::from_millis(ms))),
        }
    }
}

// ---------------------------------------------------------------------
// RetryPolicy
// ---------------------------------------------------------------------

/// Budget-capped exponential backoff with decorrelated jitter
/// (`sleep = min(cap, uniform(base, 3 × previous_sleep))`).
///
/// Two independent bounds stop a retry storm: `max_attempts` and
/// `budget_ms` (total sleep across all backoffs). A [`Deadline`] passed
/// to [`RetryPolicy::run`] adds a third: no backoff sleep may outlive
/// the request budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total tries including the first (1 = no retries).
    pub max_attempts: u32,
    /// First / minimum backoff sleep in ms.
    pub base_ms: u64,
    /// Per-sleep ceiling in ms.
    pub cap_ms: u64,
    /// Total sleep budget across every backoff in ms.
    pub budget_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy::standard()
    }
}

impl RetryPolicy {
    /// The deployment default: up to 4 tries, 25 ms base, 1 s cap,
    /// 2 s total sleep budget.
    pub fn standard() -> RetryPolicy {
        RetryPolicy { max_attempts: 4, base_ms: 25, cap_ms: 1_000, budget_ms: 2_000 }
    }

    /// Single attempt, no sleeping — for callers that hedge elsewhere
    /// (the coordinator's parity waves) or cannot tolerate replays.
    pub fn none() -> RetryPolicy {
        RetryPolicy { max_attempts: 1, base_ms: 0, cap_ms: 0, budget_ms: 0 }
    }

    /// The next decorrelated-jitter sleep given the previous one
    /// (0 = first backoff). Pure given the Rng state, so seeded runs
    /// replay exactly.
    pub fn backoff_ms(&self, rng: &mut Rng, prev_ms: u64) -> u64 {
        let lo = self.base_ms;
        let hi = (prev_ms.saturating_mul(3)).max(lo + 1);
        rng.range(lo, hi).min(self.cap_ms)
    }

    /// Run `op` under this policy: retry on [`Error::is_retryable`]
    /// failures until the attempt count, the sleep budget, or the
    /// deadline is exhausted. Non-retryable errors surface immediately.
    /// `attempts` receives the 0-based attempt index.
    pub fn run<T>(
        &self,
        seed: u64,
        deadline: Deadline,
        mut op: impl FnMut(u32) -> Result<T>,
    ) -> Result<T> {
        let mut rng = Rng::new(seed);
        let mut slept_ms = 0u64;
        let mut prev_ms = 0u64;
        let mut attempt = 0u32;
        loop {
            deadline.check("attempt")?;
            match op(attempt) {
                Ok(v) => return Ok(v),
                Err(e) if !e.is_retryable() => return Err(e),
                Err(e) => {
                    attempt += 1;
                    if attempt >= self.max_attempts.max(1) {
                        return Err(e);
                    }
                    let sleep = self.backoff_ms(&mut rng, prev_ms);
                    if slept_ms.saturating_add(sleep) > self.budget_ms {
                        return Err(e);
                    }
                    if let Some(left) = deadline.remaining_ms() {
                        if sleep >= left {
                            // Sleeping would outlive the request: the
                            // retry is doomed, surface the last error.
                            return Err(e);
                        }
                    }
                    if sleep > 0 {
                        std::thread::sleep(Duration::from_millis(sleep));
                    }
                    slept_ms += sleep;
                    prev_ms = sleep;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// CircuitBreaker
// ---------------------------------------------------------------------

/// Breaker states, surfaced by `/health` per container.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Normal operation; consecutive failures are counted.
    Closed,
    /// Tripped: requests are shed locally until the cooldown elapses.
    Open,
    /// Cooldown elapsed and one probe is in flight; everyone else is
    /// still shed until the probe reports.
    HalfOpen,
}

impl BreakerState {
    pub fn as_str(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

#[derive(Debug)]
struct BreakerInner {
    state: BreakerState,
    consecutive_failures: u32,
    opened_at_ms: u64,
}

/// Per-container circuit breaker: `threshold` consecutive failures trip
/// it open; after `cooldown_ms` exactly one caller is admitted as a
/// probe (half-open); the probe's outcome closes or re-opens it.
///
/// Time is an explicit `now_ms` parameter so the state machine is a
/// pure function of its call sequence — the property tests drive it on
/// a logical clock. Production callers pass [`mono_ms`].
#[derive(Debug)]
pub struct CircuitBreaker {
    threshold: u32,
    cooldown_ms: u64,
    inner: Mutex<BreakerInner>,
}

/// Consecutive transport failures before the breaker opens.
pub const DEFAULT_BREAKER_THRESHOLD: u32 = 3;

/// Cooldown before an open breaker admits its half-open probe. Matches
/// the old liveness-TTL order of magnitude so pull waves re-try a
/// recovered container promptly.
pub const DEFAULT_BREAKER_COOLDOWN_MS: u64 = 500;

impl Default for CircuitBreaker {
    fn default() -> Self {
        CircuitBreaker::new(DEFAULT_BREAKER_THRESHOLD, DEFAULT_BREAKER_COOLDOWN_MS)
    }
}

impl CircuitBreaker {
    pub fn new(threshold: u32, cooldown_ms: u64) -> CircuitBreaker {
        CircuitBreaker {
            threshold: threshold.max(1),
            cooldown_ms,
            inner: Mutex::new(BreakerInner {
                state: BreakerState::Closed,
                consecutive_failures: 0,
                opened_at_ms: 0,
            }),
        }
    }

    pub fn state(&self) -> BreakerState {
        self.inner.lock().unwrap().state
    }

    /// May a request proceed at `now_ms`?
    ///
    /// * Closed → yes.
    /// * Open, cooldown not elapsed → no (shed locally).
    /// * Open, cooldown elapsed → this caller becomes THE probe: the
    ///   breaker transitions to half-open and returns true; every other
    ///   caller sees half-open and is refused until the probe reports
    ///   via [`CircuitBreaker::record_success`] / `record_failure`.
    pub fn admit(&self, now_ms: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.state {
            BreakerState::Closed => true,
            BreakerState::HalfOpen => false,
            BreakerState::Open => {
                if now_ms.saturating_sub(inner.opened_at_ms) >= self.cooldown_ms {
                    inner.state = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Whether the container looks usable without claiming the probe
    /// slot (read-only view for wave planning / health reporting).
    pub fn looks_alive(&self, now_ms: u64) -> bool {
        let inner = self.inner.lock().unwrap();
        match inner.state {
            BreakerState::Closed => true,
            BreakerState::HalfOpen => false,
            BreakerState::Open => {
                now_ms.saturating_sub(inner.opened_at_ms) >= self.cooldown_ms
            }
        }
    }

    /// A request (or the half-open probe) succeeded.
    pub fn record_success(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.state = BreakerState::Closed;
        inner.consecutive_failures = 0;
    }

    /// A request (or the half-open probe) failed at `now_ms`.
    pub fn record_failure(&self, now_ms: u64) {
        let mut inner = self.inner.lock().unwrap();
        match inner.state {
            BreakerState::Closed => {
                inner.consecutive_failures += 1;
                if inner.consecutive_failures >= self.threshold {
                    inner.state = BreakerState::Open;
                    inner.opened_at_ms = now_ms;
                }
            }
            // A failed probe re-opens and restarts the cooldown.
            BreakerState::HalfOpen => {
                inner.state = BreakerState::Open;
                inner.opened_at_ms = now_ms;
            }
            // A straggler that was already in flight when the breaker
            // tripped: its failure is old news, the cooldown stands.
            BreakerState::Open => {}
        }
    }

    /// Force a known liveness verdict (admin `set_alive`, tests):
    /// `true` closes the breaker, `false` trips it open immediately.
    pub fn force(&self, alive: bool, now_ms: u64) {
        let mut inner = self.inner.lock().unwrap();
        if alive {
            inner.state = BreakerState::Closed;
            inner.consecutive_failures = 0;
        } else {
            inner.state = BreakerState::Open;
            inner.consecutive_failures = self.threshold;
            inner.opened_at_ms = now_ms;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, prop_assert};

    #[test]
    fn deadline_none_never_expires() {
        let d = Deadline::none();
        assert!(d.is_none());
        assert!(!d.expired());
        assert_eq!(d.remaining_ms(), None);
        assert!(d.check("x").is_ok());
        assert_eq!(
            d.clamp_timeout(Duration::from_secs(10)),
            Some(Duration::from_secs(10))
        );
    }

    #[test]
    fn deadline_zero_budget_is_expired() {
        let d = Deadline::in_ms(0);
        assert!(d.expired());
        assert!(matches!(d.check("push"), Err(Error::Timeout(_))));
        assert_eq!(d.clamp_timeout(Duration::from_secs(10)), None);
    }

    #[test]
    fn deadline_clamps_transport_timeouts() {
        let d = Deadline::in_ms(50);
        let clamped = d.clamp_timeout(Duration::from_secs(10)).unwrap();
        assert!(clamped <= Duration::from_millis(50));
        let unclamped = d.clamp_timeout(Duration::from_millis(1)).unwrap();
        assert_eq!(unclamped, Duration::from_millis(1));
    }

    #[test]
    fn retry_surfaces_non_retryable_immediately() {
        let mut calls = 0;
        let res: Result<()> = RetryPolicy::standard().run(1, Deadline::none(), |_| {
            calls += 1;
            Err(Error::NotFound("gone".into()))
        });
        assert!(matches!(res, Err(Error::NotFound(_))));
        assert_eq!(calls, 1);
    }

    #[test]
    fn retry_retries_until_success() {
        let policy = RetryPolicy { max_attempts: 5, base_ms: 0, cap_ms: 0, budget_ms: 10 };
        let mut calls = 0;
        let res = policy.run(1, Deadline::none(), |attempt| {
            calls += 1;
            if attempt < 3 {
                Err(Error::Unavailable("flaky".into()))
            } else {
                Ok(attempt)
            }
        });
        assert_eq!(res.unwrap(), 3);
        assert_eq!(calls, 4);
    }

    #[test]
    fn retry_respects_attempt_cap() {
        let policy = RetryPolicy { max_attempts: 3, base_ms: 0, cap_ms: 0, budget_ms: 10 };
        let mut calls = 0;
        let res: Result<()> = policy.run(1, Deadline::none(), |_| {
            calls += 1;
            Err(Error::Net("down".into()))
        });
        assert!(matches!(res, Err(Error::Net(_))));
        assert_eq!(calls, 3);
    }

    #[test]
    fn retry_expired_deadline_short_circuits() {
        let mut calls = 0;
        let res: Result<()> = RetryPolicy::standard().run(1, Deadline::in_ms(0), |_| {
            calls += 1;
            Ok(())
        });
        assert!(matches!(res, Err(Error::Timeout(_))));
        assert_eq!(calls, 0, "no attempt is even started on an expired budget");
    }

    #[test]
    fn backoff_is_decorrelated_and_capped() {
        let policy = RetryPolicy { max_attempts: 10, base_ms: 10, cap_ms: 100, budget_ms: 1000 };
        forall(50, |g| {
            let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
            let mut prev = 0;
            for _ in 0..8 {
                let s = policy.backoff_ms(&mut rng, prev);
                prop_assert(s >= policy.base_ms.min(policy.cap_ms), "above base")?;
                prop_assert(s <= policy.cap_ms, "below cap")?;
                prev = s;
            }
            Ok(())
        });
    }

    #[test]
    fn breaker_trips_after_threshold() {
        let b = CircuitBreaker::new(3, 100);
        assert_eq!(b.state(), BreakerState::Closed);
        b.record_failure(0);
        b.record_failure(1);
        assert_eq!(b.state(), BreakerState::Closed, "below threshold");
        b.record_failure(2);
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.admit(50), "sheds during cooldown");
        assert!(b.admit(102), "cooldown elapsed: probe admitted");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.admit(103), "half-open admits exactly one probe");
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn failed_probe_reopens_with_fresh_cooldown() {
        let b = CircuitBreaker::new(1, 100);
        b.record_failure(0);
        assert!(b.admit(100));
        b.record_failure(100);
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.admit(150), "cooldown restarted at the probe failure");
        assert!(b.admit(200));
    }

    #[test]
    fn success_resets_consecutive_failures() {
        let b = CircuitBreaker::new(3, 100);
        b.record_failure(0);
        b.record_failure(1);
        b.record_success();
        b.record_failure(2);
        b.record_failure(3);
        assert_eq!(b.state(), BreakerState::Closed, "streak was broken");
    }

    #[test]
    fn force_overrides_state() {
        let b = CircuitBreaker::default();
        b.force(false, 10);
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.admit(10));
        b.force(true, 20);
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.admit(20));
    }

    /// Property: replaying a random sequence of breaker events against
    /// a reference model, the breaker (a) never serves while open
    /// inside the cooldown, (b) admits exactly one probe per half-open
    /// episode, and (c) is closed exactly when the model says so.
    #[test]
    fn breaker_state_machine_property() {
        forall(200, |g| {
            let threshold = g.u64(1, 5) as u32;
            let cooldown = g.u64(1, 50);
            let b = CircuitBreaker::new(threshold, cooldown);
            // Reference model.
            let mut state = BreakerState::Closed;
            let mut fails = 0u32;
            let mut opened_at = 0u64;
            let mut now = 0u64;
            for _ in 0..g.usize(1, 60) {
                now += g.u64(0, 20);
                match g.usize(0, 2) {
                    0 => {
                        // admit
                        let admitted = b.admit(now);
                        let expect = match state {
                            BreakerState::Closed => true,
                            BreakerState::HalfOpen => false,
                            BreakerState::Open => now - opened_at >= cooldown,
                        };
                        prop_assert(admitted == expect, "admit matches model")?;
                        if admitted && state == BreakerState::Open {
                            state = BreakerState::HalfOpen;
                        }
                        if state == BreakerState::Open && now - opened_at < cooldown {
                            prop_assert(!admitted, "never serves from open")?;
                        }
                    }
                    1 => {
                        // success
                        b.record_success();
                        state = BreakerState::Closed;
                        fails = 0;
                    }
                    _ => {
                        // failure
                        b.record_failure(now);
                        match state {
                            BreakerState::Closed => {
                                fails += 1;
                                if fails >= threshold {
                                    state = BreakerState::Open;
                                    opened_at = now;
                                }
                            }
                            BreakerState::HalfOpen => {
                                state = BreakerState::Open;
                                opened_at = now;
                            }
                            BreakerState::Open => {}
                        }
                    }
                }
                prop_assert(b.state() == state, "state matches model")?;
            }
            Ok(())
        });
    }
}
