//! Post-recovery placement verification: recovered metadata is the
//! truth about what was *acknowledged*, but a crash (or operator
//! surgery between runs) can leave registry reality behind it — a
//! container never re-registered, a chunk file lost with its disk. This
//! pass re-verifies every recovered placement against what the
//! registered containers actually hold and schedules repair for the
//! gaps, so the durability guarantee extends end to end: every
//! acknowledged object is either byte-identically servable or
//! explicitly reported lost.

use std::sync::Arc;

use crate::container::ContainerChannel;
use crate::erasure::ErasureConfig;
use crate::metadata::ObjectPlacement;
use crate::Result;

use super::ops::{chunk_key, object_key, ChunkJob};
use super::reports::RepairReport;
use super::DynoStore;

/// Outcome of [`DynoStore::verify_recovered_placements`].
#[derive(Debug, Default)]
pub struct RecoveryVerifyReport {
    /// Object versions scanned.
    pub objects: usize,
    /// Chunk slots (or single copies) the recovered placements name.
    pub chunks_expected: usize,
    /// Slots whose bytes were not where the placement says: container
    /// unregistered/dead, or registered but missing the key.
    pub chunks_missing: usize,
    /// Missing chunks rebuilt from parity and rewritten onto their
    /// committed (live, registered) container — no placement change.
    pub chunks_rewritten: usize,
    /// Objects with fewer than k recoverable chunks (or a vanished
    /// single copy): acknowledged but no longer servable.
    pub objects_lost: usize,
    /// A repair pass ran because some chunks sat on unreachable
    /// containers and needed re-placement.
    pub repair_scheduled: bool,
    pub repair: RepairReport,
}

impl DynoStore {
    /// Re-verify every recovered placement against registry reality.
    ///
    /// Two kinds of gap, two remedies:
    ///
    /// * A chunk **missing on a live, registered container** (the chunk
    ///   write raced the crash, or the backend lost the file) is
    ///   rebuilt from any k surviving chunks and rewritten in place —
    ///   the committed placement stays correct, no Paxos commit needed.
    /// * A chunk on an **unregistered or dead container** needs
    ///   re-placement (a placement change), which is exactly
    ///   [`DynoStore::repair`]'s job — one pass is scheduled at the end
    ///   when any such chunk was seen.
    ///
    /// Call after the deployment's containers are registered;
    /// `Config::build` does this automatically for durable deployments
    /// that recovered state.
    pub fn verify_recovered_placements(&self) -> Result<RecoveryVerifyReport> {
        let mut report = RecoveryVerifyReport::default();
        let mut needs_repair = false;
        // Shard by shard so a metadata shard whose recovery degraded
        // (torn tail, poisoned WAL) only blocks verification of its own
        // namespaces. The per-object loop stays serial: chunk probes
        // and rebuilds inside `verify_erasure_unit` already fan out on
        // the io_pool, and the pool's scatter/gather must not nest.
        for shard in 0..self.meta.shard_count() {
            let objects = self.meta.shard(shard).read(|s| Ok(s.all_objects()))?;
            self.verify_object_set(objects, &mut report, &mut needs_repair)?;
        }
        if needs_repair {
            report.repair_scheduled = true;
            report.repair = self.repair()?;
        }
        Ok(report)
    }

    /// Verify one shard's recovered placements into the shared report.
    fn verify_object_set(
        &self,
        objects: Vec<crate::metadata::ObjectMeta>,
        report: &mut RecoveryVerifyReport,
        needs_repair: &mut bool,
    ) -> Result<()> {
        for meta in objects {
            report.objects += 1;
            match &meta.placement {
                ObjectPlacement::Single { container } => {
                    report.chunks_expected += 1;
                    let key = object_key(&meta.sha3, meta.size);
                    let present = self
                        .registry
                        .get(*container)
                        .map(|c| c.is_alive() && c.exists(&key).unwrap_or(false))
                        .unwrap_or(false);
                    if !present {
                        // A Regular object has no parity to rebuild
                        // from; repair also reports these as lost.
                        report.chunks_missing += 1;
                        report.objects_lost += 1;
                    }
                }
                ObjectPlacement::Erasure { n, k, chunks } => {
                    if self.verify_erasure_unit(
                        &meta.sha3,
                        meta.size,
                        *n,
                        *k,
                        chunks,
                        report,
                        needs_repair,
                    )? {
                        report.objects_lost += 1;
                    }
                }
                ObjectPlacement::Striped { parts } => {
                    // Each part is an independent erasure unit keyed by
                    // its own hash/size; the object is lost if ANY part
                    // is (it cannot be served whole).
                    let mut lost = false;
                    for part in parts {
                        lost |= self.verify_erasure_unit(
                            &part.sha3,
                            part.size,
                            part.n,
                            part.k,
                            &part.chunks,
                            report,
                            needs_repair,
                        )?;
                    }
                    if lost {
                        report.objects_lost += 1;
                    }
                }
            }
        }
        Ok(())
    }

    /// Verify one erasure unit (a whole Erasure object or one Striped
    /// part) against registry reality, healing chunks missing on live
    /// containers in place. Returns `true` when the unit is lost
    /// (fewer than k recoverable chunks).
    #[allow(clippy::too_many_arguments)]
    fn verify_erasure_unit(
        &self,
        sha3: &[u8; 32],
        size: u64,
        n: usize,
        k: usize,
        chunks: &[(u8, u32)],
        report: &mut RecoveryVerifyReport,
        needs_repair: &mut bool,
    ) -> Result<bool> {
        report.chunks_expected += chunks.len();
        // Partition the committed slots: present, missing on a live
        // registered container (rewrite in place), missing because the
        // container is gone (repair). The per-chunk existence probes
        // fan out over the io_pool — a remote probe is an HTTP round
        // trip, and paying n of them serially per object would make
        // durable startup O(objects × n) round trips.
        type Probe = (u8, u32, Option<Arc<dyn ContainerChannel>>, String);
        let probes: Arc<Vec<Probe>> = Arc::new(
            chunks
                .iter()
                .map(|&(idx, cid)| {
                    let ch = self.registry.get(cid).ok().filter(|c| c.is_alive());
                    (idx, cid, ch, chunk_key(sha3, size, idx))
                })
                .collect(),
        );
        let lookup = Arc::clone(&probes);
        let found = self.io_pool.scatter_gather(probes.len(), move |i| {
            let (_, _, ch, key) = &lookup[i];
            ch.as_ref().is_some_and(|c| c.exists(key).unwrap_or(false))
        })?;
        let mut present: Vec<(u8, u32)> = Vec::with_capacity(chunks.len());
        let mut rewrite: Vec<(u8, u32)> = Vec::new();
        for ((idx, cid, ch, _), here) in probes.iter().zip(&found) {
            match ch {
                Some(_) if *here => present.push((*idx, *cid)),
                Some(_) => rewrite.push((*idx, *cid)),
                None => {
                    report.chunks_missing += 1;
                    *needs_repair = true;
                }
            }
        }
        report.chunks_missing += rewrite.len();
        if present.len() < k {
            return Ok(true);
        }
        if rewrite.is_empty() {
            return Ok(false);
        }
        // Rebuild from any k surviving chunks and heal the absent ones
        // onto their committed containers.
        let codec = self.codec(ErasureConfig::new(n, k))?;
        let (collected, _) = self.collect_chunks(sha3, size, k, &present)?;
        if collected.len() < k {
            return Ok(true);
        }
        let data = codec.decode(&collected)?;
        let mut all_chunks = codec.encode(&data)?;
        let mut jobs = Vec::with_capacity(rewrite.len());
        for &(idx, cid) in &rewrite {
            if let Ok(channel) = self.registry.get(cid) {
                jobs.push(ChunkJob {
                    index: idx,
                    channel,
                    key: chunk_key(sha3, size, idx),
                    data: Some(std::mem::take(&mut all_chunks[idx as usize].packed)),
                });
            }
        }
        for xfer in self.dispatch_chunk_io(jobs)? {
            if xfer.res.is_ok() {
                report.chunks_rewritten += 1;
            } else {
                // Leave it: the slot stays committed and a later
                // repair/verify pass retries.
                *needs_repair = true;
            }
        }
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::deploy_containers;
    use crate::coordinator::{PullOpts, PushOpts};
    use crate::testkit::uniform_specs;
    use crate::util::Rng;

    fn deployment() -> (DynoStore, String) {
        let ds = DynoStore::builder().build();
        for c in deploy_containers(&uniform_specs("dc", 12, 64 << 20, 1 << 32), 12, 0)
            .containers
        {
            ds.add_container(c).unwrap();
        }
        let token = ds.register_user("UserA").unwrap();
        (ds, token)
    }

    #[test]
    fn verify_clean_deployment_finds_nothing() {
        let (ds, token) = deployment();
        let data = Rng::new(1).bytes(60_000);
        ds.push(&token, "/UserA", "obj", &data, PushOpts::default()).unwrap();
        let r = ds.verify_recovered_placements().unwrap();
        assert_eq!(r.objects, 1);
        assert_eq!(r.chunks_expected, 10);
        assert_eq!(r.chunks_missing, 0);
        assert_eq!(r.chunks_rewritten, 0);
        assert_eq!(r.objects_lost, 0);
        assert!(!r.repair_scheduled);
    }

    #[test]
    fn missing_chunk_on_live_container_is_rewritten_in_place() {
        let (ds, token) = deployment();
        let data = Rng::new(2).bytes(80_000);
        ds.push(&token, "/UserA", "obj", &data, PushOpts::default()).unwrap();
        let meta = ds.meta.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        let (idx, cid) = match &meta.placement {
            ObjectPlacement::Erasure { chunks, .. } => chunks[0],
            _ => unreachable!(),
        };
        // Simulate a chunk file lost across the crash: delete the bytes
        // but keep the metadata placement.
        ds.container_of(cid)
            .unwrap()
            .delete(&super::super::ops::chunk_key(&meta.sha3, meta.size, idx))
            .unwrap();
        let r = ds.verify_recovered_placements().unwrap();
        assert_eq!(r.chunks_missing, 1);
        assert_eq!(r.chunks_rewritten, 1);
        assert!(!r.repair_scheduled, "placement unchanged, no repair needed");
        // Placement untouched and the object reads clean (not degraded).
        let meta2 = ds.meta.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        assert_eq!(meta2.placement, meta.placement);
        let pull = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
        assert_eq!(pull.data, data);
        assert!(!pull.degraded);
    }

    #[test]
    fn unreachable_container_schedules_repair() {
        let (ds, token) = deployment();
        let data = Rng::new(3).bytes(70_000);
        ds.push(&token, "/UserA", "obj", &data, PushOpts::default()).unwrap();
        let meta = ds.meta.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        let cid = meta.placement.containers()[0];
        ds.container_of(cid).unwrap().set_alive(false);
        let r = ds.verify_recovered_placements().unwrap();
        assert_eq!(r.chunks_missing, 1);
        assert!(r.repair_scheduled);
        assert_eq!(r.repair.repaired, 1);
        let pull = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
        assert_eq!(pull.data, data);
    }

    #[test]
    fn object_below_k_is_reported_lost() {
        let (ds, token) = deployment();
        let data = Rng::new(4).bytes(50_000);
        ds.push(&token, "/UserA", "obj", &data, PushOpts::default()).unwrap();
        let meta = ds.meta.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        let chunks = match &meta.placement {
            ObjectPlacement::Erasure { chunks, .. } => chunks.clone(),
            _ => unreachable!(),
        };
        // Wipe 4 chunk files of a (10,7) object: 6 < k remain.
        for &(idx, cid) in chunks.iter().take(4) {
            ds.container_of(cid)
                .unwrap()
                .delete(&super::super::ops::chunk_key(&meta.sha3, meta.size, idx))
                .unwrap();
        }
        let r = ds.verify_recovered_placements().unwrap();
        assert_eq!(r.chunks_missing, 4);
        assert_eq!(r.objects_lost, 1);
        assert_eq!(r.chunks_rewritten, 0);
    }
}
