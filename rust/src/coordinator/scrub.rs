//! Background anti-entropy scrubbing (§III-B health service, extended).
//!
//! [`DynoStore::repair`] reacts to *dead containers*: it early-exits any
//! object whose holders are all live, so bytes silently rotting on a
//! healthy container — at-rest corruption, a chunk file lost by the
//! backend — stay invisible until a read trips over them. The scrubber
//! closes that gap: a paced sweep that **fetches and verifies every
//! placed chunk** (unpack with its sealed payload hash + header index
//! + object-hash binding — a single flipped payload byte fails), heals
//! damaged or vanished copies from parity, and re-places chunks whose
//! holders are unreachable — restoring full n-chunk redundancy without
//! operator intervention once a fault window closes.
//!
//! Pacing: each [`DynoStore::scrub_cycle`] verifies at most `sample`
//! objects, resuming from a persistent cursor (last verified UUID), so
//! a deployment with millions of objects amortizes the sweep instead of
//! stalling its data path. [`ScrubberHandle`] runs cycles on a
//! background thread at a fixed interval until stopped.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::erasure::{Chunk, ErasureConfig};
use crate::metadata::{ObjectMeta, ObjectPlacement, PartManifest};
use crate::paxos::{CommandOutcome, MetaCommand};
use crate::crypto::sha3_256;
use crate::Result;

use super::ops::{chunk_key, object_key, ChunkJob};
use super::DynoStore;

/// Objects verified per scrub cycle when the operator doesn't say.
pub const DEFAULT_SCRUB_SAMPLE: usize = 64;

/// How long the background scrubber sleeps between cycles by default.
pub const DEFAULT_SCRUB_INTERVAL: Duration = Duration::from_secs(30);

/// Outcome of one [`DynoStore::scrub_cycle`].
#[derive(Debug, Default, Clone)]
pub struct ScrubReport {
    /// Object versions examined this cycle.
    pub scanned: usize,
    /// Chunks (or single copies) fetched and verified intact.
    pub chunks_verified: usize,
    /// Placed copies found damaged or missing on a *live* holder —
    /// silent corruption the read path would only meet by accident.
    pub corrupt_found: usize,
    /// Placed copies whose holder was dead or unregistered; the slot
    /// needs re-placement to restore redundancy.
    pub unreachable: usize,
    /// Copies rewritten with correct bytes (healed in place on the
    /// committed holder, or re-placed onto a healthy container).
    pub chunks_healed: usize,
    /// Objects with fewer than k valid chunks reachable: unrecoverable
    /// until their containers return.
    pub lost: usize,
    /// The sweep reached the end of the keyspace and the cursor reset —
    /// every object has been visited since the last wrap.
    pub wrapped: bool,
}

impl DynoStore {
    /// One paced anti-entropy sweep: verify up to `sample` objects
    /// (0 = the whole keyspace), resuming after the cursor left by the
    /// previous cycle. See the module docs for what "verify" means.
    pub fn scrub_cycle(&self, sample: usize) -> Result<ScrubReport> {
        let mut report = ScrubReport::default();
        let objects = self.meta.all_objects()?;
        if objects.is_empty() {
            report.wrapped = true;
            self.metrics.scrub_cycles.fetch_add(1, Ordering::Relaxed);
            return Ok(report);
        }
        // all_objects() is UUID-sorted, so "after the cursor" is a
        // stable resume point even as pushes interleave with cycles.
        let cursor = self.scrub_cursor.lock().unwrap().clone();
        let start = match &cursor {
            Some(uuid) => objects.iter().position(|m| m.uuid > *uuid).unwrap_or(0),
            None => 0,
        };
        let budget = if sample == 0 { objects.len() } else { sample.min(objects.len()) };
        let picked: Vec<&ObjectMeta> =
            objects.iter().cycle().skip(start).take(budget).collect();
        report.wrapped = cursor.is_some() && start == 0 || start + budget >= objects.len();

        for meta in &picked {
            self.scrub_object(meta, &mut report)?;
        }

        *self.scrub_cursor.lock().unwrap() = if report.wrapped && budget == objects.len() {
            None
        } else {
            picked.last().map(|m| m.uuid.clone())
        };
        self.metrics.scrub_cycles.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .scrub_chunks_verified
            .fetch_add(report.chunks_verified as u64, Ordering::Relaxed);
        self.metrics
            .scrub_corrupt_found
            .fetch_add(report.corrupt_found as u64, Ordering::Relaxed);
        self.metrics
            .scrub_chunks_healed
            .fetch_add(report.chunks_healed as u64, Ordering::Relaxed);
        self.metrics.scrub_lost.fetch_add(report.lost as u64, Ordering::Relaxed);
        // A scrub cycle is a natural durability point for the D-Rex
        // scorecards fed during the sweep.
        if let Err(e) = self.tiering.scores.flush() {
            crate::log_warn!("scorecard flush after scrub failed: {e}");
        }
        Ok(report)
    }

    fn scrub_object(&self, meta: &ObjectMeta, report: &mut ScrubReport) -> Result<()> {
        report.scanned += 1;
        match &meta.placement {
            ObjectPlacement::Single { container } => {
                // One copy, no parity: verify when the holder is up;
                // a damaged single copy is unrecoverable.
                let Ok(channel) = self.registry.get(*container) else {
                    report.unreachable += 1;
                    return Ok(());
                };
                if !channel.is_alive() {
                    self.tiering.scores.observe_probe(*container, false);
                    report.unreachable += 1;
                    return Ok(());
                }
                self.tiering.scores.observe_probe(*container, true);
                let key = object_key(&meta.sha3, meta.size);
                match channel.get(&key) {
                    Ok(out) if sha3_256(&out.data.unwrap_or_default()) == meta.sha3 => {
                        self.tiering.scores.observe_scrub(*container, true);
                        report.chunks_verified += 1;
                    }
                    _ => {
                        self.tiering.scores.observe_scrub(*container, false);
                        report.corrupt_found += 1;
                        report.lost += 1;
                    }
                }
                Ok(())
            }
            ObjectPlacement::Erasure { n, k, chunks } => {
                match self.scrub_unit(&meta.sha3, meta.size, *n, *k, chunks, report)? {
                    ScrubUnit::Intact => {}
                    ScrubUnit::Lost => report.lost += 1,
                    ScrubUnit::Replaced { chunks: new_chunks, newly_placed } => {
                        // CAS against the placement this sweep read — a
                        // concurrent migration/repair commit wins and
                        // this object is re-verified on a later cycle
                        // (same protocol as repair).
                        let outcome = self.meta.submit(MetaCommand::UpdatePlacement {
                            uuid: meta.uuid.clone(),
                            placement: ObjectPlacement::Erasure {
                                n: *n,
                                k: *k,
                                chunks: new_chunks,
                            },
                            expect: Some(meta.placement.clone()),
                        })?;
                        if let CommandOutcome::Failed(_) = outcome {
                            let committed = self
                                .meta
                                .read(|s| s.get_by_uuid(&meta.uuid))
                                .map(|m| m.placement)
                                .ok();
                            for &(idx, cid) in &newly_placed {
                                let referenced = matches!(
                                    &committed,
                                    Some(ObjectPlacement::Erasure { chunks, .. })
                                        if chunks.contains(&(idx, cid))
                                );
                                if !referenced {
                                    if let Ok(c) = self.registry.get(cid) {
                                        let _ =
                                            c.delete(&chunk_key(&meta.sha3, meta.size, idx));
                                    }
                                }
                            }
                            report.chunks_healed -= newly_placed.len();
                        }
                    }
                }
                Ok(())
            }
            ObjectPlacement::Striped { parts } => {
                // Scrub each part as its own erasure unit; fold every
                // changed part into ONE placement CAS so readers never
                // see a half-updated manifest.
                let mut lost = false;
                let mut changed = false;
                let mut new_parts: Vec<PartManifest> = Vec::with_capacity(parts.len());
                let mut placed_by_part: Vec<(PartManifest, Vec<(u8, u32)>)> = Vec::new();
                for part in parts {
                    match self.scrub_unit(
                        &part.sha3,
                        part.size,
                        part.n,
                        part.k,
                        &part.chunks,
                        report,
                    )? {
                        ScrubUnit::Intact => new_parts.push(part.clone()),
                        ScrubUnit::Lost => {
                            lost = true;
                            new_parts.push(part.clone());
                        }
                        ScrubUnit::Replaced { chunks, newly_placed } => {
                            changed = true;
                            let mut updated = part.clone();
                            updated.chunks = chunks;
                            if !newly_placed.is_empty() {
                                placed_by_part.push((part.clone(), newly_placed));
                            }
                            new_parts.push(updated);
                        }
                    }
                }
                if lost {
                    report.lost += 1;
                }
                if !changed {
                    return Ok(());
                }
                let outcome = self.meta.submit(MetaCommand::UpdatePlacement {
                    uuid: meta.uuid.clone(),
                    placement: ObjectPlacement::Striped { parts: new_parts },
                    expect: Some(meta.placement.clone()),
                })?;
                if let CommandOutcome::Failed(_) = outcome {
                    let committed = self
                        .meta
                        .read(|s| s.get_by_uuid(&meta.uuid))
                        .map(|m| m.placement)
                        .ok();
                    for (part, newly_placed) in &placed_by_part {
                        for &(idx, cid) in newly_placed {
                            let referenced = matches!(
                                &committed,
                                Some(ObjectPlacement::Striped { parts })
                                    if parts.iter().any(|p| {
                                        p.sha3 == part.sha3
                                            && p.size == part.size
                                            && p.chunks.contains(&(idx, cid))
                                    })
                            );
                            if !referenced {
                                if let Ok(c) = self.registry.get(cid) {
                                    let _ =
                                        c.delete(&chunk_key(&part.sha3, part.size, idx));
                                }
                            }
                            report.chunks_healed -= 1;
                        }
                    }
                }
                Ok(())
            }
        }
    }

    /// Verify-and-heal one erasure unit (a whole Erasure object or one
    /// Striped part; `sha3`/`size` are the unit's own, which its chunk
    /// keys and headers bind to). Heals damaged copies in place and
    /// writes replacements for unreachable slots, but leaves the
    /// metadata commit to the caller — a Striped object commits all of
    /// its parts in a single CAS.
    fn scrub_unit(
        &self,
        sha3: &[u8; 32],
        size: u64,
        n: usize,
        k: usize,
        chunks: &[(u8, u32)],
        report: &mut ScrubReport,
    ) -> Result<ScrubUnit> {
        // Fetch every placed chunk from every live holder concurrently.
        // Skips (dead/unregistered holders) need re-placement, exactly
        // like repair treats them.
        let mut jobs = Vec::with_capacity(chunks.len());
        let mut unreachable: Vec<(u8, u32)> = Vec::new();
        for &(idx, cid) in chunks {
            match self.registry.get(cid) {
                Ok(channel) if channel.is_alive() => {
                    self.tiering.scores.observe_probe(cid, true);
                    jobs.push(ChunkJob {
                        index: idx,
                        channel,
                        key: chunk_key(sha3, size, idx),
                        data: None,
                    });
                }
                Ok(_) => {
                    self.tiering.scores.observe_probe(cid, false);
                    unreachable.push((idx, cid));
                }
                Err(_) => unreachable.push((idx, cid)),
            }
        }
        let mut valid: Vec<(u8, u32)> = Vec::new();
        let mut collected: Vec<Chunk> = Vec::new();
        let mut damaged: Vec<(u8, u32)> = Vec::new();
        for xfer in self.dispatch_chunk_io(jobs)? {
            let good = match &xfer.res {
                Ok((Some(bytes), _)) => match Chunk::unpack(bytes) {
                    Ok(chunk)
                        if chunk.header.index == xfer.index
                            && chunk.header.object_hash == *sha3 =>
                    {
                        collected.push(chunk);
                        true
                    }
                    _ => false,
                },
                _ => false,
            };
            self.tiering.scores.observe_scrub(xfer.cid, good);
            if good {
                valid.push((xfer.index, xfer.cid));
            } else {
                damaged.push((xfer.index, xfer.cid));
            }
        }
        report.chunks_verified += valid.len();
        report.corrupt_found += damaged.len();
        report.unreachable += unreachable.len();

        let placed_idx: HashSet<u8> = valid.iter().map(|&(i, _)| i).collect();
        if damaged.is_empty() && unreachable.is_empty() && placed_idx.len() == n {
            return Ok(ScrubUnit::Intact); // fully redundant and intact
        }
        if collected.len() < k {
            return Ok(ScrubUnit::Lost);
        }

        // Rebuild the unit once; heal every gap from the same encode.
        let codec = self.codec(ErasureConfig::new(n, k))?;
        collected.truncate(k);
        let data = codec.decode(&collected)?;
        let mut all_chunks = codec.encode(&data)?;
        let mut new_placement = valid.clone();

        // Heal damaged copies in place on their committed (live) holder.
        let mut heal_jobs = Vec::with_capacity(damaged.len());
        for &(idx, cid) in &damaged {
            if let Ok(channel) = self.registry.get(cid) {
                heal_jobs.push(ChunkJob {
                    index: idx,
                    channel,
                    key: chunk_key(sha3, size, idx),
                    data: Some(std::mem::take(&mut all_chunks[idx as usize].packed)),
                });
            }
        }
        for xfer in self.dispatch_chunk_io(heal_jobs)? {
            if xfer.res.is_ok() {
                new_placement.push((xfer.index, xfer.cid));
                report.chunks_healed += 1;
            }
            // A failed rewrite drops the slot: it re-places below.
        }

        // Re-place slots with no live valid copy (unreachable holders,
        // failed in-place heals, slots absent from the placement).
        let have: HashSet<u8> = new_placement.iter().map(|&(i, _)| i).collect();
        let missing: Vec<u8> = (0..n as u8).filter(|i| !have.contains(i)).collect();
        let mut newly_placed: Vec<(u8, u32)> = Vec::new();
        if !missing.is_empty() {
            let holders: HashSet<u32> = new_placement.iter().map(|&(_, c)| c).collect();
            let infos: Vec<_> = self
                .registry
                .placement_infos()
                .into_iter()
                .filter(|i| i.alive && !holders.contains(&i.id))
                .collect();
            let chunk_size = codec.chunk_len(data.len()) as u64;
            if let Ok(targets) = self.placer.select(&infos, chunk_size, missing.len()) {
                let mut jobs = Vec::with_capacity(missing.len());
                for (idx, target) in missing.iter().zip(&targets) {
                    let channel = self.registry.get(target.id)?;
                    // A damaged slot's bytes may already be consumed by
                    // the in-place heal attempt; re-encode cheaply from
                    // the still-held chunk if so.
                    let packed = std::mem::take(&mut all_chunks[*idx as usize].packed);
                    let packed = if packed.is_empty() {
                        codec.encode(&data)?[*idx as usize].packed.clone()
                    } else {
                        packed
                    };
                    jobs.push(ChunkJob {
                        index: *idx,
                        channel,
                        key: chunk_key(sha3, size, *idx),
                        data: Some(packed),
                    });
                }
                for xfer in self.dispatch_chunk_io(jobs)? {
                    if xfer.res.is_ok() {
                        new_placement.push((xfer.index, xfer.cid));
                        newly_placed.push((xfer.index, xfer.cid));
                        report.chunks_healed += 1;
                    }
                }
            }
            // No capacity for replacements: commit what was healed in
            // place anyway — partial redundancy beats none.
        }

        new_placement.sort_by_key(|&(idx, _)| idx);
        let old_sorted = {
            let mut c = chunks.to_vec();
            c.sort_by_key(|&(idx, _)| idx);
            c
        };
        if new_placement == old_sorted {
            return Ok(ScrubUnit::Intact); // healed entirely in place; placement stands
        }
        Ok(ScrubUnit::Replaced { chunks: new_placement, newly_placed })
    }
}

/// What [`DynoStore::scrub_unit`] found for one erasure unit. The
/// metadata commit stays with the caller, so a Striped object can fold
/// every part's outcome into a single placement CAS.
enum ScrubUnit {
    /// Fully redundant and intact, or healed entirely in place — the
    /// committed placement still stands.
    Intact,
    /// Fewer than k valid chunks reachable; unrecoverable for now.
    Lost,
    /// Redundancy restored onto new containers: `chunks` is the slot
    /// list to commit, `newly_placed` the rollback set if the CAS loses.
    Replaced { chunks: Vec<(u8, u32)>, newly_placed: Vec<(u8, u32)> },
}

/// A background scrubber: runs [`DynoStore::scrub_cycle`] every
/// `interval` until stopped (or dropped). The thread holds an `Arc` to
/// the deployment, so the handle can outlive the scope that started it.
pub struct ScrubberHandle {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ScrubberHandle {
    pub fn start(ds: Arc<DynoStore>, interval: Duration, sample: usize) -> ScrubberHandle {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let thread = std::thread::Builder::new()
            .name("dyno-scrubber".into())
            .spawn(move || {
                while !flag.load(Ordering::Relaxed) {
                    // Scrub errors are transient (metadata contention,
                    // transports down); the next cycle retries.
                    let _ = ds.scrub_cycle(sample);
                    // Piggyback a tiering pass on the anti-entropy
                    // cadence when any container declares a cache tier.
                    if ds.tiering.has_tiers() {
                        let _ = ds.tier_cycle(crate::tiering::TierCycleOpts::default());
                    }
                    // Sleep in short slices so stop() returns promptly.
                    let mut slept = Duration::ZERO;
                    while slept < interval && !flag.load(Ordering::Relaxed) {
                        let step = Duration::from_millis(25).min(interval - slept);
                        std::thread::sleep(step);
                        slept += step;
                    }
                }
            })
            .expect("spawn scrubber thread");
        ScrubberHandle { stop, thread: Some(thread) }
    }

    /// Signal the thread and wait for the in-flight cycle to finish.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ScrubberHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{PullOpts, PushOpts};
    use super::*;
    use crate::container::{deploy_containers, AgentSpec};
    use crate::sim::{DeviceKind, Site};

    fn deployment(n_containers: usize) -> (Arc<DynoStore>, String) {
        let ds = DynoStore::builder().build();
        let sites = [Site::ChameleonTacc, Site::ChameleonUc, Site::AwsVirginia];
        let specs: Vec<AgentSpec> = (0..n_containers)
            .map(|i| {
                AgentSpec::new(
                    format!("dc{i}"),
                    sites[i % sites.len()],
                    DeviceKind::ChameleonLocal,
                )
                .mem(64 << 20)
                .fs(1 << 32)
            })
            .collect();
        for c in deploy_containers(&specs, n_containers, 0).containers {
            ds.add_container(c).unwrap();
        }
        let token = ds.register_user("UserA").unwrap();
        (Arc::new(ds), token)
    }

    fn data(len: usize, seed: u64) -> Vec<u8> {
        crate::util::Rng::new(seed).bytes(len)
    }

    fn chunk_locations(ds: &DynoStore, name: &str) -> (ObjectMeta, Vec<(u8, u32)>) {
        let meta = ds.meta.read(|s| s.get_latest("UserA", "/UserA", name)).unwrap();
        let chunks = match &meta.placement {
            ObjectPlacement::Erasure { chunks, .. } => chunks.clone(),
            _ => unreachable!(),
        };
        (meta, chunks)
    }

    #[test]
    fn clean_deployment_scrubs_clean() {
        let (ds, token) = deployment(12);
        ds.push(&token, "/UserA", "a", &data(50_000, 1), PushOpts::default()).unwrap();
        ds.push(&token, "/UserA", "b", &data(50_000, 2), PushOpts::default()).unwrap();
        let report = ds.scrub_cycle(0).unwrap();
        assert_eq!(report.scanned, 2);
        assert_eq!(report.chunks_verified, 20);
        assert_eq!(report.corrupt_found, 0);
        assert_eq!(report.chunks_healed, 0);
        assert_eq!(report.lost, 0);
        assert!(report.wrapped);
        assert_eq!(ds.metrics.snapshot()["scrub_cycles"], 1);
        assert_eq!(ds.metrics.snapshot()["scrub_chunks_verified"], 20);
    }

    #[test]
    fn scrub_heals_silent_at_rest_corruption_repair_misses() {
        let (ds, token) = deployment(12);
        let object = data(80_000, 3);
        ds.push(&token, "/UserA", "obj", &object, PushOpts::default()).unwrap();
        let (meta, chunks) = chunk_locations(&ds, "obj");
        // Rot two chunks in place. Every holder stays alive, so a
        // repair pass early-exits without noticing.
        for &(idx, cid) in chunks.iter().take(2) {
            ds.container_of(cid)
                .unwrap()
                .put(&chunk_key(&meta.sha3, meta.size, idx), b"bitrot")
                .unwrap();
        }
        let repair = ds.repair().unwrap();
        assert_eq!(repair.repaired, 0, "repair is blind to at-rest rot on live holders");

        let report = ds.scrub_cycle(0).unwrap();
        assert_eq!(report.corrupt_found, 2);
        assert_eq!(report.chunks_healed, 2);
        assert_eq!(report.lost, 0);

        // Healed in place: same placement, clean un-degraded read.
        let (meta2, chunks2) = chunk_locations(&ds, "obj");
        assert_eq!(meta2.placement, meta.placement);
        let mut sorted = chunks2;
        sorted.sort_by_key(|&(i, _)| i);
        assert_eq!(sorted.len(), 10);
        let pull = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
        assert_eq!(pull.data, object);
        assert!(!pull.degraded);

        // And the next sweep finds nothing to do.
        let again = ds.scrub_cycle(0).unwrap();
        assert_eq!(again.corrupt_found, 0);
        assert_eq!(again.chunks_healed, 0);
    }

    #[test]
    fn scrub_replaces_chunks_on_dead_holders() {
        let (ds, token) = deployment(13);
        let object = data(60_000, 4);
        ds.push(&token, "/UserA", "obj", &object, PushOpts::default()).unwrap();
        let (_, chunks) = chunk_locations(&ds, "obj");
        // Kill two holders; their chunks must move to fresh containers.
        let dead: Vec<u32> = chunks.iter().take(2).map(|&(_, c)| c).collect();
        for &cid in &dead {
            ds.container_of(cid).unwrap().set_alive(false);
        }
        let report = ds.scrub_cycle(0).unwrap();
        assert_eq!(report.unreachable, 2);
        assert_eq!(report.chunks_healed, 2);
        let (_, after) = chunk_locations(&ds, "obj");
        assert_eq!(after.len(), 10, "full redundancy restored");
        assert!(after.iter().all(|&(_, c)| !dead.contains(&c)));
        let pull = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
        assert_eq!(pull.data, object);
    }

    #[test]
    fn scrub_reports_unrecoverable_objects_lost() {
        let (ds, token) = deployment(12);
        ds.push(&token, "/UserA", "obj", &data(30_000, 5), PushOpts::default()).unwrap();
        let (meta, chunks) = chunk_locations(&ds, "obj");
        // Corrupt 4 of 10 chunks: 6 < k=7 valid remain.
        for &(idx, cid) in chunks.iter().take(4) {
            ds.container_of(cid)
                .unwrap()
                .put(&chunk_key(&meta.sha3, meta.size, idx), b"gone")
                .unwrap();
        }
        let report = ds.scrub_cycle(0).unwrap();
        assert_eq!(report.lost, 1);
        assert_eq!(report.chunks_healed, 0);
        assert_eq!(ds.metrics.snapshot()["scrub_lost"], 1);
    }

    #[test]
    fn paced_cycles_cover_the_keyspace_and_wrap() {
        let (ds, token) = deployment(12);
        for i in 0..5 {
            ds.push(&token, "/UserA", &format!("o{i}"), &data(9_000, i), PushOpts::default())
                .unwrap();
        }
        let mut scanned = 0;
        let mut wrapped = false;
        for _ in 0..3 {
            let r = ds.scrub_cycle(2).unwrap();
            scanned += r.scanned;
            wrapped |= r.wrapped;
        }
        assert_eq!(scanned, 6, "three cycles of two objects each");
        assert!(wrapped, "five objects in cycles of two wraps within three cycles");
        assert_eq!(ds.metrics.snapshot()["scrub_cycles"], 3);
    }

    #[test]
    fn background_scrubber_heals_without_intervention() {
        let (ds, token) = deployment(12);
        let object = data(40_000, 6);
        ds.push(&token, "/UserA", "obj", &object, PushOpts::default()).unwrap();
        let (meta, chunks) = chunk_locations(&ds, "obj");
        let (idx, cid) = chunks[0];
        ds.container_of(cid)
            .unwrap()
            .put(&chunk_key(&meta.sha3, meta.size, idx), b"rot")
            .unwrap();

        let handle =
            ScrubberHandle::start(ds.clone(), Duration::from_millis(5), 0);
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while ds.metrics.snapshot()["scrub_chunks_healed"] == 0
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(10));
        }
        handle.stop();
        assert!(ds.metrics.snapshot()["scrub_chunks_healed"] >= 1);
        let pull = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
        assert_eq!(pull.data, object);
        assert!(!pull.degraded);
    }

    #[test]
    fn single_placement_corruption_is_detected() {
        let (ds, token) = deployment(3);
        let object = data(10_000, 7);
        ds.push(
            &token,
            "/UserA",
            "single",
            &object,
            PushOpts { policy: Some(crate::policy::ResiliencePolicy::Regular), ..Default::default() },
        )
        .unwrap();
        let meta = ds.meta.read(|s| s.get_latest("UserA", "/UserA", "single")).unwrap();
        let cid = match &meta.placement {
            ObjectPlacement::Single { container } => *container,
            _ => unreachable!(),
        };
        ds.container_of(cid)
            .unwrap()
            .put(&object_key(&meta.sha3, meta.size), b"smashed")
            .unwrap();
        let report = ds.scrub_cycle(0).unwrap();
        assert_eq!(report.corrupt_found, 1);
        assert_eq!(report.lost, 1, "a single copy has no parity to heal from");
    }
}
