//! The DynoStore coordinator: wires gateway-side services (auth,
//! metadata/Paxos, registry, health, placement, policies) over the data
//! containers into the system of paper Fig. 1.
//!
//! Operations return *reports* carrying both the result and the
//! simulated wide-area time of the operation (see `crate::sim` on why
//! time is simulated while the data plane is real).

pub(crate) mod lifecycle;
mod ops;
mod recovery;
mod reports;
mod scrub;

pub use lifecycle::RebalanceOpts;
pub use ops::{ObjectByteStream, OpContext, PullOpts, PushOpts};
pub use recovery::RecoveryVerifyReport;
pub use reports::{
    ChunkIoReport, DecommissionReport, PullReport, PushReport, RangeReport, RebalanceReport,
    RepairReport,
};
pub use scrub::{ScrubReport, ScrubberHandle, DEFAULT_SCRUB_INTERVAL, DEFAULT_SCRUB_SAMPLE};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::container::{ContainerChannel, DataContainer};
use crate::crypto::TokenService;
use crate::durability::{
    self, DurabilityOpts, RecoveryReport, DEFAULT_SNAPSHOT_EVERY, SNAPSHOT_FILE, WAL_FILE,
};
use crate::net::ThreadPool;
use crate::erasure::{
    Codec, ErasureConfig, GfBackend, ParallelBackend, PureRustBackend, SwarBackend,
};
use crate::json::Value;
use crate::metadata::{namespace_owner, Ring};
use crate::paxos::{shard_seed, MetaCommand, ReplicatedMeta, ShardedMeta};
use crate::placement::{Placer, Weights};
use crate::policy::ResiliencePolicy;
use crate::registry::Registry;
use crate::runtime::PjrtGfBackend;
use crate::sim::{Site, Wan};
use crate::tiering::{ScorePenalty, TieringPlane};
use crate::{Error, Result};

/// Which GF(2^8) engine drives the erasure hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GfEngine {
    /// Table-driven pure rust (always available; the oracle baseline).
    PureRust,
    /// Fused split-nibble SWAR kernel, single-threaded.
    Swar,
    /// SWAR kernel column-sharded across a worker pool sized to the
    /// host's cores (small objects stay single-threaded).
    SwarParallel,
    /// The AOT-compiled Pallas kernel via PJRT (requires `make artifacts`).
    Pjrt,
}

impl GfEngine {
    /// Parse the config/CLI spelling of an engine.
    pub fn parse(s: &str) -> Option<GfEngine> {
        match s {
            "pure" | "pure-rust" => Some(GfEngine::PureRust),
            "swar" => Some(GfEngine::Swar),
            "swar-parallel" => Some(GfEngine::SwarParallel),
            "pjrt" => Some(GfEngine::Pjrt),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            GfEngine::PureRust => "pure-rust",
            GfEngine::Swar => "swar",
            GfEngine::SwarParallel => "swar-parallel",
            GfEngine::Pjrt => "pjrt",
        }
    }
}

impl std::fmt::Display for GfEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Runtime counters (the §III-B "metrics" the gateway exposes).
#[derive(Debug, Default)]
pub struct Metrics {
    pub pushes: AtomicU64,
    pub pulls: AtomicU64,
    /// Range reads served (fast-path and fallback both count).
    pub range_pulls: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    pub repairs: AtomicU64,
    pub auth_failures: AtomicU64,
    pub gc_collected: AtomicU64,
    /// Chunk (or whole-object) migrations committed by the lifecycle
    /// plane (decommission drains + rebalance moves).
    pub chunks_migrated: AtomicU64,
    /// Containers drained and removed via `decommission`.
    pub decommissions: AtomicU64,
    /// Rebalance runs completed.
    pub rebalances: AtomicU64,
    /// Internal hedge/retry waves beyond the first attempt (erasure
    /// pulls falling back to parity count one per extra wave).
    pub retries: AtomicU64,
    /// Requests load-shed with 503 (circuit breaker open / no capacity).
    pub sheds: AtomicU64,
    /// Requests that ran out of deadline budget (504).
    pub deadline_timeouts: AtomicU64,
    /// Anti-entropy scrub cycles completed.
    pub scrub_cycles: AtomicU64,
    /// Chunks fetched and verified by the scrubber.
    pub scrub_chunks_verified: AtomicU64,
    /// Chunks the scrubber found damaged/missing and rewrote.
    pub scrub_chunks_healed: AtomicU64,
    /// Damaged/missing chunks the scrubber detected (healed or not).
    pub scrub_corrupt_found: AtomicU64,
    /// Objects the scrubber could not reconstruct (fewer than k valid
    /// chunks reachable — data loss until containers return).
    pub scrub_lost: AtomicU64,
    /// Streamed transfers (push or pull) currently in flight — the
    /// gauge that makes streaming memory-boundedness observable: peak
    /// gateway memory ≈ streams_active × stripe × pipeline depth.
    pub streams_active: AtomicU64,
    /// Multipart uploads opened / completed / aborted (counters; the
    /// `multipart_open` gauge in `/metrics` is read live from the
    /// metadata plane so it survives restarts).
    pub multipart_inits: AtomicU64,
    pub multipart_completes: AtomicU64,
    pub multipart_aborts: AtomicU64,
    /// Adaptive (k, n) selections performed (`policy: "adaptive"`).
    pub adaptive_selections: AtomicU64,
    /// Objects that had chunks promoted into / demoted out of a cache
    /// tier by `tier_cycle`.
    pub tier_promotions: AtomicU64,
    pub tier_demotions: AtomicU64,
}

impl Metrics {
    pub fn snapshot(&self) -> HashMap<&'static str, u64> {
        let mut m = HashMap::new();
        m.insert("pushes", self.pushes.load(Ordering::Relaxed));
        m.insert("pulls", self.pulls.load(Ordering::Relaxed));
        m.insert("range_pulls", self.range_pulls.load(Ordering::Relaxed));
        m.insert("bytes_in", self.bytes_in.load(Ordering::Relaxed));
        m.insert("bytes_out", self.bytes_out.load(Ordering::Relaxed));
        m.insert("repairs", self.repairs.load(Ordering::Relaxed));
        m.insert("auth_failures", self.auth_failures.load(Ordering::Relaxed));
        m.insert("gc_collected", self.gc_collected.load(Ordering::Relaxed));
        m.insert("chunks_migrated", self.chunks_migrated.load(Ordering::Relaxed));
        m.insert("decommissions", self.decommissions.load(Ordering::Relaxed));
        m.insert("rebalances", self.rebalances.load(Ordering::Relaxed));
        m.insert("retries", self.retries.load(Ordering::Relaxed));
        m.insert("sheds", self.sheds.load(Ordering::Relaxed));
        m.insert("deadline_timeouts", self.deadline_timeouts.load(Ordering::Relaxed));
        m.insert("scrub_cycles", self.scrub_cycles.load(Ordering::Relaxed));
        m.insert(
            "scrub_chunks_verified",
            self.scrub_chunks_verified.load(Ordering::Relaxed),
        );
        m.insert("scrub_chunks_healed", self.scrub_chunks_healed.load(Ordering::Relaxed));
        m.insert("scrub_corrupt_found", self.scrub_corrupt_found.load(Ordering::Relaxed));
        m.insert("scrub_lost", self.scrub_lost.load(Ordering::Relaxed));
        m.insert("streams_active", self.streams_active.load(Ordering::Relaxed));
        m.insert("multipart_inits", self.multipart_inits.load(Ordering::Relaxed));
        m.insert("multipart_completes", self.multipart_completes.load(Ordering::Relaxed));
        m.insert("multipart_aborts", self.multipart_aborts.load(Ordering::Relaxed));
        m.insert("adaptive_selections", self.adaptive_selections.load(Ordering::Relaxed));
        m.insert("tier_promotions", self.tier_promotions.load(Ordering::Relaxed));
        m.insert("tier_demotions", self.tier_demotions.load(Ordering::Relaxed));
        m
    }

    /// RAII handle for the `streams_active` gauge: created at stream
    /// start, released on drop — success, error, and abandoned-stream
    /// paths all decrement exactly once.
    pub fn begin_stream(&self) -> StreamGuard<'_> {
        self.streams_active.fetch_add(1, Ordering::Relaxed);
        StreamGuard { metrics: self }
    }
}

/// See [`Metrics::begin_stream`].
pub struct StreamGuard<'a> {
    metrics: &'a Metrics,
}

impl Drop for StreamGuard<'_> {
    fn drop(&mut self) {
        self.metrics.streams_active.fetch_sub(1, Ordering::Relaxed);
    }
}

/// The assembled DynoStore deployment.
pub struct DynoStore {
    pub registry: Registry,
    pub meta: Arc<ShardedMeta>,
    pub tokens: TokenService,
    pub placer: Placer,
    pub wan: Wan,
    /// Where the management services run (Table I "Metadata" node).
    pub gateway_site: Site,
    pub default_policy: ResiliencePolicy,
    pub metrics: Metrics,
    /// The D-Rex plane: container scorecards, tier declarations, and
    /// per-object access heat (shared with the scrubber and gateway).
    pub tiering: Arc<TieringPlane>,
    engine: GfEngine,
    codecs: Mutex<HashMap<ErasureConfig, Arc<Codec<Arc<dyn GfBackend>>>>>,
    backend: Arc<dyn GfBackend>,
    /// Worker pool dispatching per-chunk container I/O concurrently
    /// (disperse / erasure pull / repair fan out over the channels).
    pub(crate) io_pool: ThreadPool,
    /// What recovery found at build time (None = in-memory deployment).
    /// The aggregate over all metadata shards; per-shard reports are in
    /// `recovery_shards`.
    recovery: Option<RecoveryReport>,
    /// Per-shard recovery reports, index == shard id (None = in-memory).
    recovery_shards: Option<Vec<RecoveryReport>>,
    /// Where the anti-entropy scrubber's paced sweep resumes: the UUID
    /// of the last object verified (None = start of the keyspace).
    pub(crate) scrub_cursor: Mutex<Option<String>>,
}

/// Builder for a DynoStore deployment.
pub struct Builder {
    replicas: usize,
    seed: u64,
    gateway_site: Site,
    weights: Weights,
    policy: ResiliencePolicy,
    engine: GfEngine,
    wan: Wan,
    secret: Vec<u8>,
    io_workers: usize,
    data_dir: Option<std::path::PathBuf>,
    snapshot_every: u64,
    meta_shards: usize,
    score_placement: Option<bool>,
}

impl Default for Builder {
    fn default() -> Self {
        Builder {
            replicas: 3,
            seed: 0xD1_5705,
            gateway_site: Site::ChameleonUc,
            weights: Weights::default(),
            policy: ResiliencePolicy::Fixed(ErasureConfig::new(10, 7)),
            engine: GfEngine::PureRust,
            wan: Wan::paper_testbed(),
            secret: b"dynostore-dev-secret".to_vec(),
            io_workers: 0, // auto-size to the host
            data_dir: None,
            snapshot_every: DEFAULT_SNAPSHOT_EVERY,
            meta_shards: 1,
            score_placement: None,
        }
    }
}

impl Builder {
    pub fn replicas(mut self, n: usize) -> Self {
        self.replicas = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn gateway_site(mut self, site: Site) -> Self {
        self.gateway_site = site;
        self
    }

    pub fn weights(mut self, w: Weights) -> Self {
        self.weights = w;
        self
    }

    pub fn policy(mut self, p: ResiliencePolicy) -> Self {
        self.policy = p;
        self
    }

    pub fn engine(mut self, e: GfEngine) -> Self {
        self.engine = e;
        self
    }

    pub fn wan(mut self, wan: Wan) -> Self {
        self.wan = wan;
        self
    }

    pub fn secret(mut self, s: &[u8]) -> Self {
        self.secret = s.to_vec();
        self
    }

    /// Size of the chunk-I/O dispatch pool (0 = auto: host parallelism
    /// clamped to [2, 16]).
    pub fn io_workers(mut self, n: usize) -> Self {
        self.io_workers = n;
        self
    }

    /// Persist the metadata plane (WAL + snapshots) under `dir` and
    /// recover from it at build time. Deployments built without a data
    /// dir are in-memory (the default — tests and simulators).
    pub fn data_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.data_dir = Some(dir.into());
        self
    }

    /// Compact the WAL into a snapshot every `n` commits (durable
    /// deployments only; default [`DEFAULT_SNAPSHOT_EVERY`]).
    pub fn snapshot_every(mut self, n: u64) -> Self {
        self.snapshot_every = n.max(1);
        self
    }

    /// Number of independent metadata Paxos shards. The default (1)
    /// keeps the legacy single-group plane and the legacy on-disk
    /// layout byte-identical. With `n > 1` the namespace keyspace is
    /// consistent-hash partitioned over `n` groups, each with its own
    /// WAL + keyed snapshot lineage under `data_dir/shard-<i>/`; a
    /// legacy single-shard data dir migrates forward automatically on
    /// first sharded boot.
    pub fn meta_shards(mut self, n: usize) -> Self {
        self.meta_shards = n.max(1);
        self
    }

    /// Force the scorecard placement penalty on or off. By default it
    /// follows the policy: installed for `policy: "adaptive"`, absent
    /// otherwise — so static deployments keep the PR 9 placer
    /// byte-identical.
    pub fn score_placement(mut self, on: bool) -> Self {
        self.score_placement = Some(on);
        self
    }

    /// Build an in-memory deployment. Panics if [`Builder::data_dir`]
    /// was set — durable builds can fail on I/O and must go through
    /// [`Builder::build_durable`].
    pub fn build(self) -> DynoStore {
        assert!(
            self.data_dir.is_none(),
            "data_dir configured: use Builder::build_durable()"
        );
        let (ds, _) = self.build_durable().expect("in-memory build cannot fail");
        ds
    }

    /// Build the deployment, recovering the metadata plane from
    /// `data_dir` when one is configured (snapshot load → WAL tail
    /// replay → torn-tail truncation). Without a data dir this is
    /// [`Builder::build`] plus an empty report.
    ///
    /// After registering the deployment's containers, callers should
    /// run [`DynoStore::verify_recovered_placements`] so recovered
    /// placements are checked against registry reality.
    pub fn build_durable(self) -> Result<(DynoStore, RecoveryReport)> {
        let backend: Arc<dyn GfBackend> = match self.engine {
            GfEngine::PureRust => Arc::new(PureRustBackend),
            GfEngine::Swar => Arc::new(SwarBackend::new()),
            GfEngine::SwarParallel => Arc::new(ParallelBackend::auto()),
            GfEngine::Pjrt => Arc::new(PjrtGfBackend::global()),
        };
        let io_workers = if self.io_workers > 0 {
            self.io_workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 16)
        };
        // The pool exists before the metadata plane so sharded recovery
        // can replay shard WALs on it in parallel.
        let io_pool = ThreadPool::new(io_workers);
        let (meta, recovery_shards) = match &self.data_dir {
            Some(dir) => {
                let (meta, reports) = open_durable_meta(
                    dir,
                    self.meta_shards,
                    self.replicas,
                    self.seed,
                    self.snapshot_every,
                    &io_pool,
                )?;
                (meta, Some(reports))
            }
            None => (ShardedMeta::memory(self.meta_shards, self.replicas, self.seed), None),
        };
        let recovery = recovery_shards.as_ref().map(|reports| {
            let mut agg = RecoveryReport::default();
            for r in reports {
                agg.absorb(r);
            }
            agg
        });
        let report = recovery.clone().unwrap_or_default();
        let tiering = Arc::new(match &self.data_dir {
            Some(dir) => TieringPlane::durable(dir.join("tiering"))?,
            None => TieringPlane::memory(),
        });
        let score_placement = self
            .score_placement
            .unwrap_or(matches!(self.policy, ResiliencePolicy::Adaptive { .. }));
        let mut placer = Placer::new(self.weights);
        if score_placement {
            placer = placer.with_metric(Box::new(ScorePenalty::new(tiering.clone())));
        }
        Ok((
            DynoStore {
                registry: Registry::new(),
                meta,
                tokens: TokenService::new(&self.secret),
                placer,
                wan: self.wan,
                gateway_site: self.gateway_site,
                default_policy: self.policy,
                metrics: Metrics::default(),
                tiering,
                engine: self.engine,
                codecs: Mutex::new(HashMap::new()),
                backend,
                io_pool,
                recovery,
                recovery_shards,
                scrub_cursor: Mutex::new(None),
            },
            report,
        ))
    }
}

/// Open the durable metadata plane under `dir`.
///
/// `meta_shards == 1` keeps the legacy layout (one WAL + full-JSON
/// snapshots at the dir root) byte-for-byte. With more shards, each
/// shard's keyed lineage lives under `shard-<i>/` and recovery replays
/// all shards in parallel on the I/O pool; a legacy layout migrates
/// forward first when present. The `meta.layout` marker pins the shard
/// count — reopening at any other count is refused (resharding in place
/// is not supported).
fn open_durable_meta(
    dir: &std::path::Path,
    meta_shards: usize,
    replicas: usize,
    seed: u64,
    snapshot_every: u64,
    io_pool: &ThreadPool,
) -> Result<(Arc<ShardedMeta>, Vec<RecoveryReport>)> {
    let layout = durability::read_layout(dir)?;
    if meta_shards <= 1 {
        if let Some(n) = layout {
            if n > 1 {
                return Err(Error::Config(format!(
                    "data dir '{}' holds {n} metadata shards; set meta_shards = {n} \
                     (resharding is not supported)",
                    dir.display()
                )));
            }
        }
        let opts = DurabilityOpts::new(dir.to_path_buf()).snapshot_every(snapshot_every);
        let (group, report) = ReplicatedMeta::durable(replicas, seed, opts)?;
        return Ok((ShardedMeta::single(group), vec![report]));
    }
    match layout {
        Some(n) if n != meta_shards => {
            return Err(Error::Config(format!(
                "data dir '{}' holds {n} metadata shards but meta_shards = {meta_shards} \
                 (resharding is not supported)",
                dir.display()
            )));
        }
        Some(_) => {}
        None => migrate_single_to_sharded(dir, meta_shards, seed, snapshot_every)?,
    }
    let results = {
        let dir = dir.to_path_buf();
        io_pool.scatter_gather(meta_shards, move |i| {
            let opts = DurabilityOpts::new(durability::shard_dir(&dir, i))
                .snapshot_every(snapshot_every);
            ReplicatedMeta::durable_keyed(replicas, shard_seed(seed, i), opts)
        })?
    };
    let mut groups = Vec::with_capacity(meta_shards);
    let mut reports = Vec::with_capacity(meta_shards);
    for res in results {
        let (group, report) = res?;
        groups.push(group);
        reports.push(report);
    }
    Ok((ShardedMeta::from_groups(groups), reports))
}

/// One-time forward migration of a legacy single-group layout into
/// `meta_shards` keyed per-shard stores: recover the legacy state
/// (snapshot + full WAL replay), partition its keyed dump over the
/// ring, and write one base file per shard. Ordering is crash-safe:
/// shard bases land first (each atomically), the layout marker commits
/// the migration, and only then are the legacy files archived as
/// `*.pre-shard` — a crash before the marker leaves the legacy layout
/// authoritative and the migration simply reruns.
fn migrate_single_to_sharded(
    dir: &std::path::Path,
    meta_shards: usize,
    seed: u64,
    snapshot_every: u64,
) -> Result<()> {
    let has_legacy = dir.join(WAL_FILE).exists() || dir.join(SNAPSHOT_FILE).exists();
    let ring = Ring::new(meta_shards);
    let mut per_shard: Vec<Vec<(String, Value)>> = vec![Vec::new(); meta_shards];
    if has_legacy {
        // One replica is enough: the durable state is the log, not the
        // in-memory copies.
        let opts = DurabilityOpts::new(dir.to_path_buf()).snapshot_every(snapshot_every);
        let (legacy, _report) = ReplicatedMeta::durable(1, seed, opts)?;
        let dump = legacy.replica_store(0).kv_dump();
        drop(legacy);
        for (key, value) in dump {
            let shard = shard_for_kv(&ring, &key, &value)?;
            per_shard[shard].push((key, value));
        }
    }
    let now = crate::util::unix_secs();
    for (i, mut entries) in per_shard.into_iter().enumerate() {
        // Shard 0 inherits the legacy RNG/counter so its UUID stream
        // continues; fresh shards seed their own disjoint streams (when
        // there is no legacy state, shard 0 seeds fresh too).
        if i > 0 || !has_legacy {
            let rng = crate::util::Rng::new(shard_seed(seed, i));
            entries.push((
                "sys:rng".to_string(),
                Value::Arr(rng.state().iter().map(|w| format!("{w:016x}").into()).collect()),
            ));
            entries.push(("sys:uuid_counter".to_string(), 0u64.into()));
        }
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        durability::kvstore::write_base(&durability::shard_dir(dir, i), 0, now, &entries)?;
    }
    durability::write_layout(dir, meta_shards)?;
    if has_legacy {
        for name in [WAL_FILE, SNAPSHOT_FILE] {
            let from = dir.join(name);
            if from.exists() {
                if let Err(e) = std::fs::rename(&from, dir.join(format!("{name}.pre-shard"))) {
                    crate::log_warn!(
                        "shard migration: could not archive legacy '{}': {e}",
                        from.display()
                    );
                }
            }
        }
    }
    Ok(())
}

/// Which shard a legacy keyed-dump entry belongs to — by the namespace
/// owner of the collection the key (or its value) references.
fn shard_for_kv(ring: &Ring, key: &str, value: &Value) -> Result<usize> {
    if let Some(path) = key.strip_prefix("col:") {
        Ok(ring.route(namespace_owner(path)))
    } else if key.starts_with("obj:") || key.starts_with("up:") {
        let col = value.get("collection").as_str().ok_or_else(|| {
            Error::Json(format!("kv entry '{key}' lacks a collection during shard migration"))
        })?;
        Ok(ring.route(namespace_owner(col)))
    } else if let Some(rest) =
        key.strip_prefix("chain:").or_else(|| key.strip_prefix("epoch:"))
    {
        let i = rest
            .rfind('/')
            .ok_or_else(|| Error::Json(format!("bad kv key '{key}' during shard migration")))?;
        Ok(ring.route(namespace_owner(&rest[..i])))
    } else if key.starts_with("sys:") {
        // The legacy RNG/counter stay with shard 0.
        Ok(0)
    } else {
        Err(Error::Json(format!("unknown kv key '{key}' during shard migration")))
    }
}

impl DynoStore {
    pub fn builder() -> Builder {
        Builder::default()
    }

    /// Engine selected at build time.
    pub fn engine(&self) -> GfEngine {
        self.engine
    }

    /// What recovery found at build time (None for in-memory
    /// deployments). `/health` surfaces this as the `recovered` flag.
    /// With a sharded metadata plane this is the aggregate over shards;
    /// see [`DynoStore::recovery_shard_reports`] for the breakdown.
    pub fn recovery_report(&self) -> Option<&RecoveryReport> {
        self.recovery.as_ref()
    }

    /// Per-shard recovery reports, index == shard id (None for
    /// in-memory deployments). `/health` surfaces these in the
    /// `durability.shards` array.
    pub fn recovery_shard_reports(&self) -> Option<&[RecoveryReport]> {
        self.recovery_shards.as_deref()
    }

    /// Name of the live GF(2^8) backend driving this deployment's
    /// erasure hot path (surfaced by the gateway's `/health` endpoint
    /// and the per-operation reports).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Register an in-process container (administrator add, §III-B).
    pub fn add_container(&self, c: Arc<DataContainer>) -> Result<()> {
        self.registry.add(c)
    }

    /// Register a container behind any transport (a remote agent's
    /// [`crate::container::RemoteChannel`], or anything else speaking
    /// [`ContainerChannel`]).
    pub fn add_channel(&self, ch: Arc<dyn ContainerChannel>) -> Result<()> {
        self.registry.add_channel(ch)
    }

    /// Deregister a container immediately. Chunks it holds are NOT
    /// migrated — committed placements keep referencing the departed id
    /// until repair re-disperses them. Prefer [`DynoStore::decommission`]
    /// for a graceful drain that moves every chunk first.
    pub fn remove_container(&self, id: u32) -> Result<Arc<dyn ContainerChannel>> {
        self.registry.remove(id)
    }

    /// Parallelism of the chunk-I/O dispatch pool.
    pub fn io_parallelism(&self) -> usize {
        self.io_pool.size()
    }

    /// Open (uncommitted) multipart uploads, read live from the
    /// metadata plane (summed across shards) — the `multipart_open`
    /// gauge.
    pub fn open_upload_count(&self) -> u64 {
        self.meta.open_upload_count() as u64
    }

    /// Create a user namespace and issue the user's OAuth-style token.
    /// Registering a name that already exists is an [`Error::Conflict`]
    /// (HTTP `409` at the gateway).
    pub fn register_user(&self, user: &str) -> Result<String> {
        match self.meta.submit(MetaCommand::CreateNamespace { user: user.into() })? {
            crate::paxos::CommandOutcome::Failed(e) => Err(Error::from_failed(e)),
            _ => Ok(self.tokens.issue(user, &["read", "write"], 24 * 3600)),
        }
    }

    /// Issue a fresh token for an existing user (login).
    pub fn login(&self, user: &str) -> String {
        self.tokens.issue(user, &["read", "write"], 24 * 3600)
    }

    /// Issue an operator token carrying the `admin` scope the gateway's
    /// `/admin/*` routes require. Only deployment-side code (whoever
    /// holds the deployment secret) can mint one — ordinary
    /// `register`/`login` tokens never carry it; `dynostore serve`
    /// prints one at startup for the operator.
    pub fn issue_admin_token(&self, ttl_secs: u64) -> String {
        self.tokens.issue("operator", &["read", "write", "admin"], ttl_secs)
    }

    /// Codec cache: one per (n, k), sharing the selected GF engine.
    pub(crate) fn codec(&self, cfg: ErasureConfig) -> Result<Arc<Codec<Arc<dyn GfBackend>>>> {
        let mut cache = self.codecs.lock().unwrap();
        if let Some(c) = cache.get(&cfg) {
            return Ok(c.clone());
        }
        let codec = Arc::new(Codec::with_backend(cfg, self.backend.clone())?);
        cache.insert(cfg, codec.clone());
        Ok(codec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{AgentSpec, deploy_containers};
    use crate::sim::DeviceKind;

    #[test]
    fn builder_defaults_match_paper_eval() {
        let ds = DynoStore::builder().build();
        assert_eq!(ds.meta.replica_count(), 3);
        assert_eq!(
            ds.default_policy,
            ResiliencePolicy::Fixed(ErasureConfig::new(10, 7))
        );
        assert_eq!(ds.engine(), GfEngine::PureRust);
    }

    #[test]
    fn register_user_issues_valid_token() {
        let ds = DynoStore::builder().build();
        let token = ds.register_user("UserA").unwrap();
        let claims = ds.tokens.validate(&token).unwrap();
        assert_eq!(claims.subject, "UserA");
        assert!(claims.has_scope("write"));
        // Duplicate registration fails.
        assert!(ds.register_user("UserA").is_err());
    }

    #[test]
    fn container_admin_lifecycle() {
        let ds = DynoStore::builder().build();
        let report = deploy_containers(
            &[AgentSpec::new("dc0", Site::ChameleonTacc, DeviceKind::ChameleonLocal)],
            1,
            0,
        );
        ds.add_container(report.containers[0].clone()).unwrap();
        assert_eq!(ds.registry.len(), 1);
        ds.remove_container(0).unwrap();
        assert!(ds.registry.is_empty());
    }

    #[test]
    fn engine_parse_roundtrip() {
        for e in [GfEngine::PureRust, GfEngine::Swar, GfEngine::SwarParallel, GfEngine::Pjrt] {
            assert_eq!(GfEngine::parse(e.as_str()), Some(e));
        }
        assert_eq!(GfEngine::parse("pure"), Some(GfEngine::PureRust));
        assert_eq!(GfEngine::parse("cuda"), None);
    }

    #[test]
    fn builder_wires_selected_backend() {
        for (engine, name) in [
            (GfEngine::PureRust, "pure-rust"),
            (GfEngine::Swar, "swar"),
            (GfEngine::SwarParallel, "swar-parallel"),
        ] {
            let ds = DynoStore::builder().engine(engine).build();
            assert_eq!(ds.engine(), engine);
            assert_eq!(ds.backend_name(), name);
        }
    }

    #[test]
    fn codec_cache_reuses_instances() {
        let ds = DynoStore::builder().build();
        let a = ds.codec(ErasureConfig::new(6, 3)).unwrap();
        let b = ds.codec(ErasureConfig::new(6, 3)).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let c = ds.codec(ErasureConfig::new(10, 7)).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
    }
}
