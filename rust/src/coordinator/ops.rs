//! Coordinator data operations: push / pull / exists / evict / gc /
//! repair — the request paths of paper Fig. 1, with Algorithm 1-2
//! erasure handling and §IV-C placement.
//!
//! Chunk I/O is transport-abstracted: every container is reached through
//! a [`ContainerChannel`] (in-process or remote HTTP agent) and the
//! erasure hot paths dispatch their per-chunk transfers **concurrently**
//! on the coordinator's I/O pool — disperse uploads all n chunks at
//! once, pull issues the k preferred (systematic) fetches and hedges to
//! parity in follow-up waves on failure or corruption, repair fans out
//! both its reconstruction reads and its re-placement writes.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::container::{ContainerChannel, DataContainer};
use crate::crypto::sha3_256;
use crate::erasure::{Chunk, ErasureConfig};
use crate::metadata::{
    composite_sha3, ObjectMeta, ObjectPage, ObjectPlacement, PartManifest, Permission,
    UploadState,
};
use crate::paxos::{CommandOutcome, MetaCommand};
use crate::policy::{select_dynamic, ResiliencePolicy};
use crate::resilience::Deadline;
use crate::sim::{cost, Site};
use crate::tiering::{nines_to_loss, select_adaptive};
use crate::util::{now_ns, to_hex, unix_secs};
use crate::{Error, Result};

use super::reports::{ChunkIoReport, PullReport, PushReport, RangeReport, RepairReport};
use super::DynoStore;

/// Simulated metadata-commit base cost: two LAN round trips among the
/// replica group at the gateway site (prepare + accept), plus the real
/// consensus wallclock measured around `submit`.
const META_COMMIT_BASE_S: f64 = 0.004;

/// Calibrated gateway coding bandwidth (bytes/s) for *simulated* encode
/// and decode costs. The paper's Chameleon gateway nodes (96 cores)
/// stream the GF(2^8) tables at memory-ish speed; 1.2 GB/s is the
/// single-stream figure our §Perf pass measures for the table codec on
/// a comparable core. Real wallclock on this host is reported
/// separately (encode_wall_s / decode_wall_s) and never mixed into
/// simulated time — simulation results must not depend on the machine
/// running them.
const GATEWAY_CODING_BW: f64 = 1.2e9;

/// Request context: where the client is and how many parallel channels
/// its transfer uses (Fig. 7's thread knob — channels share the client's
/// WAN link and are modeled by the flow-sharing term in `Wan`).
#[derive(Debug, Clone, Copy)]
pub struct OpContext {
    pub client_site: Site,
    pub flows: u32,
    /// Per-request time budget (`x-dyno-deadline-ms` at the gateway,
    /// `--deadline-ms` at the CLI). Checked before every expensive
    /// stage and clamped onto every transport wait; expired budgets
    /// short-circuit with [`Error::Timeout`] (HTTP 504).
    pub deadline: Deadline,
}

impl Default for OpContext {
    fn default() -> Self {
        OpContext { client_site: Site::Madrid, flows: 1, deadline: Deadline::none() }
    }
}

impl OpContext {
    pub fn at(site: Site) -> Self {
        OpContext { client_site: site, ..Default::default() }
    }

    pub fn with_flows(mut self, flows: u32) -> Self {
        self.flows = flows.max(1);
        self
    }

    pub fn with_deadline(mut self, deadline: Deadline) -> Self {
        self.deadline = deadline;
        self
    }
}

/// Push options.
#[derive(Debug, Clone, Copy, Default)]
pub struct PushOpts {
    pub ctx: OpContext,
    /// Override the deployment's default resilience policy.
    pub policy: Option<ResiliencePolicy>,
}

/// Pull options.
#[derive(Debug, Clone, Copy, Default)]
pub struct PullOpts {
    pub ctx: OpContext,
    /// Pin a specific version (default: latest).
    pub version: Option<u64>,
}

/// Container-side key for a whole object.
pub(super) fn object_key(sha3: &[u8; 32], len: u64) -> String {
    format!("obj-{}-{len}", &to_hex(sha3)[..16])
}

/// Container-side key for one erasure chunk.
pub(super) fn chunk_key(sha3: &[u8; 32], len: u64, index: u8) -> String {
    format!("chk-{}-{len}-{index}", &to_hex(sha3)[..16])
}

/// Read up to `cap` bytes from `reader` (short only at end of stream).
/// The returned buffer is the unit of streaming memory: the pipeline
/// never holds more than two of these at once.
fn read_part(reader: &mut dyn std::io::Read, cap: usize) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; cap];
    let mut filled = 0usize;
    while filled < cap {
        match reader.read(&mut buf[filled..]) {
            Ok(0) => break,
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(Error::Net(format!("stream read: {e}"))),
        }
    }
    buf.truncate(filled);
    Ok(buf)
}

/// Result of repairing one erasure unit (a whole Erasure object or one
/// part of a Striped one). The metadata commit stays with the caller:
/// an Erasure object commits its unit directly, a Striped object folds
/// every part's outcome into a single placement CAS.
enum UnitOutcome {
    /// All n chunk slots placed and live — nothing to do.
    Healthy,
    /// Fewer than k chunks reachable; the unit cannot be reconstructed.
    Lost,
    /// Reconstructed and re-placed. `chunks` is the updated slot list
    /// to commit; `newly_placed` the subset written this pass (rollback
    /// set if the CAS loses); `moved` counts heals + re-placements.
    Repaired { chunks: Vec<(u8, u32)>, moved: usize, newly_placed: Vec<(u8, u32)> },
}

/// One unit of chunk I/O for the concurrent dispatcher: an upload when
/// `data` is present, a download otherwise.
pub(super) struct ChunkJob {
    pub(super) index: u8,
    pub(super) channel: Arc<dyn ContainerChannel>,
    pub(super) key: String,
    pub(super) data: Option<Vec<u8>>,
}

/// Outcome of one dispatched transfer. Identity labels are captured
/// before dispatch so failed transfers still report which container and
/// transport were involved.
pub(super) struct ChunkXfer {
    pub(super) index: u8,
    pub(super) cid: u32,
    pub(super) transport: &'static str,
    pub(super) site: Site,
    /// Bytes placed on the wire for uploads (downloads read the fetched
    /// payload length instead).
    pub(super) wire_len: usize,
    /// Measured wallclock of the channel operation.
    pub(super) wall_s: f64,
    /// (payload for downloads, simulated device seconds).
    pub(super) res: Result<(Option<Vec<u8>>, f64)>,
}

/// A lazily-materialized object read, produced by
/// [`DynoStore::pull_stream`]. Each [`next_block`](Self::next_block)
/// call reconstructs one erasure part (for `Striped` objects), so the
/// gateway can write a part to the wire while the next one is still on
/// the containers — peak memory O(part) instead of O(object). Dropping
/// the stream (finished or abandoned mid-read) releases the
/// `streams_active` gauge.
pub struct ObjectByteStream {
    store: Arc<DynoStore>,
    meta: ObjectMeta,
    parts: Vec<PartManifest>,
    next: usize,
    deadline: Deadline,
    buffered: Option<Vec<u8>>,
}

impl ObjectByteStream {
    /// Metadata of the object being streamed.
    pub fn meta(&self) -> &ObjectMeta {
        &self.meta
    }

    /// Total object length — the Content-Length the gateway frames the
    /// response with before any part is fetched.
    pub fn total_len(&self) -> u64 {
        self.meta.size
    }

    /// The next block of object bytes in order, or `None` at the end.
    /// Errors mid-stream (a part lost past its parity budget, an
    /// expired deadline) surface here; the gateway has already sent
    /// headers by then, so it aborts the connection rather than
    /// serving a truncated body as success.
    pub fn next_block(&mut self) -> Result<Option<Vec<u8>>> {
        if let Some(data) = self.buffered.take() {
            return Ok(Some(data));
        }
        if self.next >= self.parts.len() {
            return Ok(None);
        }
        let part = self.parts[self.next].clone();
        self.next += 1;
        let label = format!("{}#part{}", self.meta.uuid, part.number);
        let (bytes, _, _, _, _, _, _) = self.store.pull_erasure_unit(
            &part.sha3,
            part.size,
            &label,
            part.n,
            part.k,
            &part.chunks,
            self.deadline,
        )?;
        self.store
            .metrics
            .bytes_out
            .fetch_add(bytes.len() as u64, std::sync::atomic::Ordering::Relaxed);
        Ok(Some(bytes))
    }
}

impl Drop for ObjectByteStream {
    fn drop(&mut self) {
        self.store
            .metrics
            .streams_active
            .fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
    }
}

impl DynoStore {
    /// Fan a batch of chunk transfers out over the I/O pool, one job per
    /// channel op, and gather the outcomes in dispatch order. Individual
    /// transfer failures come back inside each [`ChunkXfer`]; only a
    /// pool-level fault (a panicked worker job) fails the whole batch.
    /// Maintenance planes (repair, scrub, lifecycle) dispatch with no
    /// deadline; request paths thread the caller's budget through.
    pub(super) fn dispatch_chunk_io(&self, jobs: Vec<ChunkJob>) -> Result<Vec<ChunkXfer>> {
        self.dispatch_chunk_io_deadline(jobs, Deadline::none())
    }

    /// [`DynoStore::dispatch_chunk_io`] under a request deadline: an
    /// expired budget fails the batch up front, and every channel op
    /// clamps its transport wait to the remaining budget.
    pub(super) fn dispatch_chunk_io_deadline(
        &self,
        jobs: Vec<ChunkJob>,
        deadline: Deadline,
    ) -> Result<Vec<ChunkXfer>> {
        deadline.check("chunk dispatch")?;
        let labels: Vec<(u8, u32, &'static str, Site, usize)> = jobs
            .iter()
            .map(|j| {
                (
                    j.index,
                    j.channel.id(),
                    j.channel.transport(),
                    j.channel.site(),
                    j.data.as_ref().map_or(0, |d| d.len()),
                )
            })
            .collect();
        let n = jobs.len();
        let jobs = Arc::new(jobs);
        let outs = self.io_pool.scatter_gather(n, move |i| {
            let job = &jobs[i];
            let t0 = now_ns();
            let res = match &job.data {
                Some(bytes) => {
                    job.channel.put_deadline(&job.key, bytes, deadline).map(|o| (None, o.sim_s))
                }
                None => job.channel.get_deadline(&job.key, deadline).map(|o| (o.data, o.sim_s)),
            };
            ((now_ns() - t0) as f64 / 1e9, res)
        })?;
        let xfers: Vec<ChunkXfer> = outs
            .into_iter()
            .zip(labels)
            .map(|((wall_s, res), (index, cid, transport, site, wire_len))| ChunkXfer {
                index,
                cid,
                transport,
                site,
                wire_len,
                wall_s,
                res,
            })
            .collect();
        // Every chunk transfer the coordinator performs flows through
        // here — feed the D-Rex scorecards (error EWMA, latency,
        // bandwidth) before handing the batch back.
        for x in &xfers {
            let bytes = match &x.res {
                Ok((Some(data), _)) if x.wire_len == 0 => data.len() as u64,
                _ => x.wire_len as u64,
            };
            self.tiering.scores.observe_io(x.cid, x.res.is_ok(), bytes, x.wall_s);
        }
        Ok(xfers)
    }

    /// Collect up to `k` valid chunks of one erasure-coded unit (a
    /// whole Erasure object, or one part of a Striped one — `sha3` and
    /// `size` are the *unit's*, which is what its chunk keys and
    /// headers bind to) from `sources` — `(index, container)` pairs
    /// tried in order, fetched in concurrent waves, skipping known-dead
    /// channels so a dead endpoint never stalls a wave for its
    /// transport timeout. Returns the collected chunks plus the sources
    /// that were skipped, failed, or served invalid bytes (repair heals
    /// those; reconstruction ignores them).
    pub(super) fn collect_chunks(
        &self,
        sha3: &[u8; 32],
        size: u64,
        k: usize,
        sources: &[(u8, u32)],
    ) -> Result<(Vec<Chunk>, Vec<(u8, u32)>)> {
        let mut collected: Vec<Chunk> = Vec::with_capacity(k);
        let mut bad: Vec<(u8, u32)> = Vec::new();
        let mut cursor = 0usize;
        while collected.len() < k {
            let mut jobs = Vec::new();
            while jobs.len() < k - collected.len() && cursor < sources.len() {
                let (idx, cid) = sources[cursor];
                cursor += 1;
                match self.registry.get(cid) {
                    Ok(channel) if channel.is_alive() => jobs.push(ChunkJob {
                        index: idx,
                        channel,
                        key: chunk_key(sha3, size, idx),
                        data: None,
                    }),
                    _ => bad.push((idx, cid)),
                }
            }
            if jobs.is_empty() {
                break;
            }
            for xfer in self.dispatch_chunk_io(jobs)? {
                let mut valid = false;
                if let Ok((Some(bytes), _)) = &xfer.res {
                    if let Ok(chunk) = Chunk::unpack(bytes) {
                        if chunk.header.index == xfer.index
                            && chunk.header.object_hash == *sha3
                        {
                            collected.push(chunk);
                            valid = true;
                        }
                    }
                }
                if !valid {
                    bad.push((xfer.index, xfer.cid));
                }
            }
        }
        Ok((collected, bad))
    }

    /// Upload an object (client `push`). Algorithm 1 under an erasure
    /// policy; single-container placement under Regular.
    pub fn push(
        &self,
        token: &str,
        collection: &str,
        name: &str,
        data: &[u8],
        opts: PushOpts,
    ) -> Result<PushReport> {
        let claims = self.tokens.validate(token).map_err(|e| {
            self.metrics.auth_failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            e
        })?;
        if !claims.has_scope("write") {
            return Err(Error::PermissionDenied("token lacks write scope".into()));
        }
        let policy = opts.policy.unwrap_or(self.default_policy);
        let ctx = opts.ctx;
        ctx.deadline.check("push")?;
        let hash = sha3_256(data);
        let len = data.len() as u64;

        // Client → gateway ingress over the WAN.
        let ingress_s =
            self.wan.transfer_s(ctx.client_site, self.gateway_site, len, ctx.flows);

        let (placement, encode_s, encode_wall_s, disperse_s, stored_bytes, chunk_io) =
            match policy {
                ResiliencePolicy::Regular => {
                    // Drain-aware: a decommissioning container never
                    // receives new placements (same for the paths below).
                    let target = self.placer.select_one(&self.registry.placement_infos(), len)?;
                    // Dispatch-time re-check: the draining flag may have
                    // landed between selection and this write.
                    if self.registry.is_draining(target.id) {
                        return Err(Error::Unavailable(
                            "selected container began draining; retry the push".into(),
                        ));
                    }
                    let channel = self.registry.get(target.id)?;
                    let key = object_key(&hash, len);
                    let t0 = now_ns();
                    let put_res = channel.put_deadline(&key, data, ctx.deadline);
                    let wall_s = (now_ns() - t0) as f64 / 1e9;
                    // The Regular path bypasses dispatch_chunk_io, so
                    // it feeds the scorecards directly.
                    self.tiering.scores.observe_io(target.id, put_res.is_ok(), len, wall_s);
                    let dev_s = put_res?.sim_s;
                    let net_s =
                        self.wan.transfer_s(self.gateway_site, channel.site(), len, 1);
                    let chunk_io = vec![ChunkIoReport {
                        index: 0,
                        container: target.id,
                        transport: channel.transport(),
                        ok: true,
                        sim_s: net_s + dev_s,
                        wall_s,
                    }];
                    (
                        ObjectPlacement::Single { container: target.id },
                        0.0,
                        0.0,
                        net_s + dev_s,
                        len,
                        chunk_io,
                    )
                }
                ResiliencePolicy::Fixed(cfg) => {
                    self.disperse(data, &hash, cfg, None, ctx.deadline)?
                }
                ResiliencePolicy::Dynamic { k, target_loss } => {
                    let chunk_size = (len / k as u64).max(1);
                    let infos = self.registry.placement_infos();
                    let choice = select_dynamic(&infos, chunk_size, k, target_loss)?;
                    self.disperse(data, &hash, choice.config, Some(choice.containers), ctx.deadline)?
                }
                ResiliencePolicy::Adaptive { nines } => {
                    let infos = self.registry.placement_infos();
                    let choice = select_adaptive(
                        &infos,
                        &self.tiering.scores,
                        len,
                        nines_to_loss(nines),
                    )?;
                    if !choice.met_target {
                        crate::log_warn!(
                            "adaptive placement best-effort: loss {:.2e} misses target {:.2e}",
                            choice.loss_probability,
                            choice.target_loss
                        );
                    }
                    self.metrics
                        .adaptive_selections
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    self.disperse(data, &hash, choice.config, Some(choice.containers), ctx.deadline)?
                }
            };

        // Metadata commit through Paxos (strong consistency, §IV-B),
        // guarded by commit-time target validation: every container the
        // placement names must still be registered and not draining — a
        // decommission may have flagged one while the uploads above
        // were in flight, and its verified-empty scan cannot see a
        // not-yet-committed placement. The precheck runs under the same
        // exclusive lock as the commit (and as decommission's scans),
        // so there is no window between validation and commit. On any
        // commit failure the written chunks are dropped; Unavailable is
        // retryable client-side.
        let placed_ids = placement.containers();
        let t0 = now_ns();
        let submitted = self.meta.submit_guarded(
            MetaCommand::PutObject {
                caller: claims.subject.clone(),
                collection: collection.into(),
                name: name.into(),
                size: len,
                sha3: hash,
                placement,
                now: unix_secs(),
            },
            || {
                if placed_ids.iter().any(|&cid| {
                    self.registry.is_draining(cid) || self.registry.get(cid).is_err()
                }) {
                    return Err(Error::Unavailable(
                        "a placement target began draining during upload; retry the push"
                            .into(),
                    ));
                }
                Ok(())
            },
        );
        // On an aborted commit the written chunks are left in place
        // (not deleted): chunk keys are content-derived, so an
        // identical-content object committed by another push may share
        // them — deleting here could destroy its data. Leaked copies on
        // a draining container disappear with the container; elsewhere
        // they are harmless unreferenced bytes.
        let outcome = submitted?;
        let meta = match outcome {
            CommandOutcome::Meta(meta) => *meta,
            CommandOutcome::Failed(e) => return Err(Error::from_failed(e)),
            other => return Err(Error::Consensus(format!("unexpected outcome {other:?}"))),
        };
        let meta_s = META_COMMIT_BASE_S + (now_ns() - t0) as f64 / 1e9;

        self.metrics.pushes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics.bytes_in.fetch_add(len, std::sync::atomic::Ordering::Relaxed);

        Ok(PushReport {
            meta,
            sim_s: cost::seq(&[ingress_s, encode_s, disperse_s, meta_s]),
            ingress_s,
            encode_s,
            encode_wall_s,
            disperse_s,
            meta_s,
            stored_bytes,
            backend: self.backend_name(),
            chunk_io,
        })
    }

    /// Streaming upload: erasure-encode and disperse the body one
    /// part at a time as bytes arrive, instead of buffering the whole
    /// object. Part p's chunk uploads overlap the read of part p+1
    /// (pipeline depth 2), so peak gateway memory is bounded by
    /// 2 × `part_size` regardless of object size. Objects that fit in
    /// a single part delegate to the buffered [`push`] and produce
    /// byte-identical metadata (same SHA3/ETag, same `Erasure`
    /// placement); larger objects commit a `Striped` placement whose
    /// object hash is the composite of per-part hashes.
    pub fn push_stream(
        &self,
        token: &str,
        collection: &str,
        name: &str,
        reader: &mut dyn std::io::Read,
        part_size: usize,
        opts: PushOpts,
    ) -> Result<PushReport> {
        let claims = self.tokens.validate(token).map_err(|e| {
            self.metrics.auth_failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            e
        })?;
        if !claims.has_scope("write") {
            return Err(Error::PermissionDenied("token lacks write scope".into()));
        }
        if part_size == 0 {
            return Err(Error::Invalid("part size must be positive".into()));
        }
        let policy = opts.policy.unwrap_or(self.default_policy);
        let ctx = opts.ctx;
        ctx.deadline.check("push stream")?;
        let _stream = self.metrics.begin_stream();

        if matches!(policy, ResiliencePolicy::Regular) {
            // Regular placement is a single whole-object copy — there
            // is no stripe to pipeline, so drain the body and take the
            // buffered path.
            let mut data = Vec::new();
            loop {
                let buf = read_part(reader, part_size)?;
                if buf.is_empty() {
                    break;
                }
                data.extend_from_slice(&buf);
            }
            return self.push(token, collection, name, &data, PushOpts {
                ctx,
                policy: Some(policy),
            });
        }

        let first = read_part(reader, part_size)?;
        if first.len() < part_size {
            // ≤ one part: buffered push, byte-identical result.
            return self.push(token, collection, name, &first, PushOpts {
                ctx,
                policy: Some(policy),
            });
        }
        let second = read_part(reader, part_size)?;
        if second.is_empty() {
            return self.push(token, collection, name, &first, PushOpts {
                ctx,
                policy: Some(policy),
            });
        }

        // ≥ 2 parts: pipeline. One dispersal runs on a scoped worker
        // while this thread reads the next part from the wire; the
        // worker is joined before the next dispatch, so at most two
        // part buffers are alive at once. A failed read or dispersal
        // aborts with no metadata commit — already-written chunks are
        // left behind under content-derived keys (harmless, same
        // rationale as an aborted buffered push).
        let mut parts: Vec<PartManifest> = Vec::new();
        let mut encode_s = 0.0;
        let mut encode_wall_s = 0.0;
        let mut disperse_s = 0.0;
        let mut stored_bytes = 0u64;
        let mut chunk_io: Vec<ChunkIoReport> = Vec::new();
        let mut total_len = 0u64;
        std::thread::scope(|scope| -> Result<()> {
            type PartOut = Result<(PartManifest, f64, f64, f64, u64, Vec<ChunkIoReport>)>;
            let mut pending: Option<std::thread::ScopedJoinHandle<'_, PartOut>> = None;
            let mut number: u32 = 0;
            let mut queued = Some(first);
            let mut lookahead = Some(second);
            loop {
                let buf = match queued.take() {
                    Some(b) => b,
                    None => unreachable!("queued refilled each iteration"),
                };
                if buf.is_empty() {
                    break;
                }
                number += 1;
                if let Some(handle) = pending.take() {
                    let (part, e_s, ew_s, d_s, stored, io) = handle
                        .join()
                        .map_err(|_| Error::Pool("part dispersal worker panicked".into()))??;
                    encode_s += e_s;
                    encode_wall_s += ew_s;
                    disperse_s += d_s;
                    stored_bytes += stored;
                    chunk_io.extend(io);
                    parts.push(part);
                }
                total_len += buf.len() as u64;
                let num = number;
                let deadline = ctx.deadline;
                pending = Some(scope.spawn(move || {
                    self.disperse_part(&buf, num, policy, deadline)
                }));
                queued = Some(match lookahead.take() {
                    Some(b) => b,
                    None => read_part(reader, part_size)?,
                });
            }
            if let Some(handle) = pending.take() {
                let (part, e_s, ew_s, d_s, stored, io) = handle
                    .join()
                    .map_err(|_| Error::Pool("part dispersal worker panicked".into()))??;
                encode_s += e_s;
                encode_wall_s += ew_s;
                disperse_s += d_s;
                stored_bytes += stored;
                chunk_io.extend(io);
                parts.push(part);
            }
            Ok(())
        })?;

        let hash = composite_sha3(&parts);
        let ingress_s =
            self.wan.transfer_s(ctx.client_site, self.gateway_site, total_len, ctx.flows);
        let placement = ObjectPlacement::Striped { parts };
        let placed_ids = placement.containers();
        let t0 = now_ns();
        // Same commit-time drain guard as the buffered push: every
        // container the striped placement names must still be
        // registered and not draining when the Paxos commit lands.
        let submitted = self.meta.submit_guarded(
            MetaCommand::PutObject {
                caller: claims.subject.clone(),
                collection: collection.into(),
                name: name.into(),
                size: total_len,
                sha3: hash,
                placement,
                now: unix_secs(),
            },
            || {
                if placed_ids.iter().any(|&cid| {
                    self.registry.is_draining(cid) || self.registry.get(cid).is_err()
                }) {
                    return Err(Error::Unavailable(
                        "a placement target began draining during upload; retry the push"
                            .into(),
                    ));
                }
                Ok(())
            },
        );
        let meta = match submitted? {
            CommandOutcome::Meta(meta) => *meta,
            CommandOutcome::Failed(e) => return Err(Error::from_failed(e)),
            other => return Err(Error::Consensus(format!("unexpected outcome {other:?}"))),
        };
        let meta_s = META_COMMIT_BASE_S + (now_ns() - t0) as f64 / 1e9;

        self.metrics.pushes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics.bytes_in.fetch_add(total_len, std::sync::atomic::Ordering::Relaxed);

        Ok(PushReport {
            meta,
            sim_s: cost::seq(&[ingress_s, encode_s, disperse_s, meta_s]),
            ingress_s,
            encode_s,
            encode_wall_s,
            disperse_s,
            meta_s,
            stored_bytes,
            backend: self.backend_name(),
            chunk_io,
        })
    }

    /// Erasure-encode and place one part (a streaming stripe or a
    /// multipart part) as an independent unit: its own SHA3, its own
    /// chunk keys, its own container selection. Regular policy is
    /// rejected — parts exist to bound memory under striping.
    #[allow(clippy::type_complexity)]
    fn disperse_part(
        &self,
        data: &[u8],
        number: u32,
        policy: ResiliencePolicy,
        deadline: Deadline,
    ) -> Result<(PartManifest, f64, f64, f64, u64, Vec<ChunkIoReport>)> {
        let (cfg, pinned) = match policy {
            ResiliencePolicy::Regular => {
                return Err(Error::Invalid(
                    "streaming/multipart parts require an erasure policy".into(),
                ))
            }
            ResiliencePolicy::Fixed(cfg) => (cfg, None),
            ResiliencePolicy::Dynamic { k, target_loss } => {
                let chunk_size = (data.len() as u64 / k as u64).max(1);
                let infos = self.registry.placement_infos();
                let choice = select_dynamic(&infos, chunk_size, k, target_loss)?;
                (choice.config, Some(choice.containers))
            }
            ResiliencePolicy::Adaptive { nines } => {
                let infos = self.registry.placement_infos();
                let choice = select_adaptive(
                    &infos,
                    &self.tiering.scores,
                    data.len() as u64,
                    nines_to_loss(nines),
                )?;
                self.metrics
                    .adaptive_selections
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                (choice.config, Some(choice.containers))
            }
        };
        let hash = sha3_256(data);
        let (placement, encode_s, encode_wall_s, disperse_s, stored, chunk_io) =
            self.disperse(data, &hash, cfg, pinned, deadline)?;
        let (n, k, chunks) = match placement {
            ObjectPlacement::Erasure { n, k, chunks } => (n, k, chunks),
            other => {
                return Err(Error::Placement(format!(
                    "disperse produced non-erasure placement {other:?}"
                )))
            }
        };
        Ok((
            PartManifest { number, size: data.len() as u64, sha3: hash, n, k, chunks },
            encode_s,
            encode_wall_s,
            disperse_s,
            stored,
            chunk_io,
        ))
    }

    /// Start a multipart upload: mint a replicated upload id under
    /// which parts accumulate until complete/abort. The id is minted
    /// through Paxos so an interrupted upload is resumable after a
    /// coordinator restart.
    pub fn multipart_init(
        &self,
        token: &str,
        collection: &str,
        name: &str,
    ) -> Result<String> {
        let claims = self.tokens.validate(token).map_err(|e| {
            self.metrics.auth_failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            e
        })?;
        if !claims.has_scope("write") {
            return Err(Error::PermissionDenied("token lacks write scope".into()));
        }
        let outcome = self.meta.submit(MetaCommand::MultipartInit {
            caller: claims.subject.clone(),
            collection: collection.into(),
            name: name.into(),
            now: unix_secs(),
        })?;
        let upload_id = match outcome {
            CommandOutcome::UploadId(id) => id,
            CommandOutcome::Failed(e) => return Err(Error::from_failed(e)),
            other => return Err(Error::Consensus(format!("unexpected outcome {other:?}"))),
        };
        self.metrics.multipart_inits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(upload_id)
    }

    /// Upload one part of a multipart upload: stripe and place it as
    /// an independent erasure unit, then record its manifest in the
    /// replicated upload state. Re-uploading a part number replaces
    /// the manifest and garbage-collects the displaced part's chunks
    /// (unless the replacement is byte-identical, in which case the
    /// content-derived keys are shared). Returns the part manifest;
    /// its `etag()` is the per-part ETag the client checks on resume.
    pub fn multipart_put_part(
        &self,
        token: &str,
        upload_id: &str,
        part_number: u32,
        data: &[u8],
        opts: PushOpts,
    ) -> Result<PartManifest> {
        let claims = self.tokens.validate(token).map_err(|e| {
            self.metrics.auth_failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            e
        })?;
        if !claims.has_scope("write") {
            return Err(Error::PermissionDenied("token lacks write scope".into()));
        }
        let policy = opts.policy.unwrap_or(self.default_policy);
        let ctx = opts.ctx;
        ctx.deadline.check("multipart put")?;
        // Pre-flight existence/permission check so an unknown upload id
        // fails before any chunk I/O is spent.
        let caller = claims.subject.clone();
        self.meta.read_upload(upload_id, {
            let caller = caller.clone();
            let upload_id = upload_id.to_string();
            move |s| s.multipart_parts(&caller, &upload_id).map(|_| ())
        })?;
        let (part, _, _, _, _, _) = self.disperse_part(data, part_number, policy, ctx.deadline)?;
        let outcome = self.meta.submit(MetaCommand::MultipartPut {
            caller,
            upload_id: upload_id.into(),
            part: part.clone(),
        })?;
        let displaced = match outcome {
            CommandOutcome::PartReplaced(displaced) => displaced,
            CommandOutcome::Failed(e) => return Err(Error::from_failed(e)),
            other => return Err(Error::Consensus(format!("unexpected outcome {other:?}"))),
        };
        if let Some(old) = displaced {
            // GC the replaced part's chunks now rather than leaking
            // them until abort — unless the re-upload carried identical
            // bytes, whose chunk keys the new manifest shares.
            if old.sha3 != part.sha3 || old.size != part.size {
                self.delete_part_chunks(&old);
            }
        }
        self.metrics
            .bytes_in
            .fetch_add(data.len() as u64, std::sync::atomic::Ordering::Relaxed);
        Ok(part)
    }

    /// List the parts recorded so far for an upload — the resume
    /// surface: a client that lost its connection asks what landed,
    /// compares ETags, and re-sends only what is missing.
    pub fn multipart_parts(&self, token: &str, upload_id: &str) -> Result<UploadState> {
        let claims = self.tokens.validate(token).map_err(|e| {
            self.metrics.auth_failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            e
        })?;
        let caller = claims.subject.clone();
        let id = upload_id.to_string();
        self.meta.read_upload(upload_id, move |s| s.multipart_parts(&caller, &id))
    }

    /// Complete a multipart upload: atomically (one Paxos command)
    /// assemble the recorded parts in part-number order into a
    /// `Striped` object placement and drop the upload state. The same
    /// commit-time drain guard as `push` applies across every part's
    /// containers.
    pub fn multipart_complete(&self, token: &str, upload_id: &str) -> Result<ObjectMeta> {
        let claims = self.tokens.validate(token).map_err(|e| {
            self.metrics.auth_failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            e
        })?;
        if !claims.has_scope("write") {
            return Err(Error::PermissionDenied("token lacks write scope".into()));
        }
        let caller = claims.subject.clone();
        // Read the recorded parts first so the drain precheck can
        // validate every container the final placement will name.
        let state = self.meta.read_upload(upload_id, {
            let caller = caller.clone();
            let upload_id = upload_id.to_string();
            move |s| s.multipart_parts(&caller, &upload_id)
        })?;
        let placed_ids: Vec<u32> = state
            .parts
            .values()
            .flat_map(|p| p.chunks.iter().map(|&(_, cid)| cid))
            .collect();
        let submitted = self.meta.submit_guarded(
            MetaCommand::MultipartComplete {
                caller,
                upload_id: upload_id.into(),
                now: unix_secs(),
            },
            || {
                if placed_ids.iter().any(|&cid| {
                    self.registry.is_draining(cid) || self.registry.get(cid).is_err()
                }) {
                    return Err(Error::Unavailable(
                        "a part's container began draining; retry the completion".into(),
                    ));
                }
                Ok(())
            },
        );
        let meta = match submitted? {
            CommandOutcome::Meta(meta) => *meta,
            CommandOutcome::Failed(e) => return Err(Error::from_failed(e)),
            other => return Err(Error::Consensus(format!("unexpected outcome {other:?}"))),
        };
        self.metrics.multipart_completes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics.pushes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(meta)
    }

    /// Abort a multipart upload: drop the replicated upload state and
    /// garbage-collect every orphan part's chunks so an abandoned
    /// upload leaves no stored bytes behind.
    pub fn multipart_abort(&self, token: &str, upload_id: &str) -> Result<usize> {
        let claims = self.tokens.validate(token).map_err(|e| {
            self.metrics.auth_failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            e
        })?;
        if !claims.has_scope("write") {
            return Err(Error::PermissionDenied("token lacks write scope".into()));
        }
        let outcome = self.meta.submit(MetaCommand::MultipartAbort {
            caller: claims.subject.clone(),
            upload_id: upload_id.into(),
        })?;
        let orphans = match outcome {
            CommandOutcome::Aborted(parts) => parts,
            CommandOutcome::Failed(e) => return Err(Error::from_failed(e)),
            other => return Err(Error::Consensus(format!("unexpected outcome {other:?}"))),
        };
        let count = orphans.len();
        for part in &orphans {
            self.delete_part_chunks(part);
        }
        self.metrics.multipart_aborts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(count)
    }

    /// Best-effort deletion of one part's stored chunks (abort GC and
    /// replaced-part GC). Failures are ignored: the keys are
    /// content-derived, so a missed delete is an unreferenced leak,
    /// never a correctness hazard.
    fn delete_part_chunks(&self, part: &PartManifest) {
        for &(idx, cid) in &part.chunks {
            if let Ok(channel) = self.registry.get(cid) {
                let _ = channel.delete(&chunk_key(&part.sha3, part.size, idx));
            }
        }
    }

    /// Erasure-encode and upload chunks (Algorithm 1 lines 2-10).
    /// `pinned` fixes the container list (dynamic policy); otherwise the
    /// UF load balancer picks n containers (line 2).
    #[allow(clippy::type_complexity)]
    fn disperse(
        &self,
        data: &[u8],
        hash: &[u8; 32],
        cfg: ErasureConfig,
        pinned: Option<Vec<u32>>,
        deadline: Deadline,
    ) -> Result<(ObjectPlacement, f64, f64, f64, u64, Vec<ChunkIoReport>)> {
        let len = data.len() as u64;
        let codec = self.codec(cfg)?;
        let chunk_size = codec.chunk_len(data.len()) as u64;

        let targets: Vec<u32> = match pinned {
            Some(ids) => ids,
            None => self
                .placer
                .select(&self.registry.placement_infos(), chunk_size, cfg.n)? // line 2
                .iter()
                .map(|c| c.id)
                .collect(),
        };
        if targets.len() != cfg.n {
            return Err(Error::Placement(format!(
                "need {} containers, got {}", // line 4
                cfg.n,
                targets.len()
            )));
        }
        // Dispatch-time drain check: selection (or the dynamic policy's
        // pinning) may predate a concurrent decommission's draining
        // flag — never start chunk writes onto a departing container.
        // Unavailable is retryable: the client's retry re-selects.
        if targets.iter().any(|&cid| self.registry.is_draining(cid)) {
            return Err(Error::Unavailable(
                "a selected container began draining; retry the push".into(),
            ));
        }

        // Encode (lines 6-9) — measured for perf telemetry, modeled
        // (calibrated bandwidth) for simulated time.
        let t0 = now_ns();
        let chunks = codec.encode(data)?;
        let encode_wall_s = (now_ns() - t0) as f64 / 1e9;
        let encode_s = data.len() as f64 / GATEWAY_CODING_BW;

        // Upload chunk i to container D[i] (line 10), all n transfers
        // dispatched concurrently through the container channels; they
        // leave the gateway together and share its uplink.
        let mut jobs = Vec::with_capacity(cfg.n);
        for (chunk, &cid) in chunks.into_iter().zip(&targets) {
            let channel = self.registry.get(cid)?;
            let key = chunk_key(hash, len, chunk.header.index);
            jobs.push(ChunkJob { index: chunk.header.index, channel, key, data: Some(chunk.packed) });
        }
        let mut times = Vec::with_capacity(cfg.n);
        let mut stored = 0u64;
        let mut placed = Vec::with_capacity(cfg.n);
        let mut chunk_io = Vec::with_capacity(cfg.n);
        for xfer in self.dispatch_chunk_io_deadline(jobs, deadline)? {
            let (_, dev_s) = xfer.res?;
            let net_s = self.wan.transfer_s(
                self.gateway_site,
                xfer.site,
                xfer.wire_len as u64,
                cfg.n as u32,
            );
            times.push(net_s + dev_s);
            stored += xfer.wire_len as u64;
            placed.push((xfer.index, xfer.cid));
            chunk_io.push(ChunkIoReport {
                index: xfer.index,
                container: xfer.cid,
                transport: xfer.transport,
                ok: true,
                sim_s: net_s + dev_s,
                wall_s: xfer.wall_s,
            });
        }
        Ok((
            ObjectPlacement::Erasure { n: cfg.n, k: cfg.k, chunks: placed },
            encode_s,
            encode_wall_s,
            cost::par(&times),
            stored,
            chunk_io,
        ))
    }

    /// Download an object (client `pull`). Algorithm 2 under erasure:
    /// fetch any k chunks, decode, verify the SHA3-256.
    pub fn pull(
        &self,
        token: &str,
        collection: &str,
        name: &str,
        opts: PullOpts,
    ) -> Result<PullReport> {
        let claims = self.tokens.validate(token).map_err(|e| {
            self.metrics.auth_failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            e
        })?;
        let ctx = opts.ctx;
        ctx.deadline.check("pull")?;
        let meta = match opts.version {
            None => self
                .meta
                .read(|s| s.get_latest(&claims.subject, collection, name))?,
            Some(v) => self
                .meta
                .read(|s| s.get_version(&claims.subject, collection, name, v))?,
        };
        self.tiering.record_access(&meta.uuid);

        let (data, collect_s, decode_s, decode_wall_s, fetched, degraded, chunk_io) =
            match &meta.placement {
                ObjectPlacement::Single { container } => {
                    // A Regular object has exactly one live copy, and a
                    // lifecycle migration may move it between our
                    // metadata read and this fetch (erasure readers are
                    // covered by the parity budget; Single readers
                    // follow the move instead): on a failed or
                    // hash-mismatched fetch, re-read the placement once
                    // and retry from wherever the copy went.
                    let key = object_key(&meta.sha3, meta.size);
                    let mut cid = *container;
                    let mut chunk_io: Vec<ChunkIoReport> = Vec::with_capacity(2);
                    let mut retried = false;
                    loop {
                        let mut last_err: Option<Error> = None;
                        let fetched = match self.registry.get(cid) {
                            Ok(channel) => {
                                let t0 = now_ns();
                                let res = channel.get_deadline(&key, ctx.deadline);
                                let wall_s = (now_ns() - t0) as f64 / 1e9;
                                let got = match res {
                                    Ok(out) => {
                                        let data = out.data.unwrap_or_default();
                                        // Integrity check on the regular
                                        // path too (§IV-E2).
                                        if sha3_256(&data) == meta.sha3 {
                                            let net_s = self.wan.transfer_s(
                                                channel.site(),
                                                self.gateway_site,
                                                meta.size,
                                                1,
                                            );
                                            Some((data, net_s + out.sim_s))
                                        } else {
                                            last_err = Some(Error::Integrity(
                                                "object hash mismatch".into(),
                                            ));
                                            None
                                        }
                                    }
                                    Err(e) => {
                                        last_err = Some(e);
                                        None
                                    }
                                };
                                chunk_io.push(ChunkIoReport {
                                    index: 0,
                                    container: cid,
                                    transport: channel.transport(),
                                    ok: got.is_some(),
                                    sim_s: got.as_ref().map_or(0.0, |&(_, s)| s),
                                    wall_s,
                                });
                                // Single-copy reads bypass
                                // dispatch_chunk_io; score them here.
                                self.tiering.scores.observe_io(
                                    cid,
                                    got.is_some(),
                                    meta.size,
                                    wall_s,
                                );
                                got
                            }
                            Err(e) => {
                                last_err = Some(e);
                                None
                            }
                        };
                        if let Some((data, sim)) = fetched {
                            break (data, sim, 0.0, 0.0, 1usize, retried, chunk_io);
                        }
                        let err = last_err.expect("failed fetch recorded an error");
                        if retried {
                            return Err(err);
                        }
                        retried = true;
                        match self.meta.read_uuid(&meta.uuid, |s| s.get_by_uuid(&meta.uuid))?.placement {
                            ObjectPlacement::Single { container } if container != cid => {
                                cid = container;
                            }
                            _ => return Err(err),
                        }
                    }
                }
                ObjectPlacement::Erasure { n, k, chunks } => self.pull_erasure_unit(
                    &meta.sha3,
                    meta.size,
                    &meta.uuid,
                    *n,
                    *k,
                    chunks,
                    ctx.deadline,
                )?,
                ObjectPlacement::Striped { parts } => {
                    // Streamed / multipart layout: each part is an
                    // independent erasure unit, assembled in part-number
                    // order. Hedging and the deadline budget apply per
                    // part; decode verifies each part's own SHA3, and
                    // the object-level hash (composite of part hashes)
                    // is re-derived from the manifest below.
                    let mut data = Vec::with_capacity(meta.size as usize);
                    let mut collect_s = 0.0;
                    let mut decode_s = 0.0;
                    let mut decode_wall_s = 0.0;
                    let mut fetched = 0usize;
                    let mut degraded = false;
                    let mut chunk_io = Vec::new();
                    for part in parts {
                        let label = format!("{}#part{}", meta.uuid, part.number);
                        let (bytes, c_s, d_s, dw_s, got, deg, io) = self.pull_erasure_unit(
                            &part.sha3,
                            part.size,
                            &label,
                            part.n,
                            part.k,
                            &part.chunks,
                            ctx.deadline,
                        )?;
                        data.extend_from_slice(&bytes);
                        collect_s += c_s;
                        decode_s += d_s;
                        decode_wall_s += dw_s;
                        fetched += got;
                        degraded = degraded || deg;
                        chunk_io.extend(io);
                    }
                    if composite_sha3(parts) != meta.sha3 {
                        return Err(Error::Integrity(format!(
                            "object {}: part manifest does not match composite hash",
                            meta.uuid
                        )));
                    }
                    (data, collect_s, decode_s, decode_wall_s, fetched, degraded, chunk_io)
                }
            };

        let egress_s =
            self.wan.transfer_s(self.gateway_site, ctx.client_site, meta.size, ctx.flows);
        self.metrics.pulls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics
            .bytes_out
            .fetch_add(meta.size, std::sync::atomic::Ordering::Relaxed);

        Ok(PullReport {
            sim_s: cost::seq(&[collect_s, decode_s, egress_s]),
            data,
            meta,
            collect_s,
            decode_s,
            decode_wall_s,
            egress_s,
            chunks_fetched: fetched,
            degraded,
            backend: self.backend_name(),
            chunk_io,
        })
    }

    /// Fetch-and-decode one erasure unit — a whole Erasure object or a
    /// single part of a Striped one (`sha3`/`size` are the unit's own,
    /// which its chunk keys and headers bind to; `label` names it in
    /// errors). Prefers the k systematic data chunks (lowest indices),
    /// fetched concurrently, and hedges to parity in follow-up waves
    /// when a container is dead, a transfer fails, or a chunk comes
    /// back corrupt (Algorithm 2: any k distinct chunks reconstruct).
    /// Returns `(data, collect_s, decode_s, decode_wall_s,
    /// chunks_fetched, degraded, chunk_io)`.
    #[allow(clippy::too_many_arguments, clippy::type_complexity)]
    fn pull_erasure_unit(
        &self,
        sha3: &[u8; 32],
        size: u64,
        label: &str,
        n: usize,
        k: usize,
        chunks: &[(u8, u32)],
        deadline: Deadline,
    ) -> Result<(Vec<u8>, f64, f64, f64, usize, bool, Vec<ChunkIoReport>)> {
        let cfg = ErasureConfig::new(n, k);
        let codec = self.codec(cfg)?;
        let mut ordered: Vec<(u8, u32)> = chunks.to_vec();
        ordered.sort_by_key(|&(idx, _)| idx);
        let mut collected: Vec<Chunk> = Vec::with_capacity(k);
        let mut chunk_io: Vec<ChunkIoReport> = Vec::with_capacity(k);
        let mut collect_s = 0.0;
        let mut degraded = false;
        let mut cursor = 0usize;
        let mut waves = 0usize;
        while collected.len() < k {
            // A hedge wave only starts if there is budget left to run
            // it; an expired deadline surfaces as Timeout, not as a
            // stalled read.
            deadline.check("pull hedge wave")?;
            waves += 1;
            // Next wave: as many untried chunks as still needed.
            let mut jobs = Vec::new();
            while jobs.len() < k - collected.len() && cursor < ordered.len() {
                let (idx, cid) = ordered[cursor];
                cursor += 1;
                match self.registry.get(cid) {
                    // Dispatch only to containers believed alive
                    // (cached liveness for remote channels): a
                    // known-dead endpoint would stall the whole wave
                    // for its transport timeout instead of hedging
                    // straight to parity.
                    Ok(channel) if channel.is_alive() => jobs.push(ChunkJob {
                        index: idx,
                        channel,
                        key: chunk_key(sha3, size, idx),
                        data: None,
                    }),
                    skipped => {
                        degraded = degraded || (idx as usize) < k;
                        // Skips count as failed attempts in the report,
                        // so the operator sees which container degraded
                        // the read.
                        chunk_io.push(ChunkIoReport {
                            index: idx,
                            container: cid,
                            transport: skipped
                                .map(|c| c.transport())
                                .unwrap_or("unregistered"),
                            ok: false,
                            sim_s: 0.0,
                            wall_s: 0.0,
                        });
                    }
                }
            }
            if jobs.is_empty() {
                return Err(Error::Unavailable(format!(
                    "object {label}: only {} of {k} required chunks reachable",
                    collected.len()
                )));
            }
            let mut wave_times = Vec::with_capacity(jobs.len());
            for xfer in self.dispatch_chunk_io_deadline(jobs, deadline)? {
                let fetched_s = match xfer.res {
                    Ok((bytes, dev_s)) => {
                        let bytes = bytes.unwrap_or_default();
                        // A corrupt or foreign chunk is treated exactly
                        // like a dead container: skip it and keep
                        // collecting toward k.
                        match Chunk::unpack(&bytes) {
                            Ok(chunk)
                                if chunk.header.index == xfer.index
                                    && chunk.header.object_hash == *sha3 =>
                            {
                                let net_s = self.wan.transfer_s(
                                    xfer.site,
                                    self.gateway_site,
                                    bytes.len() as u64,
                                    k as u32,
                                );
                                wave_times.push(net_s + dev_s);
                                collected.push(chunk);
                                Some(net_s + dev_s)
                            }
                            _ => None,
                        }
                    }
                    Err(_) => None,
                };
                if fetched_s.is_none() {
                    degraded = degraded || (xfer.index as usize) < k;
                }
                chunk_io.push(ChunkIoReport {
                    index: xfer.index,
                    container: xfer.cid,
                    transport: xfer.transport,
                    ok: fetched_s.is_some(),
                    sim_s: fetched_s.unwrap_or(0.0),
                    wall_s: xfer.wall_s,
                });
            }
            // Every hedge wave costs one more parallel round.
            collect_s += cost::par(&wave_times);
        }
        // Waves past the first are internal retries against parity;
        // surface them so operators can see hedging.
        if waves > 1 {
            self.metrics
                .retries
                .fetch_add((waves - 1) as u64, std::sync::atomic::Ordering::Relaxed);
        }
        let t0 = now_ns();
        let data = codec.decode(&collected)?; // verifies the unit SHA3
        let decode_wall_s = (now_ns() - t0) as f64 / 1e9;
        let decode_s = data.len() as f64 / GATEWAY_CODING_BW;
        Ok((data, collect_s, decode_s, decode_wall_s, collected.len(), degraded, chunk_io))
    }

    /// Streaming download: resolve the object, then hand back a
    /// [`ObjectByteStream`] that materializes one block at a time —
    /// one erasure part per block for `Striped` objects (peak memory
    /// O(part), with the full per-part parity hedging of
    /// [`pull`]), or a single pre-pulled block for `Single`/`Erasure`
    /// placements (whose chunk layout requires all k chunks at once
    /// anyway). The `streams_active` gauge tracks the stream's
    /// lifetime; it drops when the stream is dropped.
    pub fn pull_stream(
        self: Arc<Self>,
        token: &str,
        collection: &str,
        name: &str,
        opts: PullOpts,
    ) -> Result<ObjectByteStream> {
        let claims = self.tokens.validate(token).map_err(|e| {
            self.metrics.auth_failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            e
        })?;
        let ctx = opts.ctx;
        ctx.deadline.check("pull stream")?;
        let meta = match opts.version {
            None => self
                .meta
                .read(|s| s.get_latest(&claims.subject, collection, name))?,
            Some(v) => self
                .meta
                .read(|s| s.get_version(&claims.subject, collection, name, v))?,
        };
        match &meta.placement {
            ObjectPlacement::Striped { parts } => {
                self.tiering.record_access(&meta.uuid);
                let parts = parts.clone();
                self.metrics.pulls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.metrics
                    .streams_active
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Ok(ObjectByteStream {
                    store: self,
                    meta,
                    parts,
                    next: 0,
                    deadline: ctx.deadline,
                    buffered: None,
                })
            }
            _ => {
                // Buffered fallback, same accounting as a plain pull.
                let report =
                    self.pull(token, collection, name, PullOpts { ctx, version: opts.version })?;
                self.metrics
                    .streams_active
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Ok(ObjectByteStream {
                    store: self,
                    meta: report.meta,
                    parts: Vec::new(),
                    next: 0,
                    deadline: ctx.deadline,
                    buffered: Some(report.data),
                })
            }
        }
    }

    /// Metadata of `(collection, name)` at `version` (`None` = latest)
    /// without touching the data plane — the `/v1` stat / `HEAD`
    /// surface: size, version, content hash (ETag), placement.
    pub fn stat(
        &self,
        token: &str,
        collection: &str,
        name: &str,
        version: Option<u64>,
    ) -> Result<ObjectMeta> {
        let claims = self.tokens.validate(token).map_err(|e| {
            self.metrics.auth_failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            e
        })?;
        match version {
            None => {
                self.meta.read_at(collection, |s| s.get_latest(&claims.subject, collection, name))
            }
            Some(v) => self
                .meta
                .read_at(collection, |s| s.get_version(&claims.subject, collection, name, v)),
        }
    }

    /// Eviction generation of `(collection, name)` — the nonce-epoch
    /// salt the next push of that name will carry. Valid (and 0) even
    /// when the name has no live versions, which is exactly when an
    /// encrypting client needs it (see `ObjectMeta::nonce_epoch`).
    pub fn nonce_epoch(&self, token: &str, collection: &str, name: &str) -> Result<u64> {
        let claims = self.tokens.validate(token).map_err(|e| {
            self.metrics.auth_failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            e
        })?;
        self.meta.read_at(collection, |s| s.nonce_epoch(&claims.subject, collection, name))
    }

    /// Paginated object listing of a collection (the `/v1/collections`
    /// surface): names starting with `prefix`, strictly after `after`,
    /// at most `limit` entries, name-ordered.
    pub fn list_page(
        &self,
        token: &str,
        collection: &str,
        prefix: &str,
        after: Option<&str>,
        limit: usize,
    ) -> Result<ObjectPage> {
        let claims = self.tokens.validate(token).map_err(|e| {
            self.metrics.auth_failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            e
        })?;
        self.meta
            .read_at(collection, |s| s.list_page(&claims.subject, collection, prefix, after, limit))
    }

    /// Grant `perm` on collection `path` to `user` (the `/v1/grants`
    /// surface). Ownership is enforced by the metadata store — only the
    /// namespace owner may grant.
    pub fn grant(&self, token: &str, path: &str, user: &str, perm: Permission) -> Result<()> {
        self.acl_command(token, |caller| MetaCommand::Grant {
            caller,
            path: path.into(),
            user: user.into(),
            perm,
        })
    }

    /// Revoke a direct grant (inverse of [`DynoStore::grant`]).
    pub fn revoke(&self, token: &str, path: &str, user: &str, perm: Permission) -> Result<()> {
        self.acl_command(token, |caller| MetaCommand::Revoke {
            caller,
            path: path.into(),
            user: user.into(),
            perm,
        })
    }

    fn acl_command(
        &self,
        token: &str,
        cmd: impl FnOnce(String) -> MetaCommand,
    ) -> Result<()> {
        let claims = self.tokens.validate(token).map_err(|e| {
            self.metrics.auth_failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            e
        })?;
        if !claims.has_scope("write") {
            return Err(Error::PermissionDenied("token lacks write scope".into()));
        }
        match self.meta.submit(cmd(claims.subject))? {
            CommandOutcome::Failed(e) => Err(Error::from_failed(e)),
            _ => Ok(()),
        }
    }

    /// Range pull: return exactly `object[start..=end]` (end clamped to
    /// the object size), fetching **only the systematic chunks covering
    /// the range** when they are all reachable and intact — no erasure
    /// decode, no full-object transfer. This is the wide-area partial
    /// read of the satellite / medical case studies: a header-sized
    /// probe of a multi-GiB scene moves one chunk, not the scene.
    ///
    /// Fallback: when any covering chunk is missing, dead, or corrupt,
    /// the read degrades to a normal [`DynoStore::pull`] (parity
    /// reconstruction and the full integrity check included) and the
    /// slice is cut from the reconstruction. The fast path cannot verify
    /// the whole-object SHA3 (it doesn't have the whole object); it
    /// verifies each chunk's header binds to the object's recorded hash
    /// and rejects length mismatches, and readers needing end-to-end
    /// proof use a full pull.
    pub fn pull_range(
        &self,
        token: &str,
        collection: &str,
        name: &str,
        start: u64,
        end: u64,
        opts: PullOpts,
    ) -> Result<RangeReport> {
        let meta = self.stat(token, collection, name, opts.version)?;
        opts.ctx.deadline.check("pull_range")?;
        if start > end {
            return Err(Error::Invalid(format!("bad range {start}-{end}")));
        }
        if start >= meta.size {
            return Err(Error::Invalid(format!(
                "range start {start} beyond object size {}",
                meta.size
            )));
        }
        let end = end.min(meta.size - 1);

        let mut attempted: Vec<ChunkIoReport> = Vec::new();
        if let ObjectPlacement::Erasure { n, k, chunks } = &meta.placement {
            let cfg = ErasureConfig::new(*n, *k);
            let codec = self.codec(cfg)?;
            let chunk_len = codec.chunk_len(meta.size as usize) as u64;
            // Data byte b lives in systematic chunk b / chunk_len; since
            // end < size <= k * chunk_len, every needed index is < k.
            let j0 = (start / chunk_len) as u8;
            let j1 = (end / chunk_len) as u8;
            let (fast, attempts) =
                self.range_fast_path(&meta, chunk_len, j0, j1, start, end, chunks, &opts)?;
            if let Some(report) = fast {
                // The buffered fallback below records through pull();
                // the fast path records its own access.
                self.tiering.record_access(&meta.uuid);
                return Ok(report);
            }
            // The failed attempts stay in the final report, so the
            // operator sees which chunk degraded the range read.
            attempted = attempts;
        }

        // Fallback: full pull (parity reconstruction + SHA3 verify) and
        // slice. Pin the version this range was planned against — a
        // concurrent re-push must not swap a different (possibly
        // shorter) object under the already-clamped range.
        let report = self.pull(
            token,
            collection,
            name,
            PullOpts { version: Some(meta.version), ..opts },
        )?;
        if report.data.len() as u64 != meta.size {
            // Defensive: a version pin guarantees this, but never index
            // past what actually came back.
            return Err(Error::Unavailable(format!(
                "object {} changed size mid-range-read; retry",
                meta.uuid
            )));
        }
        let data = report.data[start as usize..=end as usize].to_vec();
        self.metrics.range_pulls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        attempted.extend(report.chunk_io);
        Ok(RangeReport {
            data,
            meta: report.meta,
            start,
            end,
            chunks_fetched: report.chunks_fetched,
            partial: false,
            sim_s: report.sim_s,
            chunk_io: attempted,
        })
    }

    /// Attempt the partial read: fetch exactly the systematic chunks
    /// `j0..=j1`. A `None` report means "use the full-pull fallback"
    /// (some covering chunk is unplaced, dead, failed, or invalid); the
    /// accompanying vec carries whatever transfers were attempted, so
    /// failed attempts survive into the fallback's telemetry.
    #[allow(clippy::too_many_arguments)]
    fn range_fast_path(
        &self,
        meta: &ObjectMeta,
        chunk_len: u64,
        j0: u8,
        j1: u8,
        start: u64,
        end: u64,
        placed: &[(u8, u32)],
        opts: &PullOpts,
    ) -> Result<(Option<RangeReport>, Vec<ChunkIoReport>)> {
        let mut jobs = Vec::with_capacity((j1 - j0 + 1) as usize);
        for j in j0..=j1 {
            let Some(&(idx, cid)) = placed.iter().find(|&&(idx, _)| idx == j) else {
                return Ok((None, Vec::new())); // slot missing from the placement
            };
            match self.registry.get(cid) {
                Ok(channel) if channel.is_alive() => jobs.push(ChunkJob {
                    index: idx,
                    channel,
                    key: chunk_key(&meta.sha3, meta.size, idx),
                    data: None,
                }),
                _ => return Ok((None, Vec::new())), // dead or unregistered holder
            }
        }
        let fetchers = jobs.len();
        let mut payloads: Vec<Vec<u8>> = Vec::with_capacity(fetchers);
        let mut chunk_io = Vec::with_capacity(fetchers);
        let mut times = Vec::with_capacity(fetchers);
        let mut ok = true;
        for xfer in self.dispatch_chunk_io_deadline(jobs, opts.ctx.deadline)? {
            let valid = match &xfer.res {
                Ok((Some(bytes), dev_s)) => match Chunk::unpack(bytes) {
                    Ok(chunk)
                        if chunk.header.index == xfer.index
                            && chunk.header.object_hash == meta.sha3
                            && chunk.header.chunk_len == chunk_len =>
                    {
                        let net_s = self.wan.transfer_s(
                            xfer.site,
                            self.gateway_site,
                            bytes.len() as u64,
                            fetchers as u32,
                        );
                        times.push(net_s + *dev_s);
                        payloads.push(chunk.payload().to_vec());
                        Some(net_s + *dev_s)
                    }
                    _ => None,
                },
                _ => None,
            };
            ok &= valid.is_some();
            chunk_io.push(ChunkIoReport {
                index: xfer.index,
                container: xfer.cid,
                transport: xfer.transport,
                ok: valid.is_some(),
                sim_s: valid.unwrap_or(0.0),
                wall_s: xfer.wall_s,
            });
        }
        if !ok {
            return Ok((None, chunk_io));
        }
        // Assemble the slice: chunk j holds global bytes
        // [j*chunk_len, (j+1)*chunk_len); cut each chunk's overlap
        // with [start, end] in index order.
        let mut data = Vec::with_capacity((end - start + 1) as usize);
        for (j, payload) in (j0..=j1).zip(&payloads) {
            let base = j as u64 * chunk_len;
            let lo = start.max(base) - base;
            let hi = end.min(base + chunk_len - 1) - base;
            data.extend_from_slice(&payload[lo as usize..=hi as usize]);
        }
        let collect_s = cost::par(&times);
        let egress_s = self.wan.transfer_s(
            self.gateway_site,
            opts.ctx.client_site,
            end - start + 1,
            opts.ctx.flows,
        );
        self.metrics.range_pulls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics
            .bytes_out
            .fetch_add(end - start + 1, std::sync::atomic::Ordering::Relaxed);
        Ok((
            Some(RangeReport {
                data,
                meta: meta.clone(),
                start,
                end,
                chunks_fetched: fetchers,
                partial: true,
                sim_s: cost::seq(&[collect_s, egress_s]),
                chunk_io,
            }),
            Vec::new(),
        ))
    }

    /// Does the latest version of `(collection, name)` exist (and is it
    /// visible to the caller)?
    pub fn exists(&self, token: &str, collection: &str, name: &str) -> Result<bool> {
        let claims = self.tokens.validate(token)?;
        match self.meta.read_at(collection, |s| s.get_latest(&claims.subject, collection, name)) {
            Ok(_) => Ok(true),
            Err(Error::NotFound(_)) => Ok(false),
            Err(e) => Err(e),
        }
    }

    /// Remove an object and all its versions; deletes chunks from live
    /// containers (dead ones are swept when GC next sees them).
    pub fn evict(&self, token: &str, collection: &str, name: &str) -> Result<usize> {
        let claims = self.tokens.validate(token)?;
        let outcome = self.meta.submit(MetaCommand::Evict {
            caller: claims.subject,
            collection: collection.into(),
            name: name.into(),
        })?;
        let metas = match outcome {
            CommandOutcome::Evicted(m) => m,
            CommandOutcome::Failed(e) => return Err(Error::from_failed(e)),
            other => return Err(Error::Consensus(format!("unexpected outcome {other:?}"))),
        };
        let mut deleted = 0;
        for meta in &metas {
            self.tiering.forget_access(&meta.uuid);
            deleted += self.delete_stored(meta);
        }
        Ok(deleted)
    }

    /// Garbage-collect superseded versions older than `retention_secs`
    /// (paper §IV-B, default 30 days). Returns collected version count.
    pub fn gc(&self, now: u64, retention_secs: u64) -> Result<usize> {
        let outcome =
            self.meta.submit(MetaCommand::Gc { now, retention_secs })?;
        let metas = match outcome {
            CommandOutcome::Collected(m) => m,
            other => return Err(Error::Consensus(format!("unexpected outcome {other:?}"))),
        };
        for meta in &metas {
            self.tiering.forget_access(&meta.uuid);
            self.delete_stored(meta);
        }
        self.metrics
            .gc_collected
            .fetch_add(metas.len() as u64, std::sync::atomic::Ordering::Relaxed);
        Ok(metas.len())
    }

    fn delete_stored(&self, meta: &ObjectMeta) -> usize {
        self.delete_placement(&meta.sha3, meta.size, &meta.placement)
    }

    /// Best-effort deletion of every stored copy a placement names
    /// (evict/gc sweeps; push's commit-abort cleanup).
    pub(super) fn delete_placement(
        &self,
        sha3: &[u8; 32],
        size: u64,
        placement: &ObjectPlacement,
    ) -> usize {
        let mut deleted = 0;
        match placement {
            ObjectPlacement::Single { container } => {
                if let Ok(c) = self.registry.get(*container) {
                    if c.delete(&object_key(sha3, size)).is_ok() {
                        deleted += 1;
                    }
                }
            }
            ObjectPlacement::Erasure { chunks, .. } => {
                for &(idx, cid) in chunks {
                    if let Ok(c) = self.registry.get(cid) {
                        if c.delete(&chunk_key(sha3, size, idx)).is_ok() {
                            deleted += 1;
                        }
                    }
                }
            }
            ObjectPlacement::Striped { parts } => {
                // Each part's chunks are keyed by the PART's hash and
                // size, not the object's composite hash.
                for part in parts {
                    for &(idx, cid) in &part.chunks {
                        if let Ok(c) = self.registry.get(cid) {
                            if c.delete(&chunk_key(&part.sha3, part.size, idx)).is_ok() {
                                deleted += 1;
                            }
                        }
                    }
                }
            }
        }
        deleted
    }

    /// Health-service repair pass (§III-B): for every object version,
    /// re-disperse chunks lost to dead containers onto healthy ones and
    /// commit the updated placement. Objects with fewer than k live
    /// chunks are reported lost. Reconstruction reads and re-placement
    /// writes both fan out concurrently over the container channels.
    pub fn repair(&self) -> Result<RepairReport> {
        let mut report = RepairReport::default();
        let objects = self.meta.all_objects()?;
        // One active probe per container per pass (a remote probe is an
        // HTTP round trip — never pay it per object, let alone per chunk).
        let alive_by_id: HashMap<u32, bool> =
            self.registry.all().iter().map(|c| (c.id(), c.probe())).collect();
        let is_live = |cid: u32| alive_by_id.get(&cid).copied().unwrap_or(false);
        for meta in objects {
            report.scanned += 1;
            match &meta.placement {
                ObjectPlacement::Single { container } => {
                    // Regular objects on a dead container are simply lost
                    // (the paper's motivation for the resilience policy).
                    if !is_live(*container) {
                        report.lost += 1;
                    }
                }
                ObjectPlacement::Erasure { n, k, chunks } => {
                    match self.repair_unit(&meta.sha3, meta.size, *n, *k, chunks, &is_live)? {
                        UnitOutcome::Healthy => {}
                        UnitOutcome::Lost => report.lost += 1,
                        UnitOutcome::Repaired { chunks: new_chunks, moved, newly_placed } => {
                            // CAS against the placement this pass read: a
                            // concurrent lifecycle migration must not be
                            // silently overwritten (its committed
                            // placement names chunks repair's stale
                            // snapshot doesn't know about).
                            let outcome = self.meta.submit(MetaCommand::UpdatePlacement {
                                uuid: meta.uuid.clone(),
                                placement: ObjectPlacement::Erasure {
                                    n: *n,
                                    k: *k,
                                    chunks: new_chunks,
                                },
                                expect: Some(meta.placement.clone()),
                            })?;
                            if let CommandOutcome::Failed(_) = outcome {
                                // Placement changed (migration committed)
                                // or the object vanished: drop the copies
                                // we just wrote — unless the committed
                                // placement references them — and let the
                                // next pass re-assess from fresh state.
                                let committed = self
                                    .meta
                                    .read(|s| s.get_by_uuid(&meta.uuid))
                                    .map(|m| m.placement)
                                    .ok();
                                for &(idx, cid) in &newly_placed {
                                    let referenced = matches!(
                                        &committed,
                                        Some(ObjectPlacement::Erasure { chunks, .. })
                                            if chunks.contains(&(idx, cid))
                                    );
                                    if !referenced {
                                        if let Ok(c) = self.registry.get(cid) {
                                            let _ = c.delete(&chunk_key(
                                                &meta.sha3, meta.size, idx,
                                            ));
                                        }
                                    }
                                }
                                report.chunks_moved += moved - newly_placed.len();
                                continue;
                            }
                            report.chunks_moved += moved;
                            report.repaired += 1;
                            self.metrics
                                .repairs
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                }
                ObjectPlacement::Striped { parts } => {
                    // Each part is an independent erasure unit; repair
                    // them unit by unit and commit ONE updated Striped
                    // placement via CAS. A lost part marks the object
                    // lost (it cannot be served whole), but parts that
                    // did repair are still committed so their healing
                    // is not thrown away.
                    let mut any_lost = false;
                    let mut any_repaired = false;
                    let mut moved_total = 0usize;
                    let mut new_parts: Vec<PartManifest> = Vec::with_capacity(parts.len());
                    let mut placed_by_part: Vec<(PartManifest, Vec<(u8, u32)>)> = Vec::new();
                    for part in parts {
                        match self.repair_unit(
                            &part.sha3,
                            part.size,
                            part.n,
                            part.k,
                            &part.chunks,
                            &is_live,
                        )? {
                            UnitOutcome::Healthy => new_parts.push(part.clone()),
                            UnitOutcome::Lost => {
                                any_lost = true;
                                new_parts.push(part.clone());
                            }
                            UnitOutcome::Repaired { chunks, moved, newly_placed } => {
                                any_repaired = true;
                                moved_total += moved;
                                let mut healed = part.clone();
                                healed.chunks = chunks;
                                if !newly_placed.is_empty() {
                                    placed_by_part.push((part.clone(), newly_placed));
                                }
                                new_parts.push(healed);
                            }
                        }
                    }
                    if any_lost {
                        report.lost += 1;
                    }
                    if !any_repaired {
                        continue;
                    }
                    let outcome = self.meta.submit(MetaCommand::UpdatePlacement {
                        uuid: meta.uuid.clone(),
                        placement: ObjectPlacement::Striped { parts: new_parts },
                        expect: Some(meta.placement.clone()),
                    })?;
                    if let CommandOutcome::Failed(_) = outcome {
                        // Same rollback rule as Erasure, applied per
                        // part: chunk keys bind to the PART's hash/size,
                        // and a committed placement only protects a copy
                        // if a matching part still references it.
                        let committed = self
                            .meta
                            .read(|s| s.get_by_uuid(&meta.uuid))
                            .map(|m| m.placement)
                            .ok();
                        let mut rolled_back = 0usize;
                        for (part, newly_placed) in &placed_by_part {
                            for &(idx, cid) in newly_placed {
                                let referenced = matches!(
                                    &committed,
                                    Some(ObjectPlacement::Striped { parts })
                                        if parts.iter().any(|p| {
                                            p.sha3 == part.sha3
                                                && p.size == part.size
                                                && p.chunks.contains(&(idx, cid))
                                        })
                                );
                                if !referenced {
                                    if let Ok(c) = self.registry.get(cid) {
                                        let _ = c.delete(&chunk_key(
                                            &part.sha3, part.size, idx,
                                        ));
                                    }
                                }
                                rolled_back += 1;
                            }
                        }
                        report.chunks_moved += moved_total - rolled_back;
                        continue;
                    }
                    report.chunks_moved += moved_total;
                    report.repaired += 1;
                    self.metrics.repairs.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }
        }
        Ok(report)
    }

    /// Repair one erasure unit (a whole Erasure object or one part of
    /// a Striped one): reconstruct from any k live chunks, heal
    /// corrupt-but-live copies in place, and re-place missing chunk
    /// slots on healthy containers. Returns the updated chunk list for
    /// the caller to commit (the metadata CAS stays with the caller,
    /// since a Striped object commits all its parts in one command).
    fn repair_unit(
        &self,
        sha3: &[u8; 32],
        size: u64,
        n: usize,
        k: usize,
        chunks: &[(u8, u32)],
        is_live: &impl Fn(u32) -> bool,
    ) -> Result<UnitOutcome> {
        let live: Vec<(u8, u32)> =
            chunks.iter().filter(|&&(_, cid)| is_live(cid)).copied().collect();
        // Fully healthy means all n chunk slots are placed AND live —
        // a previously committed partial placement (a re-placement
        // write failed mid-repair) must be topped back up to n.
        if live.len() == chunks.len() && chunks.len() == n {
            return Ok(UnitOutcome::Healthy);
        }
        if live.len() < k {
            return Ok(UnitOutcome::Lost);
        }
        // Reconstruct from any k live chunks, fetched concurrently;
        // hedge past sources that fail or return corrupt bytes — and
        // remember those, so the corruption gets healed below instead
        // of lingering in the committed placement.
        let cfg = ErasureConfig::new(n, k);
        let codec = self.codec(cfg)?;
        let (collected, bad_live) = self.collect_chunks(sha3, size, k, &live)?;
        if collected.len() < k {
            return Ok(UnitOutcome::Lost);
        }
        let data = codec.decode(&collected)?;
        let mut all_chunks = codec.encode(&data)?;
        let mut new_placement = live.clone();
        let mut moved = 0usize;

        // Heal corrupt-but-live chunks in place: rewrite the correct
        // bytes onto the container that served garbage. (A unit whose
        // containers are ALL live is skipped by the early-exit above —
        // corruption is healed when a repair pass touches the unit,
        // not by a full scrub.)
        if !bad_live.is_empty() {
            let mut jobs = Vec::with_capacity(bad_live.len());
            for &(idx, cid) in &bad_live {
                if let Ok(channel) = self.registry.get(cid) {
                    jobs.push(ChunkJob {
                        index: idx,
                        channel,
                        key: chunk_key(sha3, size, idx),
                        data: Some(std::mem::take(&mut all_chunks[idx as usize].packed)),
                    });
                }
            }
            for xfer in self.dispatch_chunk_io(jobs)? {
                match xfer.res {
                    Ok(_) => moved += 1,
                    // Rewrite failed: drop the stale entry so the next
                    // pass treats the chunk as missing.
                    Err(_) => new_placement
                        .retain(|&(i, c)| !(i == xfer.index && c == xfer.cid)),
                }
            }
        }

        let live_ids: HashSet<u32> = live.iter().map(|&(_, c)| c).collect();
        // Every chunk index not live right now needs (re-)placement:
        // chunks whose container died AND slots missing from the
        // committed placement entirely.
        let placed_idx: HashSet<u8> = live.iter().map(|&(i, _)| i).collect();
        let missing: Vec<u8> = (0..n as u8).filter(|i| !placed_idx.contains(i)).collect();

        // Healthy, non-draining containers not already holding a chunk
        // of this unit, ranked by the load balancer.
        let infos: Vec<_> = self
            .registry
            .placement_infos()
            .into_iter()
            .filter(|i| i.alive && !live_ids.contains(&i.id))
            .collect();
        let chunk_size = codec.chunk_len(data.len()) as u64;
        let replacements = self.placer.select(&infos, chunk_size, missing.len())?;

        let mut jobs = Vec::with_capacity(missing.len());
        for (idx, target) in missing.iter().zip(&replacements) {
            let channel = self.registry.get(target.id)?;
            let packed = std::mem::take(&mut all_chunks[*idx as usize].packed);
            jobs.push(ChunkJob {
                index: *idx,
                channel,
                key: chunk_key(sha3, size, *idx),
                data: Some(packed),
            });
        }
        let mut newly_placed: Vec<(u8, u32)> = Vec::new();
        for xfer in self.dispatch_chunk_io(jobs)? {
            // A failed re-placement write must not abort the whole pass
            // (transport failure is an expected event on this plane):
            // commit only the chunks that landed; the next pass retries
            // the rest as still-missing.
            if xfer.res.is_ok() {
                new_placement.push((xfer.index, xfer.cid));
                newly_placed.push((xfer.index, xfer.cid));
                moved += 1;
            }
        }
        new_placement.sort_by_key(|&(idx, _)| idx);
        Ok(UnitOutcome::Repaired { chunks: new_placement, moved, newly_placed })
    }

    /// Direct in-process container access for a chunk (tests, FaaS
    /// workers reading near data). Errors for remote containers — use
    /// [`DynoStore::channel_of`] to reach those.
    pub fn container_of(&self, id: u32) -> Result<Arc<DataContainer>> {
        self.registry.get_local(id)
    }

    /// The dispatch channel for a container, whatever its transport.
    pub fn channel_of(&self, id: u32) -> Result<Arc<dyn ContainerChannel>> {
        self.registry.get(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{deploy_containers, AgentSpec};
    use crate::sim::DeviceKind;

    fn deployment(n_containers: usize) -> (DynoStore, String) {
        deployment_with_engine(n_containers, crate::coordinator::GfEngine::PureRust)
    }

    fn deployment_with_engine(
        n_containers: usize,
        engine: crate::coordinator::GfEngine,
    ) -> (DynoStore, String) {
        let ds = DynoStore::builder().engine(engine).build();
        let sites = [Site::ChameleonTacc, Site::ChameleonUc, Site::AwsVirginia];
        let specs: Vec<AgentSpec> = (0..n_containers)
            .map(|i| {
                AgentSpec::new(
                    format!("dc{i}"),
                    sites[i % sites.len()],
                    DeviceKind::ChameleonLocal,
                )
                .mem(64 << 20)
                .fs(1 << 32)
                .afr(0.01 + 0.02 * i as f64)
            })
            .collect();
        for c in deploy_containers(&specs, n_containers, 0).containers {
            ds.add_container(c).unwrap();
        }
        let token = ds.register_user("UserA").unwrap();
        (ds, token)
    }

    fn data(len: usize, seed: u64) -> Vec<u8> {
        crate::util::Rng::new(seed).bytes(len)
    }

    #[test]
    fn push_pull_roundtrip_resilient() {
        let (ds, token) = deployment(12);
        let object = data(200_000, 1);
        let push = ds
            .push(&token, "/UserA", "obj1", &object, PushOpts::default())
            .unwrap();
        assert!(push.sim_s > 0.0);
        assert!(push.stored_bytes > object.len() as u64, "parity adds bytes");
        let pull = ds.pull(&token, "/UserA", "obj1", PullOpts::default()).unwrap();
        assert_eq!(pull.data, object);
        assert_eq!(pull.chunks_fetched, 7);
        assert!(!pull.degraded);
    }

    #[test]
    fn push_pull_roundtrip_on_swar_engines() {
        for (engine, name) in [
            (crate::coordinator::GfEngine::Swar, "swar"),
            (crate::coordinator::GfEngine::SwarParallel, "swar-parallel"),
        ] {
            let (ds, token) = deployment_with_engine(12, engine);
            let object = data(150_000, 42);
            let push = ds
                .push(&token, "/UserA", "obj", &object, PushOpts::default())
                .unwrap();
            assert_eq!(push.backend, name);
            let pull = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
            assert_eq!(pull.data, object, "engine {name}");
            assert_eq!(pull.backend, name);
        }
    }

    #[test]
    fn push_pull_regular_policy() {
        let (ds, token) = deployment(4);
        let object = data(50_000, 2);
        let opts = PushOpts {
            policy: Some(ResiliencePolicy::Regular),
            ..Default::default()
        };
        let push = ds.push(&token, "/UserA", "obj", &object, opts).unwrap();
        assert_eq!(push.stored_bytes, object.len() as u64);
        assert_eq!(push.encode_s, 0.0);
        let pull = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
        assert_eq!(pull.data, object);
    }

    #[test]
    fn resilience_survives_max_failures() {
        let (ds, token) = deployment(12);
        let object = data(100_000, 3);
        ds.push(&token, "/UserA", "obj", &object, PushOpts::default()).unwrap();
        // Kill 3 of the containers holding chunks (max tolerated for (10,7)).
        let meta = ds.meta.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        let holders = meta.placement.containers();
        for &cid in holders.iter().take(3) {
            ds.container_of(cid).unwrap().set_alive(false);
        }
        let pull = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
        assert_eq!(pull.data, object);
        // One more failure exceeds the budget.
        ds.container_of(holders[3]).unwrap().set_alive(false);
        assert!(matches!(
            ds.pull(&token, "/UserA", "obj", PullOpts::default()),
            Err(Error::Unavailable(_))
        ));
    }

    #[test]
    fn degraded_read_flagged() {
        let (ds, token) = deployment(12);
        let object = data(60_000, 4);
        ds.push(&token, "/UserA", "obj", &object, PushOpts::default()).unwrap();
        let meta = ds.meta.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        // Kill the container holding data chunk 0 → parity fallback.
        let chunk0_holder = match &meta.placement {
            ObjectPlacement::Erasure { chunks, .. } => {
                chunks.iter().find(|&&(i, _)| i == 0).unwrap().1
            }
            _ => unreachable!(),
        };
        ds.container_of(chunk0_holder).unwrap().set_alive(false);
        let pull = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
        assert_eq!(pull.data, object);
        assert!(pull.degraded);
    }

    #[test]
    fn corrupt_chunk_skipped_like_dead_container() {
        let (ds, token) = deployment(12);
        let object = data(90_000, 21);
        ds.push(&token, "/UserA", "obj", &object, PushOpts::default()).unwrap();
        let meta = ds.meta.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        // Overwrite data chunk 0's stored bytes with garbage.
        let (idx, cid) = match &meta.placement {
            ObjectPlacement::Erasure { chunks, .. } => {
                *chunks.iter().find(|&&(i, _)| i == 0).unwrap()
            }
            _ => unreachable!(),
        };
        let key = chunk_key(&meta.sha3, meta.size, idx);
        ds.container_of(cid).unwrap().put(&key, b"garbage, not a chunk").unwrap();
        // The pull must hedge to parity instead of aborting on unpack.
        let pull = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
        assert_eq!(pull.data, object);
        assert!(pull.degraded, "corruption is a degraded read");
        assert!(pull.chunk_io.iter().any(|c| !c.ok), "failed attempt recorded");
        assert_eq!(pull.chunks_fetched, 7);
    }

    #[test]
    fn corruption_beyond_parity_budget_is_unavailable() {
        let (ds, token) = deployment(12);
        let object = data(60_000, 22);
        ds.push(&token, "/UserA", "obj", &object, PushOpts::default()).unwrap();
        let meta = ds.meta.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        let chunks = match &meta.placement {
            ObjectPlacement::Erasure { chunks, .. } => chunks.clone(),
            _ => unreachable!(),
        };
        // Corrupt 4 chunks of a (10,7) object: only 6 clean ones remain.
        for &(idx, cid) in chunks.iter().take(4) {
            let key = chunk_key(&meta.sha3, meta.size, idx);
            ds.container_of(cid).unwrap().put(&key, b"junk").unwrap();
        }
        assert!(matches!(
            ds.pull(&token, "/UserA", "obj", PullOpts::default()),
            Err(Error::Unavailable(_))
        ));
    }

    #[test]
    fn reports_carry_per_chunk_transport_labels() {
        let (ds, token) = deployment(12);
        let object = data(50_000, 23);
        let push = ds.push(&token, "/UserA", "obj", &object, PushOpts::default()).unwrap();
        assert_eq!(push.chunk_io.len(), 10, "one entry per uploaded chunk");
        assert!(push
            .chunk_io
            .iter()
            .all(|c| c.ok && c.transport == "local" && c.sim_s > 0.0));
        let pull = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
        assert_eq!(pull.chunk_io.len(), 7);
        assert!(pull.chunk_io.iter().all(|c| c.ok && c.transport == "local"));
        // Regular-policy objects report a single whole-object transfer.
        let opts = PushOpts { policy: Some(ResiliencePolicy::Regular), ..Default::default() };
        let push = ds.push(&token, "/UserA", "reg", &object, opts).unwrap();
        assert_eq!(push.chunk_io.len(), 1);
    }

    #[test]
    fn dynamic_policy_places_by_reliability() {
        let (ds, token) = deployment(12);
        let opts = PushOpts {
            policy: Some(ResiliencePolicy::Dynamic { k: 4, target_loss: 0.001 }),
            ..Default::default()
        };
        let push = ds.push(&token, "/UserA", "obj", &data(40_000, 5), opts).unwrap();
        match &push.meta.placement {
            ObjectPlacement::Erasure { n, k, chunks } => {
                assert_eq!(*k, 4);
                assert!(*n > 5, "dynamic policy adds parity: n={n}");
                // Most reliable containers (lowest AFR = lowest ids here)
                // must be chosen first.
                assert!(chunks.iter().any(|&(_, c)| c == 0));
            }
            _ => panic!("expected erasure placement"),
        }
        let pull = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
        assert_eq!(pull.data.len(), 40_000);
    }

    #[test]
    fn versioning_and_rollback() {
        let (ds, token) = deployment(12);
        let v0 = data(10_000, 6);
        let v1 = data(12_000, 7);
        ds.push(&token, "/UserA", "obj", &v0, PushOpts::default()).unwrap();
        ds.push(&token, "/UserA", "obj", &v1, PushOpts::default()).unwrap();
        let latest = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
        assert_eq!(latest.data, v1);
        let old = ds
            .pull(&token, "/UserA", "obj", PullOpts { version: Some(0), ..Default::default() })
            .unwrap();
        assert_eq!(old.data, v0);
    }

    #[test]
    fn evict_removes_data_and_metadata() {
        let (ds, token) = deployment(12);
        ds.push(&token, "/UserA", "obj", &data(5_000, 8), PushOpts::default()).unwrap();
        assert!(ds.exists(&token, "/UserA", "obj").unwrap());
        let deleted = ds.evict(&token, "/UserA", "obj").unwrap();
        assert_eq!(deleted, 10, "all 10 chunks deleted");
        assert!(!ds.exists(&token, "/UserA", "obj").unwrap());
        assert!(ds.pull(&token, "/UserA", "obj", PullOpts::default()).is_err());
    }

    #[test]
    fn gc_frees_superseded_chunks() {
        let (ds, token) = deployment(12);
        ds.push(&token, "/UserA", "obj", &data(5_000, 9), PushOpts::default()).unwrap();
        ds.push(&token, "/UserA", "obj", &data(6_000, 10), PushOpts::default()).unwrap();
        let now = unix_secs() + crate::metadata::DEFAULT_RETENTION_SECS + 10;
        let collected = ds.gc(now, crate::metadata::DEFAULT_RETENTION_SECS).unwrap();
        assert_eq!(collected, 1);
        // Latest still readable.
        assert_eq!(
            ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap().data.len(),
            6_000
        );
    }

    #[test]
    fn repair_restores_failure_budget() {
        let (ds, token) = deployment(14);
        let object = data(80_000, 11);
        ds.push(&token, "/UserA", "obj", &object, PushOpts::default()).unwrap();
        let meta = ds.meta.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        let holders = meta.placement.containers();
        // Kill two chunk holders, repair, then kill three MORE of the
        // original holders: without repair that is 5 failures > 3
        // tolerated; after repair the budget is restored.
        for &cid in holders.iter().take(2) {
            ds.container_of(cid).unwrap().set_alive(false);
        }
        let report = ds.repair().unwrap();
        assert_eq!(report.repaired, 1);
        assert_eq!(report.chunks_moved, 2);
        assert_eq!(report.lost, 0);
        for &cid in holders.iter().skip(2).take(3) {
            ds.container_of(cid).unwrap().set_alive(false);
        }
        let pull = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
        assert_eq!(pull.data, object);
    }

    #[test]
    fn repair_heals_corrupt_chunk_it_encounters() {
        let (ds, token) = deployment(12);
        let object = data(70_000, 24);
        ds.push(&token, "/UserA", "obj", &object, PushOpts::default()).unwrap();
        let meta = ds.meta.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        let chunks = match &meta.placement {
            ObjectPlacement::Erasure { chunks, .. } => chunks.clone(),
            _ => unreachable!(),
        };
        // Corrupt data chunk 0 in place and kill the holder of chunk 9,
        // so the repair pass touches the object and trips over the rot.
        let (idx0, cid0) = chunks[0];
        ds.container_of(cid0)
            .unwrap()
            .put(&chunk_key(&meta.sha3, meta.size, idx0), b"rot")
            .unwrap();
        let (_, cid9) = *chunks.iter().find(|&&(i, _)| i == 9).unwrap();
        ds.container_of(cid9).unwrap().set_alive(false);

        let report = ds.repair().unwrap();
        assert_eq!(report.repaired, 1);
        assert_eq!(report.chunks_moved, 2, "dead chunk re-placed + corrupt chunk healed");
        // The healed object now pulls clean: chunk 0 is valid again.
        let pull = ds.pull(&token, "/UserA", "obj", PullOpts::default()).unwrap();
        assert_eq!(pull.data, object);
        assert!(!pull.degraded, "corruption was healed in place");
    }

    #[test]
    fn repair_reports_lost_objects() {
        let (ds, token) = deployment(12);
        ds.push(&token, "/UserA", "obj", &data(5_000, 12), PushOpts::default()).unwrap();
        let meta = ds.meta.read(|s| s.get_latest("UserA", "/UserA", "obj")).unwrap();
        // Kill 4 holders of a (10,7) object: only 6 < k=7 chunks remain.
        for &cid in meta.placement.containers().iter().take(4) {
            ds.container_of(cid).unwrap().set_alive(false);
        }
        let report = ds.repair().unwrap();
        assert_eq!(report.lost, 1);
        assert_eq!(report.repaired, 0);
    }

    #[test]
    fn auth_enforced_on_data_path() {
        let (ds, _token) = deployment(12);
        let err = ds.push("garbage-token", "/UserA", "o", b"x", PushOpts::default());
        assert!(matches!(err, Err(Error::Auth(_))));
        assert_eq!(ds.metrics.snapshot()["auth_failures"], 1);
        // Token from another deployment (different secret) also fails.
        let other = DynoStore::builder().secret(b"other").build();
        let foreign = other.tokens.issue("UserA", &["read", "write"], 3600);
        assert!(matches!(
            ds.push(&foreign, "/UserA", "o", b"x", PushOpts::default()),
            Err(Error::Auth(_))
        ));
    }

    #[test]
    fn permission_isolation_between_users() {
        let (ds, token_a) = deployment(12);
        let token_b = ds.register_user("UserB").unwrap();
        ds.push(&token_a, "/UserA", "secret", &data(1_000, 13), PushOpts::default())
            .unwrap();
        // UserB cannot read UserA's object...
        assert!(matches!(
            ds.pull(&token_b, "/UserA", "secret", PullOpts::default()),
            Err(Error::PermissionDenied(_))
        ));
        // ...until UserA grants read on the collection.
        let grant = MetaCommand::Grant {
            caller: "UserA".into(),
            path: "/UserA".into(),
            user: "UserB".into(),
            perm: crate::metadata::Permission::Read,
        };
        ds.meta.submit(grant).unwrap();
        assert!(ds.pull(&token_b, "/UserA", "secret", PullOpts::default()).is_ok());
    }

    #[test]
    fn wide_area_times_are_sensible() {
        let (ds, token) = deployment(12);
        let object = data(1_000_000, 14);
        // Madrid client is slower than a Chameleon-local client.
        let far = ds
            .push(
                &token,
                "/UserA",
                "far",
                &object,
                PushOpts { ctx: OpContext::at(Site::Madrid), ..Default::default() },
            )
            .unwrap();
        let near = ds
            .push(
                &token,
                "/UserA",
                "near",
                &object,
                PushOpts { ctx: OpContext::at(Site::ChameleonUc), ..Default::default() },
            )
            .unwrap();
        assert!(far.sim_s > near.sim_s, "far {} vs near {}", far.sim_s, near.sim_s);
        assert!(far.ingress_s > near.ingress_s);
    }

    #[test]
    fn metrics_accumulate() {
        let (ds, token) = deployment(12);
        ds.push(&token, "/UserA", "m", &data(1_000, 15), PushOpts::default()).unwrap();
        ds.pull(&token, "/UserA", "m", PullOpts::default()).unwrap();
        let snap = ds.metrics.snapshot();
        assert_eq!(snap["pushes"], 1);
        assert_eq!(snap["pulls"], 1);
        assert_eq!(snap["bytes_in"], 1_000);
        assert_eq!(snap["bytes_out"], 1_000);
    }

    #[test]
    fn pull_range_fast_path_fetches_only_covering_chunks() {
        let (ds, token) = deployment(12);
        let object = data(70_000, 31); // (10,7): chunk_len = 10048
        ds.push(&token, "/UserA", "obj", &object, PushOpts::default()).unwrap();
        // Inside chunk 0.
        let r = ds.pull_range(&token, "/UserA", "obj", 100, 199, PullOpts::default()).unwrap();
        assert_eq!(r.data, &object[100..=199]);
        assert!(r.partial);
        assert_eq!(r.chunks_fetched, 1);
        assert_eq!(ds.metrics.snapshot()["range_pulls"], 1);
        // Straddling the chunk 0 / chunk 1 boundary.
        let r = ds
            .pull_range(&token, "/UserA", "obj", 10_000, 10_100, PullOpts::default())
            .unwrap();
        assert_eq!(r.data, &object[10_000..=10_100]);
        assert_eq!(r.chunks_fetched, 2);
        // End clamps to the object size.
        let r = ds
            .pull_range(&token, "/UserA", "obj", 69_990, 1 << 30, PullOpts::default())
            .unwrap();
        assert_eq!(r.end, 69_999);
        assert_eq!(r.data, &object[69_990..]);
        // Degenerate ranges error.
        assert!(ds.pull_range(&token, "/UserA", "obj", 5, 4, PullOpts::default()).is_err());
        assert!(ds
            .pull_range(&token, "/UserA", "obj", 70_000, 70_001, PullOpts::default())
            .is_err());
    }

    #[test]
    fn pull_range_respects_version_pin() {
        let (ds, token) = deployment(12);
        let v0 = data(30_000, 32);
        let v1 = data(20_000, 33);
        ds.push(&token, "/UserA", "obj", &v0, PushOpts::default()).unwrap();
        ds.push(&token, "/UserA", "obj", &v1, PushOpts::default()).unwrap();
        let pinned = PullOpts { version: Some(0), ..Default::default() };
        let r = ds.pull_range(&token, "/UserA", "obj", 25_000, 25_999, pinned).unwrap();
        assert_eq!(r.data, &v0[25_000..=25_999], "range reads the pinned version");
        let r = ds
            .pull_range(&token, "/UserA", "obj", 0, 99, PullOpts::default())
            .unwrap();
        assert_eq!(r.data, &v1[0..=99], "default range reads latest");
    }

    #[test]
    fn stat_list_page_and_grants_via_coordinator() {
        let (ds, token) = deployment(12);
        for name in ["pag-a", "pag-b", "pag-c", "other"] {
            ds.push(&token, "/UserA", name, &data(500, 40), PushOpts::default()).unwrap();
        }
        let info = ds.stat(&token, "/UserA", "pag-a", None).unwrap();
        assert_eq!(info.size, 500);
        let page = ds.list_page(&token, "/UserA", "pag-", None, 2).unwrap();
        assert_eq!(page.objects.len(), 2);
        assert!(page.truncated);
        let page = ds.list_page(&token, "/UserA", "pag-", Some("pag-b"), 2).unwrap();
        assert_eq!(page.objects.len(), 1);
        assert!(!page.truncated);
        // Grants through the coordinator surface.
        let token_b = ds.register_user("UserB").unwrap();
        assert!(ds.stat(&token_b, "/UserA", "pag-a", None).is_err());
        ds.grant(&token, "/UserA", "UserB", crate::metadata::Permission::Read).unwrap();
        assert!(ds.stat(&token_b, "/UserA", "pag-a", None).is_ok());
        ds.revoke(&token, "/UserA", "UserB", crate::metadata::Permission::Read).unwrap();
        assert!(ds.stat(&token_b, "/UserA", "pag-a", None).is_err());
        // Non-owners cannot grant (403 at the gateway).
        assert!(matches!(
            ds.grant(&token_b, "/UserA", "UserB", crate::metadata::Permission::Write),
            Err(Error::PermissionDenied(_))
        ));
    }

    #[test]
    fn streamed_push_matches_buffered_across_part_boundaries() {
        let (ds, token) = deployment(12);
        let part = 4096usize;
        // 1 B, part−1, part, part+1, and a many-part size: the first
        // three take the buffered fallback (≤ one part), the rest
        // commit a Striped placement — all must pull byte-identical.
        for (i, len) in [1, part - 1, part, part + 1, 4 * part + 123].into_iter().enumerate()
        {
            let object = data(len, 100 + i as u64);
            let name = format!("s{i}");
            let report = ds
                .push_stream(
                    &token,
                    "/UserA",
                    &name,
                    &mut std::io::Cursor::new(&object),
                    part,
                    PushOpts::default(),
                )
                .unwrap();
            assert_eq!(report.meta.size, len as u64, "len {len}");
            let striped =
                matches!(report.meta.placement, ObjectPlacement::Striped { .. });
            assert_eq!(striped, len > part, "len {len}: striped iff > one part");
            if !striped {
                // Single-part streams delegate to the buffered push:
                // same SHA3 (and hence same ETag) as a buffered push
                // of the same bytes.
                assert_eq!(report.meta.sha3, crate::crypto::sha3_256(&object));
            }
            let pull = ds.pull(&token, "/UserA", &name, PullOpts::default()).unwrap();
            assert_eq!(pull.data, object, "len {len}");
        }
    }

    #[test]
    fn streamed_pull_yields_identical_bytes() {
        let (ds, token) = deployment(12);
        let ds = std::sync::Arc::new(ds);
        let part = 8192usize;
        let object = data(3 * part + 17, 7);
        ds.push_stream(
            &token,
            "/UserA",
            "obj",
            &mut std::io::Cursor::new(&object),
            part,
            PushOpts::default(),
        )
        .unwrap();
        let mut stream = std::sync::Arc::clone(&ds)
            .pull_stream(&token, "/UserA", "obj", PullOpts::default())
            .unwrap();
        assert_eq!(stream.total_len(), object.len() as u64);
        let mut out = Vec::new();
        while let Some(block) = stream.next_block().unwrap() {
            out.extend_from_slice(&block);
        }
        assert_eq!(out, object, "streamed pull of a striped object");
        // Non-striped objects stream through the buffered fallback arm.
        let small = data(500, 8);
        ds.push(&token, "/UserA", "small", &small, PushOpts::default()).unwrap();
        let mut stream = std::sync::Arc::clone(&ds)
            .pull_stream(&token, "/UserA", "small", PullOpts::default())
            .unwrap();
        let mut out = Vec::new();
        while let Some(block) = stream.next_block().unwrap() {
            out.extend_from_slice(&block);
        }
        assert_eq!(out, small, "streamed pull of an erasure object");
    }

    #[test]
    fn multipart_out_of_order_replace_and_complete() {
        let (ds, token) = deployment(12);
        let p1 = data(10_000, 50);
        let p2 = data(6_000, 51);
        let id = ds.multipart_init(&token, "/UserA", "mp").unwrap();
        assert_eq!(ds.open_upload_count(), 1);
        // Parts land out of order; part 1 is replaced before completion.
        ds.multipart_put_part(&token, &id, 2, &p2, PushOpts::default()).unwrap();
        ds.multipart_put_part(&token, &id, 1, &data(9_999, 52), PushOpts::default())
            .unwrap();
        let replaced =
            ds.multipart_put_part(&token, &id, 1, &p1, PushOpts::default()).unwrap();
        assert_eq!(replaced.size, p1.len() as u64);
        let state = ds.multipart_parts(&token, &id).unwrap();
        assert_eq!(
            state.parts.keys().copied().collect::<Vec<_>>(),
            vec![1, 2],
            "parts listed in number order regardless of upload order"
        );
        // The object is invisible until complete.
        assert!(matches!(
            ds.pull(&token, "/UserA", "mp", PullOpts::default()),
            Err(Error::NotFound(_))
        ));
        let meta = ds.multipart_complete(&token, &id).unwrap();
        assert_eq!(meta.size, (p1.len() + p2.len()) as u64);
        assert!(matches!(meta.placement, ObjectPlacement::Striped { .. }));
        assert_eq!(ds.open_upload_count(), 0);
        assert!(ds.multipart_parts(&token, &id).is_err(), "upload state dropped");
        let pull = ds.pull(&token, "/UserA", "mp", PullOpts::default()).unwrap();
        let mut want = p1.clone();
        want.extend_from_slice(&p2);
        assert_eq!(pull.data, want, "parts assemble in number order");
    }

    #[test]
    fn multipart_abort_collects_orphan_parts() {
        let (ds, token) = deployment(12);
        let id = ds.multipart_init(&token, "/UserA", "gone").unwrap();
        ds.multipart_put_part(&token, &id, 1, &data(5_000, 60), PushOpts::default())
            .unwrap();
        ds.multipart_put_part(&token, &id, 2, &data(5_000, 61), PushOpts::default())
            .unwrap();
        assert_eq!(ds.multipart_abort(&token, &id).unwrap(), 2);
        assert_eq!(ds.open_upload_count(), 0);
        assert!(ds.multipart_parts(&token, &id).is_err());
        assert!(matches!(
            ds.pull(&token, "/UserA", "gone", PullOpts::default()),
            Err(Error::NotFound(_))
        ));
        // Unknown upload ids fail fast on every surface.
        assert!(ds
            .multipart_put_part(&token, &id, 3, &data(100, 62), PushOpts::default())
            .is_err());
        assert!(ds.multipart_complete(&token, &id).is_err());
        let snap = ds.metrics.snapshot();
        assert_eq!(snap["multipart_inits"], 1);
        assert_eq!(snap["multipart_aborts"], 1);
    }
}
